"""Serving-style example: the continuous-batching engine answering a stream
of math prompts with greedy decoding — including one mid-stream in-flight
weight update (the serving-side view of PipelineRL).

    PYTHONPATH=src python examples/serve_continuous_batching.py
"""
import jax

from repro.configs.tiny import config as tiny_config
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values


def main():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))

    ec = EngineConfig(n_slots=8, max_len=20, temperature=1e-4)  # ~greedy
    engine = GenerationEngine(cfg, params, ec, task.sample, seed=0)
    engine.refill()

    served = 0
    for step in range(96):
        if step == 30:  # in-flight update: swap weights, keep every KV cache
            new_params = tree_values(M.init_params(cfg, jax.random.PRNGKey(1)))
            engine.set_weights(new_params, version=1)
            print(f"-- step {step}: in-flight weight update applied "
                  f"({engine.n_active} sequences kept in flight)")
        for r in engine.step(task):
            served += 1
            prompt = task.tok.decode(r.tokens[:r.prompt_len])
            completion = task.tok.decode(r.tokens[r.prompt_len:])
            vmin, vmax = r.weight_versions[r.prompt_len:].min(), \
                r.weight_versions[r.prompt_len:].max()
            print(f"[{served:2d}] {prompt!r} -> {completion!r} "
                  f"(sampled under versions {vmin}..{vmax})")
        engine.refill()
    print(f"\nserved {served} requests; engine generated "
          f"{engine.tokens_generated} tokens total")


if __name__ == "__main__":
    main()
