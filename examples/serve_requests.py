"""Request-level serving with the Server API (the paper's three endpoints):
submit a burst of requests, pair with a 'trainer', and fire an in-flight
weight update mid-burst — no request is dropped, latencies are tracked.

    PYTHONPATH=src python examples/serve_requests.py
"""
import jax

from repro.configs.tiny import config as tiny_config
from repro.core.rollout import EngineConfig
from repro.core.serving import Server
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values


def main():
    task = MathTask(max_operand=9, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    params_v1 = tree_values(M.init_params(cfg, jax.random.PRNGKey(1)))

    srv = Server(cfg, params, EngineConfig(n_slots=6, max_len=20))
    srv.connect_trainer(lambda: (params_v1, 1))   # ~ /init_process_group

    for _ in range(16):                           # ~ /v1/chat/completions
        srv.submit(task.sample().prompt_ids)

    for step in range(200):
        if step == 12:
            v = srv.request_weight_update()       # ~ /request_weight_update
            m = srv.metrics()
            print(f"-- step {step}: in-flight update to v{v} with "
                  f"{m['in_flight']} requests in flight, "
                  f"{m['waiting']} waiting")
        for req in srv.step():
            mixed = (req.weight_versions.min() != req.weight_versions.max())
            print(f"[req {req.rid:2d}] latency={req.latency:4.0f} steps  "
                  f"completion={task.tok.decode(req.completion_ids)!r:12s}"
                  f"{'  <- mixed-policy (spanned the update)' if mixed else ''}")
        if not srv.in_flight and not srv.waiting:
            break

    m = srv.metrics()
    print(f"\nserved={m['served']}  p50={m['p50_latency']:.0f}  "
          f"p99={m['p99_latency']:.0f}  mean_admission_wait="
          f"{m['mean_admission_wait']:.1f} steps  "
          f"tokens={m['tokens_generated']}")


if __name__ == "__main__":
    main()
