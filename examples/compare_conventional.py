"""PipelineRL vs Conventional RL: same trainer, same task, same simulated
hardware — compare wall-clock (flash units) to reach the same sample count
and the lag/ESS profiles (paper Figures 5 and 6).

    PYTHONPATH=src python examples/compare_conventional.py [--steps 24]
"""
import argparse

import jax
import numpy as np

from repro.configs.tiny import config as tiny_config
from repro.core.algo import RLConfig
from repro.core.conventional import ConventionalConfig, ConventionalRL
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.sharding import tree_values


def fresh(seed=0):
    task = MathTask(max_operand=3, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=96, n_layers=2)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(seed)))
    trainer = Trainer(cfg, params, rl=RLConfig(entropy_coef=0.003),
                      adam=AdamConfig(lr=3e-3))
    return task, cfg, params, trainer


def summarize(name, log):
    t = log[-1]["time"]
    r = np.mean([x["reward"] for x in log[-5:]])
    ess = np.mean([x["ess"] for x in log])
    lag = max(x["max_lag"] for x in log)
    print(f"{name:16s} sim_t={t:9.0f}f  reward(last5)={r:+.3f}  "
          f"mean_ess={ess:.3f}  max_lag={lag:.0f}")
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()
    # hardware model scaled so toy batches sit where H100 batches sit on
    # U(h); PipelineRL at its balanced config (Appendix A.3): T=5 trainer
    # chips, H=24 slots -> r_gen ~ r_train, max lag ~3
    hw = HardwareModel(h_sat=16)

    task, cfg, params, trainer = fresh()
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=24, max_len=16),
                   PipelineConfig(batch_size=16, n_opt_steps=args.steps,
                                  n_chips=8, train_chips=5,
                                  pack_rows=4, pack_seq=80),
                   hw=hw, trainer=trainer)
    t_pipe = summarize("PipelineRL", p.run())

    for G in (2, 4):
        task, cfg, params, trainer = fresh()
        c = ConventionalRL(cfg, params, task,
                           EngineConfig(n_slots=16, max_len=16),
                           ConventionalConfig(batch_size=16, g_steps=G,
                                              n_opt_steps=args.steps,
                                              n_chips=8, pack_rows=4,
                                              pack_seq=80),
                           hw=hw, trainer=trainer)
        t_conv = summarize(f"Conventional G={G}", c.run())
        print(f"  -> PipelineRL speedup vs G={G}: {t_conv / t_pipe:.2f}x "
              f"(same {args.steps} optimizer steps, same batch)")


if __name__ == "__main__":
    main()
