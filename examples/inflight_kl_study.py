"""§5.1 reproduction: how far does the *mixed-policy* behavior distribution
(in-flight weight updates, stale KV cache) drift from the final policy,
compared to conventional lagged sampling and to in-flight + KV recompute?

    PYTHONPATH=src python examples/inflight_kl_study.py

Expected (paper Fig. 7): KL(inflight) << KL(conventional lag g_max), and
recomputing the KV cache changes little — justifying stale-KV in-flight
updates.

The successor study lives in `benchmarks/lag_bench.py` (DESIGN.md §12):
where this sweeps update cadence against a KL proxy, that reads the
*typed* per-token staleness contract back out of the training path
(`PipelineRL.lag_stats()`, per-lag-bucket ESS) while sweeping the
`max_lag` bounded-staleness barrier — emitting `BENCH_lag.json`.
"""
import os

os.environ.setdefault("BENCH_FAST", "1")

from benchmarks.figures import fig7_kl  # noqa: E402


def main():
    print("sampling-policy divergence from the final checkpoint "
          "(KL, nats/token):\n")
    for name, _, derived in fig7_kl():
        print(f"  {name:32s} {derived}")
    print("\nin-flight (stale KV) should sit near lag 0 / recomputed-KV, far"
          " below the full conventional lag.")


if __name__ == "__main__":
    main()
