"""Quickstart: 10 optimizer steps of PipelineRL on the math task.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API end to end: model init -> trainer -> actor pool of
generation engines -> PipelineRL orchestrator with streamed in-flight
weight broadcast on the shared event scheduler (DESIGN.md §7).
"""
import jax

from repro.configs.tiny import config as tiny_config
from repro.core.algo import RLConfig
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.sharding import tree_values


def main():
    task = MathTask(max_operand=3, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))

    pipeline = PipelineRL(
        cfg, params, task,
        # H slots, per-sequence token budget. prefill_chunk: admitted
        # prompts enter the KV cache in batched chunk-sized forwards
        # (ceil((P-1)/chunk) model calls per prompt) instead of one decode
        # step per prompt token; 0 restores the legacy forcing loop.
        EngineConfig(n_slots=16, max_len=16, prefill_chunk=8),
        # n_engines=2: an actor pool — two independent engines share the
        # N-T generation chips, each with its own clock and staggered
        # weight arrivals (identical engines share compiled step fns, so
        # the pool costs one jit compile). broadcast="streamed": weight
        # publications fill a shadow buffer chunk-by-chunk between decode
        # steps and pointer-swap on the last chunk — the decode pause per
        # update is charged and reported, not assumed free.
        PipelineConfig(batch_size=8, n_opt_steps=10,
                       n_chips=8, train_chips=4,    # T of N chips train
                       pack_rows=3, pack_seq=64,
                       n_engines=2, broadcast="streamed"),
        trainer=Trainer(cfg, params, rl=RLConfig(entropy_coef=0.003),
                        adam=AdamConfig(lr=1e-3)),
    )
    for rec in pipeline.run():
        print(f"step {rec['version']:3d}  sim_t={rec['time']:8.0f} flashes  "
              f"reward={rec['reward']:+.3f}  ess={rec['ess']:.3f}  "
              f"max_lag={rec['max_lag']:.0f}")
    total_tokens = sum(e.tokens_generated for e in pipeline.engines)
    versions = [e.version for e in pipeline.engines]
    bs = pipeline.broadcast_stats()
    pauses = [f"{e['pause_per_update']:.1f}f" for e in bs["engines"]]
    print(f"\ngenerated {total_tokens} tokens across "
          f"{len(pipeline.engines)} engines; engine weight versions "
          f"{versions}; streamed-broadcast decode pause/update {pauses}")


if __name__ == "__main__":
    main()
