"""Quickstart: 10 optimizer steps of PipelineRL on the math task.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API end to end: model init -> trainer -> generation engine
-> PipelineRL orchestrator with in-flight weight updates.
"""
import jax

from repro.configs.tiny import config as tiny_config
from repro.core.algo import RLConfig
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.sharding import tree_values


def main():
    task = MathTask(max_operand=3, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))

    pipeline = PipelineRL(
        cfg, params, task,
        # H slots, per-sequence token budget. prefill_chunk: admitted
        # prompts enter the KV cache in batched chunk-sized forwards
        # (ceil((P-1)/chunk) model calls per prompt) instead of one decode
        # step per prompt token; 0 restores the legacy forcing loop.
        EngineConfig(n_slots=16, max_len=16, prefill_chunk=8),
        PipelineConfig(batch_size=8, n_opt_steps=10,
                       n_chips=8, train_chips=4,    # T of N chips train
                       pack_rows=3, pack_seq=64),
        trainer=Trainer(cfg, params, rl=RLConfig(entropy_coef=0.003),
                        adam=AdamConfig(lr=1e-3)),
    )
    for rec in pipeline.run():
        print(f"step {rec['version']:3d}  sim_t={rec['time']:8.0f} flashes  "
              f"reward={rec['reward']:+.3f}  ess={rec['ess']:.3f}  "
              f"max_lag={rec['max_lag']:.0f}")
    print(f"\ngenerated {pipeline.engine.tokens_generated} tokens; "
          f"engine is at weight version {pipeline.engine.version}")


if __name__ == "__main__":
    main()
