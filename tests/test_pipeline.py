"""PipelineRL vs Conventional RL orchestration: lag structure (paper Fig 3a),
throughput ordering, end-to-end stepping."""
import jax
import pytest

from repro.configs.tiny import config as tiny_config
from repro.core.conventional import ConventionalConfig, ConventionalRL
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.sim import HardwareModel
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values


@pytest.fixture(scope="module")
def setup():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return task, cfg, params


@pytest.mark.parametrize("n_engines", [1, 2])
def test_pipeline_runs_and_logs(setup, n_engines):
    task, cfg, params = setup
    ec = EngineConfig(n_slots=8, max_len=20)
    pc = PipelineConfig(batch_size=4, n_opt_steps=4, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48, n_engines=n_engines)
    p = PipelineRL(cfg, params, task, ec, pc)
    log = p.run()
    assert len(log) == 4
    assert log[-1]["version"] == 4
    assert log[-1]["time"] > 0
    assert all("ess" in r for r in log)


@pytest.mark.parametrize("n_engines", [1, 2])
def test_pipeline_lag_bounded_and_mixed(setup, n_engines):
    """Fig 3a: PipelineRL batches have a stable, bounded max lag once warm."""
    task, cfg, params = setup
    ec = EngineConfig(n_slots=8, max_len=20)
    pc = PipelineConfig(batch_size=4, n_opt_steps=8, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48, n_engines=n_engines)
    p = PipelineRL(cfg, params, task, ec, pc)
    log = p.run()
    warm = log[3:]
    lags = [r["max_lag"] for r in warm]
    assert max(lags) > 0              # off-policy tokens exist
    assert max(lags) <= 8             # bounded (not growing with steps)
    # mean lag strictly below max lag: mixed-policy structure
    assert all(r["mean_lag"] <= r["max_lag"] for r in warm)


def test_conventional_lag_grows_with_g(setup):
    """Alg. 1: within one RL step, batch g has lag exactly g."""
    task, cfg, params = setup
    ec = EngineConfig(n_slots=8, max_len=20)
    cc = ConventionalConfig(batch_size=4, g_steps=3, n_opt_steps=6,
                            n_chips=8, pack_rows=2, pack_seq=48)
    c = ConventionalRL(cfg, params, task, ec, cc)
    log = c.run()
    for i, r in enumerate(log):
        assert r["max_lag"] == i % 3
        assert r["mean_lag"] == pytest.approx(i % 3)


def test_pipeline_weight_updates_propagate(setup):
    task, cfg, params = setup
    ec = EngineConfig(n_slots=8, max_len=20)
    pc = PipelineConfig(batch_size=4, n_opt_steps=6, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48)
    p = PipelineRL(cfg, params, task, ec, pc)
    p.run()
    assert p.engine.version > 0  # engine received in-flight updates


def test_sim_clock_monotonic(setup):
    task, cfg, params = setup
    ec = EngineConfig(n_slots=8, max_len=20)
    pc = PipelineConfig(batch_size=4, n_opt_steps=5, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48)
    p = PipelineRL(cfg, params, task, ec, pc)
    log = p.run()
    times = [r["time"] for r in log]
    assert times == sorted(times)
