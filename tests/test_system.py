"""End-to-end behaviour tests for the PipelineRL system.

The headline paper claims at CPU scale:
  - PipelineRL learns (reward improves) on the math task
  - its training data stays near on-policy (ESS close to 1)
"""
import jax
import numpy as np
import pytest

from repro.configs.tiny import config as tiny_config
from repro.core.algo import RLConfig
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.sharding import tree_values


@pytest.mark.slow
def test_pipeline_rl_learns():
    task = MathTask(max_operand=3, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=96, n_layers=2)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    ec = EngineConfig(n_slots=16, max_len=16, temperature=1.0)
    pc = PipelineConfig(batch_size=16, n_opt_steps=60, n_chips=8,
                        train_chips=4, pack_rows=4, pack_seq=80)
    trainer = Trainer(cfg, params, rl=RLConfig(entropy_coef=0.003),
                      adam=AdamConfig(lr=3e-3))
    p = PipelineRL(cfg, params, task, ec, pc, trainer=trainer)
    log = p.run()
    first = np.mean([r["reward"] for r in log[:10]])
    last = np.mean([r["reward"] for r in log[-10:]])
    assert last > first + 0.2, f"no learning: {first:.3f} -> {last:.3f}"


def test_ess_stays_high_during_training():
    task = MathTask(max_operand=3, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    ec = EngineConfig(n_slots=8, max_len=16)
    pc = PipelineConfig(batch_size=8, n_opt_steps=8, n_chips=8, train_chips=4,
                        pack_rows=3, pack_seq=64)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    p = PipelineRL(cfg, params, task, ec, pc, trainer=trainer)
    log = p.run()
    # paper Fig 6b: PipelineRL ESS stays near 1 despite nonzero lag
    for r in log[2:]:
        assert r["ess"] > 0.7, r
    assert any(r["max_lag"] > 0 for r in log[2:])
