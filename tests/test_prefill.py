"""Chunked-prefill admission path (DESIGN.md §2).

Equivalence law: admitting a prompt through batched chunked prefill must
land the engine in the same state as the legacy token-at-a-time forcing
loop — identical n_cached, matching cache contents on the valid region,
and (at ~greedy temperature) identical completions. Checked for GQA, MLA,
and hybrid-SSM configs, for chunk sizes that do and do not divide the
prompt length.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.configs.tiny import config as tiny_config
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.data.math_task import MathTask, Problem
from repro.models import model as M
from repro.sharding import tree_values

TASK = MathTask(max_operand=5, ops="+")


def _arch_setup(arch: str):
    if arch == "gqa":
        cfg = tiny_config(vocab_size=TASK.tok.vocab_size, d_model=64,
                          n_layers=2)
    else:
        name = {"mla": "deepseek-v3-671b", "ssm": "mamba2-2.7b",
                "hybrid": "hymba-1.5b"}[arch]
        cfg = dataclasses.replace(smoke_config(get_config(name)),
                                  vocab_size=TASK.tok.vocab_size)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, params


def _list_source(problems):
    it = iter(list(problems))
    return lambda: next(it, None)


def _drain(engine, max_steps=200):
    out = []
    for _ in range(max_steps):
        out.extend(engine.step(TASK))
        if engine.n_active == 0:
            break
    return out


def _pair_engines(cfg, params, chunk, n_slots=4, max_len=16, seed=1):
    """(chunked, legacy) engines over the same prompt stream and PRNG."""
    problems = [TASK.sample() for _ in range(n_slots)]
    ecA = EngineConfig(n_slots=n_slots, max_len=max_len, prefill_chunk=chunk,
                      temperature=1e-4)
    ecB = EngineConfig(n_slots=n_slots, max_len=max_len, prefill_chunk=0,
                      temperature=1e-4)
    eA = GenerationEngine(cfg, params, ecA, _list_source(problems), seed=seed)
    eB = GenerationEngine(cfg, params, ecB, _list_source(problems), seed=seed)
    return eA, eB


@pytest.mark.parametrize("arch", ["gqa", "mla", "ssm", "hybrid"])
@pytest.mark.parametrize("chunk", [4, 16])
def test_prefill_matches_sequential(arch, chunk):
    cfg, params = _arch_setup(arch)
    eA, eB = _pair_engines(cfg, params, chunk)
    assert eA.refill() == 4 and eB.refill() == 4
    # bring the legacy engine to the same point by forcing the prompt
    for _ in range(int(eA._host_prompt_len.max()) - 1):
        eB.step(TASK)
    np.testing.assert_array_equal(eA._host_ncached, eB._host_ncached)
    np.testing.assert_array_equal(np.asarray(eA.state["n_cached"]),
                                  np.asarray(eB.state["n_cached"]))
    # caches must agree on the valid region (bitwise for attention caches,
    # fp32 tolerance for SSD state: chunked scan reorders the reduction)
    for key in eA.state["cache"]:
        a = np.asarray(eA.state["cache"][key], np.float32)
        b = np.asarray(eB.state["cache"][key], np.float32)
        if key in ("conv", "ssd"):
            np.testing.assert_allclose(a, b, atol=1e-5, err_msg=key)
        else:
            for s in range(4):
                n = int(eA._host_ncached[s])
                np.testing.assert_allclose(a[:, s, :n], b[:, s, :n],
                                           atol=1e-5, err_msg=f"{key}[{s}]")
    # ~greedy completions and behavior logprobs must match
    outA = sorted(_drain(eA), key=lambda r: r.slot)
    outB = sorted(_drain(eB), key=lambda r: r.slot)
    assert len(outA) == len(outB) == 4
    for rA, rB in zip(outA, outB):
        np.testing.assert_array_equal(rA.tokens, rB.tokens)
        assert rA.prompt_len == rB.prompt_len
        np.testing.assert_allclose(rA.behavior_logprobs, rB.behavior_logprobs,
                                   atol=1e-5)


def test_prefill_invocation_count():
    """Admission must cost ceil((P-1)/chunk) model calls, not P-1."""
    cfg, params = _arch_setup("gqa")
    pl = 13
    prob = Problem(list(range(1, pl + 1)), 0)
    ec = EngineConfig(n_slots=1, max_len=32, prefill_chunk=4)
    eng = GenerationEngine(cfg, params, ec, _list_source([prob]), seed=0)
    eng.refill()
    assert eng.prefill_chunk_size == 4
    assert eng.prefill_invocations == -(-(pl - 1) // 4)  # ceil(12/4) = 3
    assert eng.prefill_tokens == pl - 1
    assert int(eng._host_ncached[0]) == pl - 1


def test_prefill_mixed_prompt_lengths():
    """Slots with different prompt lengths admitted in one refill must each
    resume at their own pl-1 and produce self-consistent rollouts."""
    cfg, params = _arch_setup("hybrid")
    probs = [Problem(list(range(1, n + 1)), 0) for n in (2, 5, 9, 12)]
    ec = EngineConfig(n_slots=4, max_len=16, prefill_chunk=4,
                      temperature=1e-4)
    eng = GenerationEngine(cfg, params, ec, _list_source(probs), seed=3)
    eng.refill()
    np.testing.assert_array_equal(eng._host_ncached, [1, 4, 8, 11])
    # legacy twin must agree per-slot despite ragged lengths
    ecB = dataclasses.replace(ec, prefill_chunk=0)
    engB = GenerationEngine(cfg, params, ecB, _list_source(probs), seed=3)
    engB.refill()
    for _ in range(11):
        engB.step(TASK)
    outA = sorted(_drain(eng), key=lambda r: r.slot)
    outB = sorted(_drain(engB), key=lambda r: r.slot)
    for rA, rB in zip(outA, outB):
        np.testing.assert_array_equal(rA.tokens, rB.tokens)


def test_refill_under_inflight_update_stamps_new_version():
    """Slots admitted after an in-flight weight update must sample every
    completion token under the NEW version — and prompt positions must
    never carry a behavior version (satellite: stamping is masked to
    sampled tokens)."""
    cfg, params = _arch_setup("gqa")
    params2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(7)))
    for chunk in (8, 0):
        probs = [TASK.sample() for _ in range(8)]
        ec = EngineConfig(n_slots=2, max_len=16, prefill_chunk=chunk)
        eng = GenerationEngine(cfg, params, ec, _list_source(probs), seed=5)
        eng.refill()
        for _ in range(3):
            eng.step(TASK)
        eng.set_weights(params2, version=5)   # in-flight update
        done = []
        for _ in range(120):                  # continuous batching: slots
            done.extend(eng.step(TASK))       # retire and refill mid-run
            eng.refill()
            if len(done) >= 4:
                break
        assert len(done) >= 4
        late = [r for r in done if r.weight_versions.max() == 5]
        assert late, "some rollout must carry the new version"
        for r in done:
            # prompt tokens never stamped with a behavior version
            assert (r.weight_versions[:r.prompt_len] == 0).all()
        # rollouts from slots admitted after the swap: every sampled token
        # must carry the new version
        for r in done[2:]:
            assert (r.weight_versions[r.prompt_len:] == 5).all()


def test_prefill_does_not_disturb_inflight_slots():
    """Admitting into a free slot must not alter the cache/logprobs of a
    sequence already in progress in another slot."""
    cfg, params = _arch_setup("gqa")
    long_prob = Problem(list(range(1, 11)), 0)
    # engine A: slot 0 admitted alone, stepped 4 times, then slot 1 refills
    # refill #1 consumes (long_prob, None): slot 0 admitted, slot 1 declined;
    # refill #2 consumes the final prompt for slot 1
    src = _list_source([long_prob, None, TASK.sample()])
    ec = EngineConfig(n_slots=2, max_len=32, prefill_chunk=8,
                      temperature=1e-4)
    eng = GenerationEngine(cfg, params, ec, src, seed=9)
    eng.refill()          # admits slot 0 only (source declines slot 1)
    assert eng.n_active == 1
    for _ in range(4):
        eng.step(TASK)
    k_before = np.asarray(eng.state["cache"]["k"])[:, 0].copy()
    n0 = int(eng._host_ncached[0])
    eng.refill()          # admits slot 1, chunked prefill runs
    assert eng.n_active == 2
    k_after = np.asarray(eng.state["cache"]["k"])[:, 0]
    np.testing.assert_array_equal(k_before[:, :n0], k_after[:, :n0])


def _ring_cfg(arch, window=8):
    """Sliding-window variant: the engine allocates a CL=window ring cache
    for attention archs (MLA keeps its cheap full-length latent cache)."""
    cfg, params = _arch_setup(arch)
    cfg = dataclasses.replace(cfg, attention_variant="sliding_window",
                              sliding_window=window)
    return cfg, params


def _synthetic_probs(lens):
    return [Problem([3 + (i + j) % 16 for j in range(n)], 0)
            for i, n in enumerate(lens)]


@pytest.mark.parametrize("arch", ["gqa", "mla", "ssm", "hybrid"])
def test_ring_prefill_matches_sequential(arch):
    """Chunked admission over ring-buffer (sliding-window) caches must
    match the legacy per-token loop — prompts longer than the window wrap
    the ring during prefill. MLA keeps a full-length cache and SSM has
    none; both must still admit chunked under the sliding-window variant."""
    cfg, params = _ring_cfg(arch, window=8)
    # equal lengths so the legacy twin reaches the same point after P-1
    # forcing steps (ragged ring lengths: see the test below); P=22 wraps
    # the CL=8 ring almost three times during prefill
    probs = _synthetic_probs((22, 22, 22, 22))
    ecA = EngineConfig(n_slots=4, max_len=24, prefill_chunk=4,
                       temperature=1e-4)
    ecB = dataclasses.replace(ecA, prefill_chunk=0)
    eA = GenerationEngine(cfg, params, ecA, _list_source(probs), seed=11)
    eB = GenerationEngine(cfg, params, ecB, _list_source(probs), seed=11)
    if arch in ("gqa", "hybrid"):
        key = "k"
        assert eA.state["cache"][key].shape[2] == 8   # a real ring
    elif arch == "mla":
        key = "c_kv"
        assert eA.state["cache"][key].shape[2] == 24  # MLA stays full-length
    # ring caches no longer force the legacy loop
    assert eA.prefill_chunk_size == 4
    assert eA.refill() == 4 and eB.refill() == 4
    for _ in range(int(eA._host_prompt_len.max()) - 1):
        eB.step(TASK)
    np.testing.assert_array_equal(eA._host_ncached, eB._host_ncached)
    for k in eA.state["cache"]:
        a = np.asarray(eA.state["cache"][k], np.float32)
        b = np.asarray(eB.state["cache"][k], np.float32)
        if k in ("conv", "ssd"):
            np.testing.assert_allclose(a, b, atol=1e-5, err_msg=k)
        else:
            CL = a.shape[2]
            for s in range(4):
                m = min(int(eA._host_ncached[s]), CL)  # wrapped => all slots
                np.testing.assert_allclose(a[:, s, :m], b[:, s, :m],
                                           atol=1e-5, err_msg=f"{k}[{s}]")
    outA = sorted(_drain(eA), key=lambda r: r.slot)
    outB = sorted(_drain(eB), key=lambda r: r.slot)
    assert len(outA) == len(outB) == 4
    for rA, rB in zip(outA, outB):
        np.testing.assert_array_equal(rA.tokens, rB.tokens)
        np.testing.assert_allclose(rA.behavior_logprobs, rB.behavior_logprobs,
                                   atol=1e-5)


@pytest.mark.parametrize("arch", ["gqa", "hybrid"])
def test_ring_prefill_ragged_lengths(arch):
    """Ragged prompt lengths over a ring cache: each slot must produce the
    same ~greedy rollout as the legacy loop (some slots wrap, some don't)."""
    cfg, params = _ring_cfg(arch, window=8)
    probs = _synthetic_probs((4, 9, 14, 21))
    ec = EngineConfig(n_slots=4, max_len=24, prefill_chunk=4,
                      temperature=1e-4)
    eng = GenerationEngine(cfg, params, ec, _list_source(probs), seed=13)
    eng.refill()
    np.testing.assert_array_equal(eng._host_ncached, [3, 8, 13, 20])
    ecB = dataclasses.replace(ec, prefill_chunk=0)
    engB = GenerationEngine(cfg, params, ecB, _list_source(probs), seed=13)
    engB.refill()
    outB = []
    for _ in range(20):       # short rows may finish while long rows force
        outB.extend(engB.step(TASK))
    outB.extend(_drain(engB))
    outA = sorted(_drain(eng), key=lambda r: r.slot)
    outB = sorted(outB, key=lambda r: r.slot)
    assert len(outA) == len(outB) == 4
    for rA, rB in zip(outA, outB):
        np.testing.assert_array_equal(rA.tokens, rB.tokens)


def test_ring_prefill_wraparound_chunk():
    """A prompt long enough that prefill chunks straddle the ring boundary:
    chunks at offset >= CL write low slots while their queries' window
    still spans the high slots written by earlier chunks."""
    cfg, params = _ring_cfg("gqa", window=8)
    pl_ = 19
    probs = _synthetic_probs((pl_,))
    ec = EngineConfig(n_slots=1, max_len=32, prefill_chunk=4,
                      temperature=1e-4)
    eng = GenerationEngine(cfg, params, ec, _list_source(probs), seed=2)
    eng.refill()
    assert eng.prefill_chunk_size == 4
    assert eng.prefill_invocations == -(-(pl_ - 1) // 4)  # ceil(18/4) = 5
    assert int(eng._host_ncached[0]) == pl_ - 1
    ecB = dataclasses.replace(ec, prefill_chunk=0)
    engB = GenerationEngine(cfg, params, ecB, _list_source(probs), seed=2)
    engB.refill()
    for _ in range(pl_ - 1):
        engB.step(TASK)
    # the wrapped ring is fully valid: every slot must agree bitwise-ish
    np.testing.assert_allclose(
        np.asarray(eng.state["cache"]["k"], np.float32)[:, 0],
        np.asarray(engB.state["cache"]["k"], np.float32)[:, 0], atol=1e-5)
    outA, outB = _drain(eng), _drain(engB)
    np.testing.assert_array_equal(outA[0].tokens, outB[0].tokens)


# MLA has no ring variant — its cache stays full-length by construction —
# so the (mla, ring) cell is excluded at parametrize time, not skipped
@pytest.mark.parametrize("arch,ring", [("gqa", False), ("gqa", True),
                                       ("mla", False)])
def test_prefill_kernel_in_engine_matches_jnp(arch, ring):
    """use_pallas=True must route chunk attention through the Pallas
    prefill kernel inside a real engine and reproduce the jnp engine's
    completions (MLA has no ring variant: its cache stays full-length)."""
    cfg, params = _ring_cfg(arch, window=8) if ring else _arch_setup(arch)
    probs = _synthetic_probs((5, 13))
    ec = EngineConfig(n_slots=2, max_len=16, prefill_chunk=8,
                      temperature=1e-4)
    eng = GenerationEngine(cfg, params, ec, _list_source(probs), seed=4)
    kcfg = dataclasses.replace(cfg, use_pallas=True)
    engK = GenerationEngine(kcfg, params, ec, _list_source(probs), seed=4)
    from repro.models.attention import _use_prefill_kernel
    CL = eng.state["cache"]["k" if arch == "gqa" else "c_kv"].shape[2]
    assert _use_prefill_kernel(kcfg, engK.prefill_chunk_size, CL)
    eng.refill(), engK.refill()
    for k in eng.state["cache"]:
        np.testing.assert_allclose(
            np.asarray(eng.state["cache"][k], np.float32),
            np.asarray(engK.state["cache"][k], np.float32),
            atol=1e-5, err_msg=k)
    outA = sorted(_drain(eng), key=lambda r: r.slot)
    outB = sorted(_drain(engK), key=lambda r: r.slot)
    assert len(outA) == len(outB) == 2
    for rA, rB in zip(outA, outB):
        np.testing.assert_array_equal(rA.tokens, rB.tokens)


def test_decode_hint_engine_parity():
    """With use_pallas and a 64-multiple cache, the engine threads the
    host-derived kv_len_hint into flash_decode; completions must match the
    jnp engine exactly."""
    cfg, params = _arch_setup("gqa")
    probs = _synthetic_probs((5, 9))
    ec = EngineConfig(n_slots=2, max_len=64, prefill_chunk=16,
                      temperature=1e-4)
    kcfg = dataclasses.replace(cfg, use_pallas=True)
    engK = GenerationEngine(kcfg, params, ec, _list_source(probs), seed=8)
    assert engK._use_decode_hint
    eng = GenerationEngine(cfg, params, ec, _list_source(probs), seed=8)
    assert not eng._use_decode_hint
    eng.refill(), engK.refill()
    outA = sorted(_drain(eng), key=lambda r: r.slot)
    outB = sorted(_drain(engK), key=lambda r: r.slot)
    assert len(outA) == len(outB) == 2
    for rA, rB in zip(outA, outB):
        np.testing.assert_array_equal(rA.tokens, rB.tokens)


def test_ssm_state_after_chunked_refill_matches_fresh_prefill():
    """Chunked admission must leave the SSM state exactly as a from-scratch
    prefill of the new prompt (no leakage from the retired sequence)."""
    cfg, params = _arch_setup("ssm")
    probs = [TASK.sample() for _ in range(4)]
    ec = EngineConfig(n_slots=2, max_len=12, prefill_chunk=4,
                      temperature=1e-4)
    eng = GenerationEngine(cfg, params, ec, _list_source(probs), seed=6)
    eng.refill()
    _drain(eng)
    eng.refill()          # slots now hold prompts 2 and 3, prefilled
    # fresh single-shot engine over the same prompts
    ref = GenerationEngine(cfg, params, ec, _list_source(probs[2:]), seed=6)
    ref.refill()
    np.testing.assert_allclose(
        np.asarray(eng.state["cache"]["ssd"], np.float32),
        np.asarray(ref.state["cache"]["ssd"], np.float32), atol=1e-5)
