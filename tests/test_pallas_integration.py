"""use_pallas=True must match the pure-jnp model bit-for-bit-ish: same
forward logits (train path) and same decode logits, across attention and
SSD architectures."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import model as M
from repro.sharding import tree_values

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-32b", "mamba2-2.7b",
                                  "hymba-1.5b"])
def test_forward_parity(arch):
    cfg = dataclasses.replace(smoke_config(get_config(arch)), ssm_chunk=32)
    params = tree_values(M.init_params(cfg, KEY))
    B, S = 2, 128  # S % 128 == 0 so the flash kernel engages
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = M.forward(params, toks, pos, cfg)
    kcfg = dataclasses.replace(cfg, use_pallas=True)
    out = M.forward(params, toks, pos, kcfg)
    np.testing.assert_allclose(
        np.asarray(out["logits"], np.float32),
        np.asarray(ref["logits"], np.float32), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-2.7b"])
def test_decode_parity(arch):
    cfg = dataclasses.replace(smoke_config(get_config(arch)), use_mtp=False)
    params = tree_values(M.init_params(cfg, KEY))
    B, S = 2, 63
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
    pre = M.forward(params, toks[:, :S], pos[:, :S], cfg, return_cache=True)

    def pad(k, v):  # pad cache to 64 so the decode kernel engages
        if k in ("k", "v"):
            return jnp.pad(v, ((0, 0), (0, 0), (0, 64 - S), (0, 0), (0, 0)))
        return v

    cache = {k: pad(k, v) for k, v in pre["cache"].items()}
    ref = M.decode_step(params, toks[:, S:], pos[:, S:], cache,
                        jnp.int32(S), cfg)
    kcfg = dataclasses.replace(cfg, use_pallas=True)
    out = M.decode_step(params, toks[:, S:], pos[:, S:], cache,
                        jnp.int32(S), kcfg)
    np.testing.assert_allclose(
        np.asarray(out["logits"], np.float32),
        np.asarray(ref["logits"], np.float32), atol=2e-4, rtol=2e-4)
