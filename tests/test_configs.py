"""Config registry: exact assigned specs, analytic param counts vs published
sizes, smoke-variant constraints, spec coverage for all 4 input shapes."""
import jax
import pytest

from repro.configs import (
    ARCH_IDS, SHAPES, all_configs, for_shape, get_config, input_specs,
    smoke_config,
)
from repro.configs.base import input_logical, kv_cache_specs

EXPECTED_PARAMS_B = {
    "qwen3-32b": (30, 35),
    "hymba-1.5b": (1.3, 2.0),
    "phi3-mini-3.8b": (3.5, 4.1),
    "phi-3-vision-4.2b": (3.5, 4.5),
    "granite-moe-1b-a400m": (1.1, 1.6),
    "llama3-8b": (7.5, 8.5),
    "granite-3-2b": (2.2, 3.0),
    "musicgen-medium": (1.4, 2.2),
    "deepseek-v3-671b": (650, 690),
    "mamba2-2.7b": (2.5, 3.0),
}


def test_all_archs_registered():
    assert len(ARCH_IDS) == 10
    assert len(set(ARCH_IDS)) == 10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_match_published(arch):
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_active_params_moe():
    ds = get_config("deepseek-v3-671b")
    active = ds.param_count(active_only=True) / 1e9
    assert 30 <= active <= 45  # DeepSeek-V3: 37B activated
    gm = get_config("granite-moe-1b-a400m")
    assert gm.param_count(active_only=True) < gm.param_count()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_reduced(arch):
    sc = smoke_config(get_config(arch))
    assert sc.n_layers == 2
    assert sc.d_model <= 512
    assert sc.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", tuple(SHAPES))
def test_input_specs_cover_all_shapes(arch, shape):
    cfg = for_shape(get_config(arch), SHAPES[shape])
    specs = input_specs(cfg, SHAPES[shape])
    logical = input_logical(cfg, SHAPES[shape])
    assert set(specs) == set(logical)
    for k, v in specs.items():
        if isinstance(v, dict):
            assert set(v) == set(logical[k])
        else:
            assert len(logical[k]) == len(v.shape)


def test_long_context_uses_ring_buffer():
    cfg = for_shape(get_config("llama3-8b"), SHAPES["long_500k"])
    assert cfg.attention_variant == "sliding_window"
    cache = kv_cache_specs(cfg, 1, SHAPES["long_500k"].seq_len)
    assert cache["k"].shape[2] == cfg.sliding_window  # ring buffer, not 524288


def test_mla_keeps_full_compressed_cache():
    cfg = for_shape(get_config("deepseek-v3-671b"), SHAPES["long_500k"])
    cache = kv_cache_specs(cfg, 1, SHAPES["long_500k"].seq_len)
    assert cache["c_kv"].shape[2] == SHAPES["long_500k"].seq_len


def test_ssm_cache_is_constant_size():
    cfg = get_config("mamba2-2.7b")
    c32 = kv_cache_specs(cfg, 1, 32768)
    c500 = kv_cache_specs(cfg, 1, 524288)
    assert c32["ssd"].shape == c500["ssd"].shape
