"""GRPO-style group-relative baseline (beyond-paper option)."""
import numpy as np
import jax
import pytest

from repro.configs.tiny import config as tiny_config
from repro.core.pipeline import PipelineConfig, PipelineRL, _apply_group_baseline
from repro.core.rollout import EngineConfig
from repro.data.math_task import MathTask
from repro.data.packing import Rollout
from repro.models import model as M
from repro.sharding import tree_values


def _mk(reward, key):
    return Rollout(tokens=np.zeros(4, np.int32), prompt_len=1,
                   behavior_logprobs=np.zeros(4, np.float32), reward=reward,
                   weight_versions=np.zeros(4, np.int32), prompt_key=key)


def test_group_baseline_zero_mean_per_group():
    rollouts = [_mk(1.0, 7), _mk(0.0, 7), _mk(0.5, 9), _mk(0.5, 9)]
    out = _apply_group_baseline(rollouts)
    assert out[0].reward == pytest.approx(0.5)
    assert out[1].reward == pytest.approx(-0.5)
    assert out[2].reward == pytest.approx(0.0)
    assert out[3].reward == pytest.approx(0.0)
    # originals untouched (queue bookkeeping safety)
    assert rollouts[0].reward == 1.0


class RepeatingSampler:
    """Yields each sampled problem `group` times (GRPO group sampling)."""

    def __init__(self, task, group=4):
        self.task, self.group = task, group
        self._left, self._cur = 0, None

    def __call__(self):
        if self._left == 0:
            self._cur = self.task.sample()
            self._left = self.group
        self._left -= 1
        return self._cur


def test_pipeline_runs_with_group_baseline():
    task = MathTask(max_operand=3, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1,
                      use_value_head=False)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    p = PipelineRL(cfg, params, task,
                   EngineConfig(n_slots=8, max_len=16),
                   PipelineConfig(batch_size=8, n_opt_steps=3, n_chips=8,
                                  train_chips=4, pack_rows=3, pack_seq=64,
                                  group_baseline=True))
    p.engine.prompt_source = RepeatingSampler(task, group=4)
    log = p.run()
    assert len(log) == 3
    assert all(np.isfinite(r["loss"]) for r in log)
