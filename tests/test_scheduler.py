"""Pool scheduler (DESIGN.md §7 "Pool scheduling"): heterogeneous
per-engine cost models, timed preemption windows, PoolRouter admission
policies + determinism, long-prompt reject-and-count, and the
preprocessor's length-safe ref-logprob bucketing."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.tiny import config as tiny_config
from repro.core.events import ActorStage, EventLoop, PoolRouter
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.preprocess import PreprocessConfig, Preprocessor
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.sim import HardwareModel
from repro.data.math_task import MathTask, Problem
from repro.data.packing import Rollout
from repro.models import model as M
from repro.sharding import tree_values


@pytest.fixture(scope="module")
def setup():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return task, cfg, params


# ---------------------------------------------------------------------------
# per-engine HardwareModel overrides (heterogeneous pool)
# ---------------------------------------------------------------------------

def test_hardware_model_speed_scaling():
    hw = HardwareModel()
    fast = hw.scaled(2.0)
    assert fast.step_cost(8) == pytest.approx(hw.step_cost(8) / 2.0)
    assert fast.prefill_time(64, 4) == pytest.approx(
        hw.prefill_time(64, 4) / 2.0)
    # trainer fleet and broadcast interconnect are separate hardware
    assert fast.train_time(100, 4) == hw.train_time(100, 4)
    assert fast.broadcast_time(1e5) == hw.broadcast_time(1e5)
    # overrides compose multiplicatively
    assert fast.scaled(2.0).speed == pytest.approx(4.0)


def test_hetero_pool_fast_engine_finishes_more(setup):
    """Throughput ordering: with a 3x/1x chip split the fast engine must
    tick more often, generate more tokens, and pull more prompts."""
    task, cfg, params = setup
    pc = PipelineConfig(batch_size=4, n_opt_steps=4, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48, n_engines=2,
                        engine_speeds=[3.0, 1.0])
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=4, max_len=20), pc)
    log = p.run()
    assert len(log) == 4
    fast, slow = p.engines
    assert fast.tokens_generated > slow.tokens_generated
    rs = p.router_stats()
    assert rs["engines"][0]["assigned"] > rs["engines"][1]["assigned"]
    # both engines contribute — heterogeneity must not starve the slow one
    assert slow.tokens_generated > 0


def test_engine_speeds_length_mismatch_raises(setup):
    task, cfg, params = setup
    pc = PipelineConfig(batch_size=4, n_opt_steps=2, n_engines=2,
                        engine_speeds=[1.0])
    with pytest.raises(ValueError):
        PipelineRL(cfg, params, task, EngineConfig(n_slots=4, max_len=20), pc)


# ---------------------------------------------------------------------------
# timed preemption windows
# ---------------------------------------------------------------------------

def _drive_actor(cfg, params, seed, n_rollouts, preempt=None,
                 publish_at=None, version=5):
    """One ActorStage on its own loop; unit step cost; optional preemption
    window and an atomic publication of the SAME params (so sampling is
    unaffected and only version stamps/timing can differ)."""
    task = MathTask(max_operand=5, ops="+", seed=0)
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=4, max_len=16),
                           task.sample, seed=seed)
    loop = EventLoop()
    got = []
    actor = ActorStage(loop, eng, task=task, name="a",
                       step_cost=lambda h: 1.0,
                       deliver=lambda rs, t: got.extend(rs))
    if preempt is not None:
        actor.preempt(*preempt)
    if publish_at is not None:
        actor.deliver_atomic(publish_at, params, version, pause=0.0)
    actor.start(0.0)
    loop.run(until=lambda: len(got) >= n_rollouts)
    return actor, got[:n_rollouts]


def test_preemption_resume_no_rollout_lost(setup):
    """An engine preempted for [3, 53) must produce exactly the same
    rollouts (tokens, prompt splits, count) as an unpreempted twin — the
    window only shifts its timeline; in-flight slots resume untouched."""
    _, cfg, params = setup
    a, got_a = _drive_actor(cfg, params, seed=11, n_rollouts=8,
                            publish_at=30.0)
    b, got_b = _drive_actor(cfg, params, seed=11, n_rollouts=8,
                            preempt=(3.0, 50.0), publish_at=30.0)
    assert len(got_a) == len(got_b) == 8
    for ra, rb in zip(got_a, got_b):
        assert ra.prompt_len == rb.prompt_len
        np.testing.assert_array_equal(ra.tokens, rb.tokens)
    assert b.preemptions_taken == 1
    assert b.preempt_total == pytest.approx(50.0)
    assert a.preempt_total == 0.0
    # timeline shifted past the window, never rewound
    assert b.time > a.time


def test_preemption_weight_versions_stay_exact(setup):
    """A publication arriving during the window installs at the deferred
    tick: stamps stay exact — 0 before the install, `version` after,
    nondecreasing along every rollout, and the swap did land."""
    _, cfg, params = setup
    b, got = _drive_actor(cfg, params, seed=11, n_rollouts=8,
                          preempt=(3.0, 50.0), publish_at=30.0)
    assert b.engine.version == 5
    assert b.updates_applied == 1
    for r in got:
        vers = r.weight_versions[r.prompt_len:]
        assert set(np.unique(vers)) <= {0, 5}
        assert (np.diff(vers) >= 0).all()
    assert max(r.weight_versions.max() for r in got) == 5


def test_preemption_windows_compose():
    """Chained/overlapping windows defer transitively; expired windows are
    dropped."""
    loop = EventLoop()

    class _Eng:
        n_active = 0
        ec = EngineConfig(n_slots=1, max_len=8)

        def refill(self, now):
            return 0

    a = ActorStage(loop, _Eng(), auto_refill=False, chain=False)
    a.preempt(1.0, 2.0)    # [1, 3)
    a.preempt(3.0, 4.0)    # [3, 7) — abuts: 2.0 must defer to 7.0
    a.preempt(0.0, -1.0)   # non-positive duration: ignored
    assert a._preempt_until(2.0) == pytest.approx(7.0)
    assert a._preempt_until(7.0) is None   # half-open, and windows expired
    assert a._preempt == []


# ---------------------------------------------------------------------------
# PoolRouter policies (unit, scripted source + fake engines)
# ---------------------------------------------------------------------------

class _FakeEngine:
    def __init__(self, n_slots=4, max_len=16, active=0, ncached=1):
        self.ec = EngineConfig(n_slots=n_slots, max_len=max_len)
        self._host_active = np.zeros(n_slots, bool)
        self._host_active[:active] = True
        self._host_ncached = np.full(n_slots, ncached, np.int64)


def _scripted_source(lengths):
    probs = [Problem([1] * n, 0) for n in lengths]

    def source():
        return probs.pop(0) if probs else None

    return source


def test_router_fifo_passthrough_order():
    r = PoolRouter(_scripted_source([3, 10, 5, 8]), policy="fifo")
    r.attach([_FakeEngine(), _FakeEngine()])
    lens = [len(r.request(i % 2).prompt_ids) for i in range(4)]
    assert lens == [3, 10, 5, 8]          # arrival order, untouched
    assert r.request(0) is None           # source exhausted
    st = r.stats()
    assert [e["assigned"] for e in st["engines"]] == [2, 2]


def test_router_length_affinity_routes_long_to_fast():
    r = PoolRouter(_scripted_source([3, 10, 5, 8]),
                   policy="length_affinity", lookahead=4)
    r.attach([_FakeEngine(), _FakeEngine()], speeds=[2.0, 1.0])
    assert len(r.request(0).prompt_ids) == 10   # fast: longest pending
    assert len(r.request(1).prompt_ids) == 3    # slow: shortest pending
    assert len(r.request(0).prompt_ids) == 8
    assert len(r.request(1).prompt_ids) == 5
    st = r.stats()
    assert st["engines"][0]["prompt_tokens"] == 18
    assert st["engines"][1]["prompt_tokens"] == 8


def test_router_shortest_queue_declines_deep_engine():
    # engine 0 is saturated (4 active slots, ~56 outstanding tokens);
    # engine 1 is idle — with the default slack (max_len=16) engine 0's
    # pull is declined, engine 1's granted
    e0 = _FakeEngine(active=4, ncached=1)
    e1 = _FakeEngine(active=0)
    r = PoolRouter(_scripted_source([4, 4, 4]), policy="shortest_queue")
    r.attach([e0, e1])
    assert r.request(0) is None
    assert r.request(1) is not None
    st = r.stats()
    assert st["engines"][0]["declined"] == 1
    assert st["engines"][1]["assigned"] == 1
    # once engine 0 drains, it is granted again
    e0._host_active[:] = False
    assert r.request(0) is not None


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError):
        PoolRouter(lambda: None, policy="round_robin")


def test_router_determinism_under_sim_clock(setup):
    """Two identically-seeded hetero runs with length-affinity routing
    must be bit-identical: same log timeline, same per-engine admission
    counts, same tokens — routing reads only the prompt stream and host
    mirrors, never wall-clock or RNG."""
    task_cls = lambda: MathTask(max_operand=5, ops="+", seed=3)
    _, cfg, params = setup

    def run():
        task = task_cls()
        pc = PipelineConfig(batch_size=4, n_opt_steps=3, n_chips=8,
                            train_chips=4, pack_rows=2, pack_seq=48,
                            n_engines=2, engine_speeds=[2.0, 1.0],
                            router="length_affinity")
        p = PipelineRL(cfg, params, task,
                       EngineConfig(n_slots=4, max_len=20), pc, seed=7)
        log = p.run()
        return p, log

    p1, log1 = run()
    p2, log2 = run()
    assert [r["time"] for r in log1] == [r["time"] for r in log2]
    assert [r["reward"] for r in log1] == [r["reward"] for r in log2]
    assert p1.router_stats() == p2.router_stats()
    assert [e.tokens_generated for e in p1.engines] == \
        [e.tokens_generated for e in p2.engines]


# ---------------------------------------------------------------------------
# long-prompt admission: reject-and-count (satellite bugfix)
# ---------------------------------------------------------------------------

def test_engine_rejects_long_prompt_and_counts(setup):
    _, cfg, params = setup
    seen = []
    probs = [Problem([1] + [3] * 9, 0),      # 10 > max_len-2: rejected
             Problem([1, 3, 4, 5], 0)]       # fits
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=1, max_len=8),
                           lambda: probs.pop(0) if probs else None, seed=0)
    eng.on_prompt_rejected = seen.append
    # the rejected prompt re-offers its slot in the SAME refill: the
    # short prompt behind it is admitted without idling the slot a tick
    assert eng.refill() == 1
    assert eng.prompts_rejected == 1
    assert len(seen) == 1 and len(seen[0].prompt_ids) == 10
    assert eng.prompts_truncated == 0
    # the admitted prompt is the FULL short one, not a clipped long one
    assert int(eng._host_prompt_len[0]) == 4


def test_engine_truncate_policy_is_opt_in(setup):
    _, cfg, params = setup
    probs = [Problem([1] + [3] * 9, 0)]
    eng = GenerationEngine(
        cfg, params, EngineConfig(n_slots=1, max_len=8,
                                  long_prompt="truncate"),
        lambda: probs.pop(0) if probs else None, seed=0)
    assert eng.refill() == 1
    assert eng.prompts_truncated == 1
    assert eng.prompts_rejected == 0
    assert int(eng._host_prompt_len[0]) == 6   # max_len-2 legacy clip


def test_server_rejects_long_request(setup):
    from repro.core.serving import Server
    task, cfg, params = setup
    srv = Server(cfg, params, EngineConfig(n_slots=2, max_len=8))
    rid_long = srv.submit([1] + [3] * 12)
    rid_ok = srv.submit(task.sample().prompt_ids)
    for _ in range(100):
        srv.step()
        if len(srv.done) == 1:
            break
    m = srv.metrics()
    assert m["prompts_rejected"] == 1
    assert m["prompts_truncated"] == 0
    assert len(srv.rejected) == 1
    rej = srv.rejected[0]
    assert rej.rid == rid_long and rej.rejected
    assert rej.finished_at is not None
    # the rejected request is not served, not in flight, not hung
    assert m["served"] == 1 and srv.done[0].rid == rid_ok
    assert m["in_flight"] == 0 and m["waiting"] == 0


def test_server_sjf_admission_prefers_short_prompts(setup):
    from repro.core.serving import Server
    _, cfg, params = setup
    srv = Server(cfg, params, EngineConfig(n_slots=1, max_len=16),
                 admission="sjf")
    rid_long = srv.submit([1] + [3] * 8)     # 9 tokens, submitted first
    rid_short = srv.submit([1, 3, 4])        # 3 tokens
    srv.step()
    # the single slot admitted the SHORT prompt despite FIFO submission
    assert srv.in_flight and list(srv.in_flight) == [rid_short]
    assert [r.rid for r in srv.waiting] == [rid_long]


# ---------------------------------------------------------------------------
# preprocessor length safety (satellite bugfixes)
# ---------------------------------------------------------------------------

def _mk_rollout(rng, length, prompt_len, vocab):
    toks = rng.randint(3, vocab, size=length).astype(np.int32)
    toks[0] = 1
    return Rollout(tokens=toks, prompt_len=prompt_len,
                   behavior_logprobs=rng.randn(length).astype(np.float32)
                   * 0.1,
                   reward=1.0, weight_versions=np.zeros(length, np.int32))


def test_preprocessor_never_clips_rollouts(setup):
    """The jitted ref forward buckets by next-pow2 of the longest rollout
    (bounded by max_len); every rollout gets full-length ref_logprobs and
    token_rewards — the KL tail is never dropped."""
    task, cfg, params = setup
    rng = np.random.RandomState(0)
    pre = Preprocessor(cfg, params, PreprocessConfig(kl_coef=0.1, max_len=64))
    rollouts = [_mk_rollout(rng, L, 3, cfg.vocab_size)
                for L in (5, 11, 16, 23)]
    out = pre.process(rollouts)
    for r in out:
        assert len(r.ref_logprobs) == r.length
        assert len(r.token_rewards) == r.length
        assert (r.token_rewards[:r.prompt_len] == 0).all()
    # pow2 bucketing: 23 -> 32, bounded by the cap
    assert Preprocessor._bucket(23, 64) == 32
    assert Preprocessor._bucket(16, 64) == 16
    assert Preprocessor._bucket(65, 64) == 64


def test_preprocessor_raises_on_overlong_rollout(setup):
    task, cfg, params = setup
    rng = np.random.RandomState(0)
    pre = Preprocessor(cfg, params, PreprocessConfig(kl_coef=0.1, max_len=16))
    with pytest.raises(ValueError, match="exceeds"):
        pre.process([_mk_rollout(rng, 20, 3, cfg.vocab_size)])


def test_fused_ref_logprobs_parity_at_boundary(setup):
    """Fused-vs-unfused ref-logprob parity for rollouts exactly at the
    bucket boundary (length == padded T) and below it: every entry agrees
    including the final position, and entry 0 is the alignment pad."""
    import copy
    task, cfg, params = setup
    rng = np.random.RandomState(1)
    cfg_fused = dataclasses.replace(cfg, fused_loss=True)
    pcfg = PreprocessConfig(kl_coef=0.1, max_len=16)
    rollouts = [_mk_rollout(rng, L, 3, cfg.vocab_size) for L in (16, 9, 16)]
    out_u = Preprocessor(cfg, params, pcfg).process(
        [copy.copy(r) for r in rollouts])
    out_f = Preprocessor(cfg_fused, params, pcfg).process(
        [copy.copy(r) for r in rollouts])
    for a, b in zip(out_u, out_f):
        assert len(a.ref_logprobs) == len(b.ref_logprobs) == a.length
        assert a.ref_logprobs[0] == b.ref_logprobs[0] == 0.0
        np.testing.assert_allclose(a.ref_logprobs, b.ref_logprobs,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(a.token_rewards, b.token_rewards,
                                   rtol=1e-4, atol=1e-5)
        # the final entry is a real logprob, not a duplicate-target score
        assert a.ref_logprobs[-1] != 0.0
