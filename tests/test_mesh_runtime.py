"""Real-mesh distributed runtime (DESIGN.md §11) on forced host devices.

The whole module needs a multi-device backend; CI provides one with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (true multi-device
GSPMD on CPU). On a plain single-device host every test here skips.

Covers the four §11 contracts:
  - vocab-sharded fused_logprob: value AND grads match the single-device
    path, both at the kernel level and through the model's fused loss
    routing across GQA / MLA+MoE families, tied and untied heads
  - executed streamed broadcast: real per-chunk reshard installs are
    bit-identical to atomic `set_weights`, with the integrity gate
    (chunk crc + stream digest) armed on real device buffers
  - sharded engines: decode on a mesh-placed engine is token-identical
    to the single-device engine (GSPMD partitioning is
    semantics-preserving), and the pipeline splits engines onto disjoint
    device subsets
  - co-sim calibrated twin: a recorded real-mesh trace replayed through
    the EventLoop agrees with measurement within tolerance
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.configs.tiny import config as tiny_config
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values

N_DEV = 8
pytestmark = pytest.mark.skipif(
    jax.device_count() < N_DEV,
    reason=f"needs {N_DEV} devices (run under "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={N_DEV})")

KEY = jax.random.PRNGKey(3)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((N_DEV,), ("model",))


def _engine_pair_tasks():
    """Two identically-seeded tasks: `MathTask` derives its prompt stream
    from its own RandomState, so paired engines see identical prompts."""
    return MathTask(max_operand=5, ops="+"), MathTask(max_operand=5, ops="+")


# ---------------------------------------------------------------------------
# vocab-sharded fused loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transpose_head", [False, True])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_sharded_fused_logprob_value_and_grads(mesh, transpose_head,
                                               use_pallas):
    """Kernel-level: the shard_map'd per-shard online-logsumexp combine
    must match the single-device blocked twin on values and on gradients
    to hidden and head, through all three outputs."""
    from repro.kernels.fused_logprob import (fused_logprob_blocked,
                                             fused_logprob_sharded)

    N, D, V = 48, 32, 64
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (N, D), jnp.float32)
    w = jax.random.normal(
        ks[1], (V, D) if transpose_head else (D, V), jnp.float32) * 0.3
    t = jax.random.randint(ks[2], (N,), 0, V)

    def scalar(fn):
        def f(h, w):
            lp, lse, ent = fn(h, w)
            return (lp * 1.3 - 0.7 * lse + 0.11 * ent).sum()
        return f

    v1, g1 = jax.jit(jax.value_and_grad(scalar(
        lambda h, w: fused_logprob_sharded(
            h, w, t, mesh=mesh, transpose_head=transpose_head,
            use_pallas=use_pallas, interpret=use_pallas)),
        argnums=(0, 1)))(h, w)
    v2, g2 = jax.jit(jax.value_and_grad(scalar(
        lambda h, w: fused_logprob_blocked(
            h, w, t, transpose_head=transpose_head)),
        argnums=(0, 1)))(h, w)
    np.testing.assert_allclose(v1, v2, rtol=2e-4, atol=2e-4)
    for a, b, name in zip(g1, g2, ("dhidden", "dhead")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v3-671b",
                                  "granite-moe-1b-a400m"])
@pytest.mark.parametrize("tied", [False, True])
def test_sharded_fused_loss_through_model(mesh, arch, tied):
    """Model-level routing: under `sharding_context` the fused lm-head
    call is vocab-sharded; loss stats and parameter gradients must match
    the single-device run across GQA / MLA / MoE, tied and untied."""
    from repro.shardctx import sharding_context

    cfg = dataclasses.replace(smoke_config(get_config(arch)),
                              tie_embeddings=tied, use_mtp=False,
                              fused_loss=True)
    params = tree_values(M.init_params(cfg, KEY))
    B, S = 2, 16
    ks = jax.random.split(jax.random.fold_in(KEY, 5), 1)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    tgt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)

    def loss(p):
        out = M.forward(p, tokens, positions, cfg, loss_targets=tgt)
        return (out["token_logprobs"] - 0.5 * out["lse"]
                + 0.2 * out["entropy"]).sum(), out

    (v_ref, out_ref), g_ref = jax.value_and_grad(loss, has_aux=True)(params)
    with sharding_context(mesh):
        (v_sh, out_sh), g_sh = jax.jit(
            jax.value_and_grad(loss, has_aux=True))(params)
    np.testing.assert_allclose(float(v_sh), float(v_ref),
                               rtol=2e-4, atol=2e-4)
    for k in ("token_logprobs", "lse", "entropy"):
        np.testing.assert_allclose(np.asarray(out_sh[k]),
                                   np.asarray(out_ref[k]),
                                   rtol=2e-4, atol=2e-4, err_msg=k)
    flat_sh = jax.tree_util.tree_leaves_with_path(g_sh)
    flat_ref = jax.tree_util.tree_leaves(g_ref)
    for (path, a), b in zip(flat_sh, flat_ref):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4,
            err_msg=jax.tree_util.keystr(path))


# ---------------------------------------------------------------------------
# executed streamed broadcast
# ---------------------------------------------------------------------------

def _tiny_engine(mesh, task, seed=1, **kw):
    from repro.core.rollout import EngineConfig, GenerationEngine
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64,
                      n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    ec = EngineConfig(n_slots=4, max_len=20)
    return cfg, params, GenerationEngine(cfg, params, ec, task.sample,
                                         seed=seed, mesh=mesh, **kw)


def test_executed_stream_bitwise_with_integrity_gate(mesh):
    """Streamed install on real device buffers: a corrupt chunk token is
    rejected (no partial state change), the retransmit succeeds, and the
    final params are bit-identical to an atomic `set_weights` of the same
    tree. Every chunk leaves a measured transfer in wexec_log."""
    from repro.core.events import (chunk_spans, chunk_token, span_bytes,
                                   stream_digest)
    from repro.core.rollout import EngineConfig, GenerationEngine

    task_a, task_b = _engine_pair_tasks()
    cfg, params, eng = _tiny_engine(mesh, task_b)
    ec = EngineConfig(n_slots=4, max_len=20)
    ref = GenerationEngine(cfg, params, ec, task_a.sample, seed=1)
    params2 = jax.tree.map(lambda x: x + 0.01, params)

    leaves = jax.tree_util.tree_leaves(params2)
    sizes = span_bytes(leaves, chunk_spans(leaves, 4))
    good = [chunk_token(7, k, sizes[k]) for k in range(len(sizes))]
    eng.begin_weight_stream(params2, 7, n_chunks=4,
                            expect_digest=stream_digest(good))
    done, k = False, 0
    while not done:
        tok = good[min(k, len(good) - 1)]
        if k == 1:    # corrupt one token mid-stream
            assert eng.stream_weight_chunk(token=tok ^ 0x5AD0BAD) is False
            assert eng.wchunks_rejected == 1
        done = eng.stream_weight_chunk(token=tok)
        k += 1
    assert eng.last_stream_installed and eng.version == 7
    chunk_recs = [r for r in eng.wexec_log if r["kind"] == "chunk"]
    assert len(chunk_recs) == 4
    assert all(r["seconds"] > 0 for r in chunk_recs)

    ref.set_weights(params2, 7)
    for a, b in zip(jax.tree_util.tree_leaves(eng.params),
                    jax.tree_util.tree_leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_broadcaster_executor_records_real_transfers(mesh):
    """`WeightBroadcaster(executor=MeshBroadcastExecutor())`: a streamed
    publication executes real per-chunk reshards onto the engine's
    devices, records them, and the installed tree is bitwise right."""
    from repro.core.events import ActorStage, EventLoop, WeightBroadcaster
    from repro.core.sim import HardwareModel
    from repro.launch.meshrt import MeshBroadcastExecutor

    _, task_b = _engine_pair_tasks()
    cfg, params, eng = _tiny_engine(mesh, task_b)
    params2 = jax.tree.map(lambda x: x + 0.01, params)
    loop = EventLoop()
    stage = ActorStage(loop, eng, task=task_b, name="a0")
    bc = WeightBroadcaster(HardwareModel(), [stage], mode="streamed",
                           n_chunks=4, executor=MeshBroadcastExecutor())
    bc.publish(params2, 3, now=0.0)
    stage.start(0.0)
    loop.run(until=lambda: stage.updates_applied >= 1)
    assert eng.version == 3
    assert len(bc.exec_records) == 1
    rec = bc.exec_records[0]
    assert len(rec["per_chunk"]) == 4 and rec["nbytes"] > 0
    assert bc.stats()["executed"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(eng.params),
                    jax.tree_util.tree_leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# sharded engines
# ---------------------------------------------------------------------------

def test_sharded_engine_decode_token_identical(mesh):
    """GSPMD partitioning is semantics-preserving: the mesh-placed engine
    produces exactly the single-device engine's tokens, step by step."""
    from repro.core.rollout import EngineConfig, GenerationEngine

    task_a, task_b = _engine_pair_tasks()
    cfg, params, eng = _tiny_engine(mesh, task_b)
    ec = EngineConfig(n_slots=4, max_len=20)
    ref = GenerationEngine(cfg, params, ec, task_a.sample, seed=1)
    ref.refill()
    eng.refill()
    for i in range(20):
        ref.step(task_a)
        eng.step(task_b)
        np.testing.assert_array_equal(np.asarray(ref.state["tokens"]),
                                      np.asarray(eng.state["tokens"]),
                                      err_msg=f"step {i}")
        if ref.n_active == 0:
            ref.refill()
        if eng.n_active == 0:
            eng.refill()


def test_engine_submeshes_are_disjoint(mesh):
    from repro.launch.mesh import engine_submeshes

    subs = engine_submeshes(mesh, 2)
    assert len(subs) == 2
    d0 = set(subs[0].devices.reshape(-1))
    d1 = set(subs[1].devices.reshape(-1))
    assert len(d0) == len(d1) == N_DEV // 2
    assert not d0 & d1
    with pytest.raises(ValueError):
        engine_submeshes(mesh, 3)    # 8 devices don't split 3 ways


def test_pipeline_on_mesh_end_to_end(mesh):
    """The crown e2e: mesh trainer + engines on disjoint submeshes +
    executed streamed broadcast, and every engine at the trainer's
    version holds bitwise-identical params."""
    from repro.core.pipeline import PipelineConfig, PipelineRL
    from repro.core.rollout import EngineConfig

    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64,
                      n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    ec = EngineConfig(n_slots=4, max_len=20)
    pc = PipelineConfig(batch_size=4, n_opt_steps=3, n_engines=2,
                        pack_rows=4, pack_seq=32, broadcast="streamed",
                        broadcast_chunks=4)
    pipe = PipelineRL(cfg, params, task, ec, pc, mesh=mesh)
    assert pipe.trainer.mesh is mesh
    assert all(e.mesh is not None for e in pipe.engines)
    assert not (set(pipe.actors[0].devices) & set(pipe.actors[1].devices))
    pipe.run()
    st = pipe.broadcast_stats()
    assert pipe.trainer.version >= 3
    assert st["executed"] >= 1
    tp = jax.tree_util.tree_leaves(pipe.trainer.params)
    for e in pipe.engines:
        if e.version == pipe.trainer.version:
            for a, b in zip(jax.tree_util.tree_leaves(e.params), tp):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# co-sim calibrated twin + executed weight-update reshard
# ---------------------------------------------------------------------------

def test_cosim_replay_agrees_with_measurement(mesh):
    """Replaying a recorded real-mesh trace through the EventLoop twin
    must reproduce the measured totals: the sim shares per-tick decode
    costs by construction, so the tolerance bounds its pause/lag
    *accounting* drift."""
    from repro.core.rollout import EngineConfig, GenerationEngine
    from repro.launch.meshrt import record_cosim_trace, replay_trace

    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64,
                      n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    params2 = jax.tree.map(lambda x: x + 0.01, params)
    ec = EngineConfig(n_slots=4, max_len=20)
    eng = GenerationEngine(cfg, params, ec, task.sample, seed=2, mesh=mesh)
    trace = record_cosim_trace(eng, params2, n_ticks=24, publish_every=8,
                               n_chunks=4, task=task)
    rep = replay_trace(trace)
    rel = (abs(rep["sim_total_s"] - rep["measured_total_s"])
           / max(rep["measured_total_s"], 1e-12))
    assert rel < 0.05, rel
    assert rep["updates_sim"] == rep["updates_measured"] == 2
    assert abs(rep["mean_lag_sim"] - rep["mean_lag_measured"]) <= 0.5
    assert rep["sim_pause_per_update"] > 0
    np.testing.assert_allclose(rep["sim_pause_per_update"],
                               rep["measured_pause_per_update"],
                               rtol=0.05)


def test_execute_weight_update_measures_chunks(mesh):
    """The executed trainer→generator reshard: one timed record per
    chunk, chunk bytes summing to the whole tree, and the byte guard
    refusing configs that can't fit."""
    from repro.launch.steps import execute_weight_update

    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64,
                      n_layers=1)
    recs = execute_weight_update(cfg, mesh, n_chunks=4)
    assert len(recs) == 4
    assert all(r["t_exec_s"] > 0 for r in recs)
    ann = M.init_params(cfg, abstract=True)
    total = sum(v.size * v.dtype.itemsize
                for v in jax.tree_util.tree_leaves(tree_values(ann)))
    assert sum(r["nbytes"] for r in recs) == total
    with pytest.raises(ValueError):
        execute_weight_update(cfg, mesh, n_chunks=2, max_bytes=16)
