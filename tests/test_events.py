"""Event-driven orchestrator (DESIGN.md §7): scheduler ordering, actor
pool, streamed weight broadcast exactness + pause accounting, SampleQueue
back-pressure under a trainer stall, fused preprocessor parity, and the
chunked weight-update lowering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.tiny import config as tiny_config
from repro.core.events import EventLoop, chunk_spans, span_bytes, tree_bytes
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.preprocess import PreprocessConfig, Preprocessor
from repro.core.queues import SampleQueue
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.sim import HardwareModel
from repro.data.math_task import MathTask
from repro.data.packing import Rollout
from repro.models import model as M
from repro.sharding import tree_values


@pytest.fixture(scope="module")
def setup():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return task, cfg, params


# ---------------------------------------------------------------------------
# event loop
# ---------------------------------------------------------------------------

def test_event_loop_time_order_and_fifo_ties():
    loop = EventLoop()
    fired = []
    loop.post(3.0, lambda t: fired.append(("c", t)))
    loop.post(1.0, lambda t: fired.append(("a", t)))
    loop.post(1.0, lambda t: fired.append(("b", t)))  # tie: FIFO
    loop.run()
    assert fired == [("a", 1.0), ("b", 1.0), ("c", 3.0)]
    assert loop.now == 3.0


def test_event_loop_clamps_past_and_resumes():
    loop = EventLoop()
    fired = []
    loop.post(5.0, lambda t: loop.post(1.0, lambda u: fired.append(u)))
    loop.run()
    assert fired == [5.0]  # posting into the past clamps to now
    # pending events survive a bounded run (resumability)
    loop.post(7.0, lambda t: fired.append(t))
    loop.run(until=lambda: len(fired) >= 1)
    assert fired == [5.0]
    loop.run()
    assert fired == [5.0, 7.0]


# ---------------------------------------------------------------------------
# chunk plan helpers
# ---------------------------------------------------------------------------

def test_chunk_spans_cover_and_balance():
    leaves = [np.zeros(n, np.float32) for n in (7, 1, 9, 4, 4, 2, 30, 3)]
    for n_chunks in (1, 3, 8, 100):
        spans = chunk_spans(leaves, n_chunks)
        # contiguous, disjoint, complete cover
        assert spans[0][0] == 0 and spans[-1][1] == len(leaves)
        for (a, b), (c, d) in zip(spans, spans[1:]):
            assert b == c and a < b
        assert len(spans) <= n_chunks
        assert sum(span_bytes(leaves, spans)) == tree_bytes(leaves)


# ---------------------------------------------------------------------------
# streamed weight stream on the engine
# ---------------------------------------------------------------------------

def test_weight_stream_swaps_only_on_last_chunk(setup):
    task, cfg, params = setup
    params2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(9)))
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=2, max_len=16),
                           task.sample, seed=0)
    sizes = eng.begin_weight_stream(params2, version=5, n_chunks=4)
    assert len(sizes) >= 2 and sum(sizes) == tree_bytes(params2)
    for _ in range(len(sizes) - 1):
        assert eng.stream_weight_chunk() is False
        assert eng.version == 0            # old mu until the swap
        assert eng.params is params
    assert eng.stream_weight_chunk() is True
    assert eng.version == 5
    # pointer swap delivers the exact published tree
    for a, b in zip(jax.tree.leaves(eng.params), jax.tree.leaves(params2)):
        assert a is b
    assert not eng.stream_active


def test_weight_stream_mid_sequence_versions_exact(setup):
    """Tokens sampled while the stream is in flight stamp the OLD version;
    tokens after the pointer swap stamp the new one (Fig. 3a exactness
    across a non-instant transfer)."""
    task, cfg, params = setup
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=2, max_len=32),
                           task.sample, seed=3)
    eng.refill()
    for _ in range(5):
        eng.step(task)
    eng.begin_weight_stream(params, version=7, n_chunks=3)
    eng.step(task)                 # in-flight: still old version
    eng.stream_weight_chunk()
    eng.step(task)                 # still old (stream unfinished)
    while not eng.stream_weight_chunk():
        pass
    rollouts = []
    for _ in range(100):
        rollouts.extend(eng.step(task))
        if rollouts:
            break
    assert rollouts
    vers = rollouts[0].weight_versions[rollouts[0].prompt_len:]
    assert vers.min() == 0 and vers.max() == 7


def test_slow_broadcast_still_makes_progress(setup):
    """Starvation regression: when broadcast_time exceeds the publish
    interval, the in-flight stream must COMPLETE (newest pending
    publication waits) — the policy keeps updating instead of silently
    running fully off-policy forever."""
    task, cfg, params = setup
    hw = HardwareModel(bcast_bytes_per_flash=10.0)  # transfer >> interval
    pc = PipelineConfig(batch_size=4, n_opt_steps=8, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48, broadcast="streamed")
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=4, max_len=20),
                   pc, hw=hw)
    log = p.run()
    assert p.engine.version > 0          # weights DID update
    st = p.broadcast_stats()
    assert st["engines"][0]["streams_completed"] > 0
    # lag is large (slow interconnect) but finite and logged
    assert all(np.isfinite(r["max_lag"]) for r in log)


def test_preprocess_overlaps_trainer(setup):
    """Fig. 4 contract: the preprocessor must be able to START a batch
    while the trainer is busy (strict alternation = serialized latency,
    the thing this stage exists to avoid)."""
    task, cfg, params = setup
    ref_params = tree_values(M.init_params(cfg, jax.random.PRNGKey(7)))
    # long trainer step + long preprocess so windows are wide
    pre = Preprocessor(cfg, ref_params,
                       PreprocessConfig(kl_coef=0.05, max_len=20, n_chips=1))
    hw = HardwareModel(tau=50.0)
    pc = PipelineConfig(batch_size=4, n_opt_steps=6, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48)
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=8, max_len=20),
                   pc, hw=hw, preprocessor=pre)
    intervals = {"pre": [], "train": []}
    orig_kick = p.pre_stage.kick

    def kick(now):
        busy0 = p.pre_stage.busy
        orig_kick(now)
        if not busy0 and p.pre_stage.busy:
            intervals["pre"].append((now, p.pre_stage.busy_until))
    p.pre_stage.kick = kick
    p.trainer_stage.on_free = kick
    orig_train = p.trainer_stage._train

    def train(rollouts, raw, avail, now, on_done):
        orig_train(rollouts, raw, avail, now, on_done)
        intervals["train"].append((max(now, avail),
                                   p.trainer_stage.free_at))
    p.trainer_stage._train = train
    p.run()
    overlap = any(a < d and c < b
                  for a, b in intervals["pre"]
                  for c, d in intervals["train"])
    assert overlap, (intervals)


def test_atomic_set_weights_supersedes_stream(setup):
    task, cfg, params = setup
    params2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(1)))
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=2, max_len=16),
                           task.sample, seed=0)
    eng.begin_weight_stream(params2, version=3, n_chunks=4)
    eng.stream_weight_chunk()
    eng.set_weights(params2, version=9)
    assert not eng.stream_active
    assert eng.version == 9
    assert eng.stream_weight_chunk() is False  # no-op, stream gone


# ---------------------------------------------------------------------------
# actor pool on the scheduler
# ---------------------------------------------------------------------------

def test_actor_pool_two_engines_runs_and_propagates(setup):
    task, cfg, params = setup
    pc = PipelineConfig(batch_size=4, n_opt_steps=5, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48, n_engines=2)
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=4, max_len=20), pc)
    log = p.run()
    assert len(log) == 5
    assert [r["version"] for r in log] == [1, 2, 3, 4, 5]
    times = [r["time"] for r in log]
    assert times == sorted(times) and times[0] > 0
    # both engines generated and both received in-flight updates
    assert all(e.tokens_generated > 0 for e in p.engines)
    assert all(e.version > 0 for e in p.engines)
    # pool engines share one compiled step function (jit donor)
    assert p.engines[1]._step is p.engines[0]._step
    # lag structure: bounded, mixed-policy
    warm = log[2:]
    assert max(r["max_lag"] for r in warm) > 0
    assert max(r["max_lag"] for r in warm) <= 10
    assert all(r["mean_lag"] <= r["max_lag"] for r in warm)


def test_actor_pool_staggered_arrivals(setup):
    """Sequential unicast: engine 1's publication lands after engine 0's,
    so with a slow interconnect engine 1 applies strictly fewer or equal
    updates at any time — check final versions are <=."""
    task, cfg, params = setup
    hw = HardwareModel(bcast_bytes_per_flash=50.0)  # very slow broadcast
    pc = PipelineConfig(batch_size=4, n_opt_steps=4, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48, n_engines=2,
                        broadcast="streamed")
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=4, max_len=20),
                   pc, hw=hw)
    p.run()
    assert p.engines[1].version <= p.engines[0].version


# ---------------------------------------------------------------------------
# broadcast pause accounting
# ---------------------------------------------------------------------------

def test_streamed_pause_below_atomic(setup):
    task, cfg, params = setup
    stats = {}
    for mode in ("streamed", "atomic", "free"):
        pc = PipelineConfig(batch_size=4, n_opt_steps=4, n_chips=8,
                            train_chips=4, pack_rows=2, pack_seq=48,
                            broadcast=mode)
        hw = HardwareModel(bcast_bytes_per_flash=2e3, bcast_install_flash=1.0)
        p = PipelineRL(cfg, params, task,
                       EngineConfig(n_slots=4, max_len=20), pc, hw=hw)
        log = p.run()
        times = [r["time"] for r in log]
        assert times == sorted(times)
        st = p.broadcast_stats()
        eng = st["engines"][0]
        stats[mode] = eng
        assert st["mode"] == mode
        assert st["published"] >= 1
    assert stats["free"]["pause_total"] == 0.0
    assert stats["atomic"]["pause_per_update"] > 0
    assert stats["streamed"]["updates_applied"] > 0
    assert (stats["streamed"]["pause_per_update"]
            < stats["atomic"]["pause_per_update"])


# ---------------------------------------------------------------------------
# SampleQueue back-pressure (drop-oldest) + trainer stall
# ---------------------------------------------------------------------------

def _mk_rollout(i):
    return Rollout(tokens=np.zeros(4, np.int32), prompt_len=1,
                   behavior_logprobs=np.zeros(4, np.float32), reward=float(i),
                   weight_versions=np.zeros(4, np.int32), prompt_key=i)


def test_sample_queue_drop_oldest_counters():
    q = SampleQueue(maxsize=4)
    q.put([_mk_rollout(i) for i in range(10)])
    assert len(q) == 4
    assert q.total_put == 10
    assert q.dropped == 6
    # intra-put peak: depth hits maxsize+1 while a drop is pending — the
    # watermark must record the overflow, not the post-drop steady state
    assert q.high_watermark == 5
    # drop-OLDEST: the newest 4 survive
    assert [r.prompt_key for r in q.pop(4)] == [6, 7, 8, 9]
    with pytest.raises(ValueError):
        q.pop(1)


def test_trainer_stall_backpressure_bounds_lag(setup):
    """Checkpoint-pause scenario on the scheduler: with a bounded queue the
    drop-oldest policy keeps max lag bounded across the stall; unbounded,
    the stall's backlog shows up as strictly more queued samples."""
    task, cfg, params = setup
    ec = EngineConfig(n_slots=8, max_len=20)

    def run(maxsize):
        pc = PipelineConfig(batch_size=4, n_opt_steps=8, n_chips=8,
                            train_chips=4, pack_rows=2, pack_seq=48,
                            queue_maxsize=maxsize,
                            ckpt_every=3, ckpt_pause=50_000.0)
        p = PipelineRL(cfg, params, task, ec, pc)
        log = p.run()
        return p, log

    p_bounded, log_b = run(maxsize=8)
    p_unbounded, log_u = run(maxsize=None)
    assert p_bounded.trainer_stage.stalls >= 2
    # the stall forced drops on the bounded queue, none on the unbounded
    assert p_bounded.queue.dropped > 0
    assert p_unbounded.queue.dropped == 0
    assert p_bounded.queue.total_put > 0
    # drop-oldest keeps the post-stall batch fresher: the bounded queue's
    # worst-case token lag may not exceed the unbounded run's
    assert (max(r["max_lag"] for r in log_b)
            <= max(r["max_lag"] for r in log_u))
    # queue depth at pop time is visible in the log and larger unbounded
    assert (max(r["queue_depth"] for r in log_u)
            >= max(r["queue_depth"] for r in log_b))


# ---------------------------------------------------------------------------
# overlapped preprocessor stage
# ---------------------------------------------------------------------------

def test_preprocessor_stage_overlaps_and_shapes(setup):
    task, cfg, params = setup
    ref_params = tree_values(M.init_params(cfg, jax.random.PRNGKey(7)))
    pre = Preprocessor(cfg, ref_params,
                       PreprocessConfig(kl_coef=0.05, max_len=20))
    pc = PipelineConfig(batch_size=4, n_opt_steps=4, n_chips=8, train_chips=4,
                        pack_rows=2, pack_seq=48)
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=8, max_len=20),
                   pc, preprocessor=pre)
    log = p.run()
    assert len(log) == 4
    assert p.pre_stage is not None and p.pre_stage.batches >= 4
    assert all(np.isfinite(r["loss"]) for r in log)
    times = [r["time"] for r in log]
    assert times == sorted(times)


# ---------------------------------------------------------------------------
# fused ref_logprobs parity (ROADMAP PR-3 follow-up)
# ---------------------------------------------------------------------------

def test_preprocessor_fused_ref_logprobs_parity(setup):
    task, cfg, params = setup
    ref_params = tree_values(M.init_params(cfg, jax.random.PRNGKey(7)))
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=4, max_len=16),
                           task.sample, seed=2)
    eng.refill()
    rollouts = []
    for _ in range(40):
        rollouts.extend(eng.step(task))
        if eng.n_active == 0:
            break
    assert rollouts
    import copy
    cfg_fused = dataclasses.replace(cfg, fused_loss=True)
    pcfg = PreprocessConfig(kl_coef=0.1, max_len=16)
    out_logits = Preprocessor(cfg, ref_params, pcfg).process(
        [copy.copy(r) for r in rollouts])
    out_fused = Preprocessor(cfg_fused, ref_params, pcfg).process(
        [copy.copy(r) for r in rollouts])
    for a, b in zip(out_logits, out_fused):
        np.testing.assert_allclose(a.ref_logprobs, b.ref_logprobs,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(a.token_rewards, b.token_rewards,
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# chunked weight-update lowering (launch-side twin of the stream)
# ---------------------------------------------------------------------------

def test_lower_weight_update_chunked(setup):
    from jax.sharding import Mesh
    from repro.launch.steps import lower_weight_update
    _, cfg, _ = setup
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    whole = lower_weight_update(cfg, mesh)
    assert whole.name.endswith("weight_update")
    progs = lower_weight_update(cfg, mesh, n_chunks=3)
    assert isinstance(progs, list) and 2 <= len(progs) <= 3
    names = [p.name for p in progs]
    assert len(set(names)) == len(names)
    for p in progs:
        assert p.lowered is not None
