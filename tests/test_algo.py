"""RL algorithm invariants (Eq. 4-6), incl. hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CPU image may lack it
from hypothesis import given, settings, strategies as st

from repro.core.algo import RLConfig, ess, reinforce_loss, token_logprobs


def test_ess_on_policy_is_one():
    w = jnp.ones((4, 16))
    mask = jnp.ones((4, 16))
    assert float(ess(w, mask)) == pytest.approx(1.0, abs=1e-6)


@given(st.lists(st.floats(0.01, 100.0), min_size=2, max_size=64))
@settings(max_examples=50, deadline=None)
def test_ess_bounded_unit_interval(ws):
    w = jnp.asarray(ws)[None]
    mask = jnp.ones_like(w)
    v = float(ess(w, mask))
    assert 0.0 < v <= 1.0 + 1e-6


@given(st.floats(0.01, 100.0))
@settings(max_examples=20, deadline=None)
def test_ess_constant_weights_is_one(c):
    """ESS is scale-invariant: constant weights == on-policy."""
    w = jnp.full((1, 32), c)
    assert float(ess(w, jnp.ones_like(w))) == pytest.approx(1.0, rel=1e-5)


def test_ess_degenerate_single_heavy_weight():
    w = jnp.asarray([[1000.0] + [1e-6] * 31])
    v = float(ess(w, jnp.ones_like(w)))
    assert v < 0.05  # one dominant sample -> ESS ~ 1/N


def test_token_logprobs_alignment():
    """token_logprobs[t] must be the logprob of tokens[t] given prefix."""
    V = 8
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 5, V))
    tokens = jnp.asarray([[3, 1, 4, 1, 5]])
    lp = token_logprobs(logits, tokens)
    full = jax.nn.log_softmax(logits, axis=-1)
    assert float(lp[0, 0]) == 0.0
    for t in range(1, 5):
        assert float(lp[0, t]) == pytest.approx(
            float(full[0, t - 1, tokens[0, t]]), abs=1e-6)


def _fake_batch(key, B=2, S=16, V=11, lag_shift=0.0):
    ks = jax.random.split(key, 4)
    logits = jax.random.normal(ks[0], (B, S, V))
    tokens = jax.random.randint(ks[1], (B, S), 0, V)
    mask = jnp.ones((B, S)).at[:, :4].set(0.0)
    beh = token_logprobs(logits, tokens) + lag_shift
    return logits, {
        "tokens": tokens, "loss_mask": mask,
        "behavior_logprobs": beh,
        "rewards": jnp.ones((B, S)) * 0.5,
    }


def test_reinforce_on_policy_ess_one():
    logits, batch = _fake_batch(jax.random.PRNGKey(1))
    _, m = reinforce_loss(logits, None, batch, RLConfig())
    assert float(m["ess"]) == pytest.approx(1.0, abs=1e-5)
    assert float(m["mean_is_weight"]) == pytest.approx(1.0, abs=1e-5)
    assert float(m["clip_frac"]) == 0.0


def test_reinforce_off_policy_ess_below_one():
    key = jax.random.PRNGKey(2)
    logits, batch = _fake_batch(key)
    noise = 0.5 * jax.random.normal(key, batch["behavior_logprobs"].shape)
    batch["behavior_logprobs"] = batch["behavior_logprobs"] + noise
    _, m = reinforce_loss(logits, None, batch, RLConfig())
    assert float(m["ess"]) < 0.99


@given(st.floats(1.0, 10.0))
@settings(max_examples=10, deadline=None)
def test_is_clamp_bounds_clipfrac(c):
    key = jax.random.PRNGKey(3)
    logits, batch = _fake_batch(key)
    batch["behavior_logprobs"] = batch["behavior_logprobs"] - 5.0  # huge ratios
    _, m = reinforce_loss(logits, None, batch, RLConfig(is_clamp=c))
    assert float(m["clip_frac"]) == pytest.approx(1.0)


def test_value_baseline_reduces_to_advantage():
    logits, batch = _fake_batch(jax.random.PRNGKey(4))
    values = jnp.full(batch["rewards"].shape, 0.5)  # perfect baseline
    loss_v, m_v = reinforce_loss(logits, values, batch, RLConfig(value_coef=0.0))
    # zero advantage everywhere -> zero policy gradient loss
    assert float(m_v["pg_loss"]) == pytest.approx(0.0, abs=1e-6)
