"""Dry-run smoke: one cheap (arch, shape) must lower+compile on the
512-device production mesh, in a subprocess (XLA device count is locked at
first jax init, so the 512-device flag cannot be set in this process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.dryrun
def test_dryrun_single_combo_subprocess(tmp_path):
    out = os.path.join(tmp_path, "dr.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-3-2b", "--shape", "decode_32k",
         "--weight-update", "--wu-chunks", "3", "--out", out],
        env=env, capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.load(open(out))[0]
    assert rec["ok"]
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["mesh"] == "16x16"
    assert rec["t_compute_s"] > 0
    # per-chunk weight-update costs (streamed-broadcast launcher twin):
    # chunk collectives must cover the whole-tree transfer, and the max
    # single-chunk pause must be strictly below the whole-tree pause
    ch = rec["weight_update_chunks"]
    assert 2 <= ch["n_chunks"] <= 3 and len(ch["chunks"]) == ch["n_chunks"]
    whole = rec["weight_update"]["t_collective_s"]
    assert ch["sum_t_collective_s"] == pytest.approx(whole, rel=0.05)
    assert 0 < ch["max_chunk_t_collective_s"] < whole


@pytest.mark.dryrun
def test_dryrun_disaggregated_subprocess():
    """Paper topology: train_step on the trainer submesh + serve_step on the
    generator submesh must both lower."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    script = (
        "import os, json;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
        "from repro.launch.dryrun import run_disaggregated;"
        "r = run_disaggregated('granite-3-2b');"
        "print(json.dumps({'ok': r['ok'], 'err': r.get('error','')}))"
    )
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    assert rec["ok"], rec["err"]
