"""Differential tests: paged KV engine vs the slot-array oracle
(DESIGN.md §9).

The paged engine's default read path gathers each slot's pages into the
contiguous ring view and runs the *unchanged* attention on it, so every
rollout — tokens, behavior logprobs, per-token weight versions — must be
BIT-identical to the slot engine under the same seed and prompt stream:
across architectures (GQA / MLA / SSM / hybrid), Pallas on and off,
ragged prompts, ring (sliding-window) caches, mid-stream in-flight weight
updates, and GRPO prefix sharing. The opt-in paged flash-decode kernel
reassociates the softmax per page, so it is bitwise only when page_size
equals the slot kernel's block size (pinned separately).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.configs.tiny import config as tiny_config
from repro.core.events import EventLoop, PoolRouter
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.serving import Server
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask, Problem
from repro.models import attention as attn
from repro.models import model as M
from repro.sharding import tree_values

TASK = MathTask(max_operand=5, ops="+")


def _arch_setup(arch: str, use_pallas: bool = False):
    if arch == "gqa":
        cfg = tiny_config(vocab_size=TASK.tok.vocab_size, d_model=64,
                          n_layers=2)
    else:
        name = {"mla": "deepseek-v3-671b", "ssm": "mamba2-2.7b",
                "hybrid": "hymba-1.5b"}[arch]
        cfg = dataclasses.replace(smoke_config(get_config(name)),
                                  vocab_size=TASK.tok.vocab_size)
    if use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=True)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, params


def _list_source(problems):
    it = iter(list(problems))
    return lambda: next(it, None)


def _drain(engine, max_steps=300):
    out = []
    for _ in range(max_steps):
        out.extend(engine.step(TASK))
        if engine.n_active == 0:
            break
    return out


def _ragged_probs(lens=(3, 5, 9, 13)):
    return [Problem(list(range(2, 2 + n)), 0) for n in lens]


def _assert_rollouts_bitwise(a_list, b_list, n):
    a_list = sorted(a_list, key=lambda r: r.slot)
    b_list = sorted(b_list, key=lambda r: r.slot)
    assert len(a_list) == len(b_list) == n
    for a, b in zip(a_list, b_list):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.prompt_len == b.prompt_len
        np.testing.assert_array_equal(a.behavior_logprobs,
                                      b.behavior_logprobs)
        np.testing.assert_array_equal(a.weight_versions, b.weight_versions)


def _paged_done(engine):
    """Post-drain paged-engine hygiene: every page back in the pool and
    the table/allocator cross-checks clean."""
    if engine.allocator is not None:
        assert engine.allocator.live_pages == 0
        engine.tables.check()


# ---------------------------------------------------------------------------
# bit-identity across architectures, ragged prompts, in-flight update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True], ids=["jnp", "pallas"])
@pytest.mark.parametrize("arch", ["gqa", "mla", "ssm", "hybrid"])
def test_paged_bitwise_equals_slots(arch, use_pallas):
    """Ragged prompts + a mid-stream atomic weight update: the paged
    engine must replay the slot engine bit-for-bit, including the
    per-token weight-version stamps."""
    cfg, params = _arch_setup(arch, use_pallas)
    p2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(7)))
    probs = _ragged_probs()
    ec = EngineConfig(n_slots=4, max_len=16, prefill_chunk=4,
                      temperature=1e-4)
    eS = GenerationEngine(cfg, params, ec, _list_source(probs), seed=2)
    eP = GenerationEngine(cfg, params,
                          dataclasses.replace(ec, cache="paged", page_size=4),
                          _list_source(probs), seed=2)
    assert eS.refill() == 4 and eP.refill() == 4
    outS, outP = [], []
    for i in range(300):
        if i == 3:   # in-flight update over live, partially-shared caches
            eS.set_weights(p2, 1)
            eP.set_weights(p2, 1)
        outS.extend(eS.step(TASK))
        outP.extend(eP.step(TASK))
        if eS.n_active == 0 and eP.n_active == 0:
            break
    _assert_rollouts_bitwise(outS, outP, 4)
    _paged_done(eP)


@pytest.mark.parametrize("arch", ["gqa", "hybrid"])
def test_paged_ring_cache_bitwise(arch):
    """Sliding-window (ring) caches page like everything else: block j
    holds ring positions [j*PS, (j+1)*PS) and decode wraps through the
    same table."""
    cfg, params = _arch_setup(arch)
    cfg = dataclasses.replace(cfg, sliding_window=8)
    probs = _ragged_probs((4, 6, 11, 13))
    ec = EngineConfig(n_slots=4, max_len=16, prefill_chunk=4,
                      temperature=1e-4)
    eS = GenerationEngine(cfg, params, ec, _list_source(probs), seed=3)
    eP = GenerationEngine(cfg, params,
                          dataclasses.replace(ec, cache="paged", page_size=4),
                          _list_source(probs), seed=3)
    assert eS.refill() == 4 and eP.refill() == 4
    _assert_rollouts_bitwise(_drain(eS), _drain(eP), 4)
    _paged_done(eP)


def test_paged_streamed_update_bitwise():
    """The chunked weight stream (DESIGN.md §7) interleaves with decode;
    version stamps must stay exact on the paged engine too."""
    cfg, params = _arch_setup("gqa")
    p2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(9)))
    probs = _ragged_probs()
    ec = EngineConfig(n_slots=4, max_len=16, prefill_chunk=4,
                      temperature=1e-4)
    engines = []
    for cache in ("slots", "paged"):
        e = GenerationEngine(
            cfg, params,
            dataclasses.replace(ec, cache=cache, page_size=4),
            _list_source(probs), seed=6)
        e.refill()
        e.begin_weight_stream(p2, 1, n_chunks=4)
        engines.append(e)
    outs = [[], []]
    for _ in range(300):
        for e, out in zip(engines, outs):
            e.stream_weight_chunk()
            out.extend(e.step(TASK))
        if all(e.n_active == 0 for e in engines):
            break
    _assert_rollouts_bitwise(outs[0], outs[1], 4)
    _paged_done(engines[1])


@pytest.mark.parametrize("rec", [False, True], ids=["stale", "recompute"])
def test_paged_recompute_kv_bitwise(rec):
    """§5.1 ablation on pages: recompute-under-new-weights scatters the
    ring view back through the block table (after unsharing every COW
    block) and must match the slot engine's recompute exactly."""
    cfg, params = _arch_setup("gqa")
    p2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(11)))
    probs = _ragged_probs()
    ec = EngineConfig(n_slots=4, max_len=16, prefill_chunk=4,
                      temperature=1e-4)
    eS = GenerationEngine(cfg, params, ec, _list_source(probs), seed=4)
    eP = GenerationEngine(cfg, params,
                          dataclasses.replace(ec, cache="paged", page_size=4),
                          _list_source(probs), seed=4)
    eS.refill(), eP.refill()
    outS, outP = [], []
    for i in range(300):
        if i == 3:
            eS.set_weights(p2, 1, recompute_kv=rec)
            eP.set_weights(p2, 1, recompute_kv=rec)
        outS.extend(eS.step(TASK))
        outP.extend(eP.step(TASK))
        if eS.n_active == 0 and eP.n_active == 0:
            break
    _assert_rollouts_bitwise(outS, outP, 4)
    _paged_done(eP)


# ---------------------------------------------------------------------------
# GRPO prefix sharing: prefill-once + COW forks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gqa", "hybrid", "ssm"])
def test_prefix_sharing_prefills_once_and_stays_bitwise(arch):
    """A G-way group of identical prompts: exactly ONE prefill pass runs
    (counters prove it), the forks share pages copy-on-write, and the
    G rollouts are bit-identical to the slot engine's. Scoped to non-MoE
    archs: capacity-limited MoE dispatch couples batch rows, so leader-
    only prefill takes a different expert route than all-rows prefill."""
    cfg, params = _arch_setup(arch)
    G, pl = 4, 6   # P-1 = 5 splits mid-page for PS=4 -> COW at divergence
    group = [Problem(list(range(3, 3 + pl)), 0) for _ in range(G)]
    ec = EngineConfig(n_slots=G, max_len=16, prefill_chunk=4,
                      temperature=1e-4)
    eS = GenerationEngine(cfg, params, ec, _list_source(group), seed=5)
    eP = GenerationEngine(cfg, params,
                          dataclasses.replace(ec, cache="paged", page_size=4),
                          _list_source(group), seed=5)
    assert eS.refill() == G and eP.refill() == G
    if eP._paged:
        # the whole point: the group's prompt was prefilled exactly once
        assert eP.prompt_prefills == 1
        assert eP.prefix_forks == G - 1
        assert eP.last_admit_prefill_tokens == pl - 1
        assert eS.last_admit_prefill_tokens == G * (pl - 1)
    _assert_rollouts_bitwise(_drain(eS), _drain(eP), G)
    if eP._paged:
        assert eP.pages_copied >= G - 1   # COW actually fired mid-page
    _paged_done(eP)


def test_prefix_sharing_off_prefills_everything():
    cfg, params = _arch_setup("gqa")
    group = [Problem([3, 4, 5, 6, 7, 8], 0) for _ in range(4)]
    ec = EngineConfig(n_slots=4, max_len=16, prefill_chunk=4,
                      cache="paged", page_size=4, prefix_sharing=False,
                      temperature=1e-4)
    e = GenerationEngine(cfg, params, ec, _list_source(group), seed=5)
    assert e.refill() == 4
    assert e.prompt_prefills == 4 and e.prefix_forks == 0


# ---------------------------------------------------------------------------
# the opt-in paged flash-decode kernel
# ---------------------------------------------------------------------------

def test_paged_kernel_bitwise_when_page_equals_block():
    """flash_decode_paged == flash_decode on the gathered view, bitwise,
    when page_size == the slot kernel's block size (same softmax block
    reassociation); the engine-level run must then also be bitwise."""
    from repro.kernels import ops as kops
    from repro.kernels.paged_cache import gather_pages
    rng = np.random.default_rng(0)
    B, H, KV, D, NB = 3, 4, 2, 8, 4
    CL = NB * 4
    blk = attn.decode_block_k(CL)
    PS = blk            # the bitwise-equality condition
    NBe = CL // PS
    n_pages = B * NBe + 1
    pool_k = rng.standard_normal((n_pages, PS, KV, D)).astype(np.float32)
    pool_v = rng.standard_normal((n_pages, PS, KV, D)).astype(np.float32)
    bt = np.arange(1, n_pages).reshape(B, NBe).astype(np.int32)
    lengths = np.array([CL, 5, 9], np.int32)
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    kg = gather_pages(pool_k, bt)
    vg = gather_pages(pool_v, bt)
    ref = kops.flash_decode(q, kg, vg, lengths, scale=0.5, block_k=blk)
    out = kops.flash_decode_paged(q, pool_k, pool_v, bt, lengths, scale=0.5)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    # and with the grid-shrinking length hint
    out_h = kops.flash_decode_paged(q, pool_k, pool_v, bt, lengths,
                                    scale=0.5, max_len_hint=CL)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_h))


def test_paged_kernel_engine_matches_gather_engine():
    """EngineConfig.paged_attention="kernel" routes decode through the
    scalar-prefetch kernel; tokens match the gather engine at ~greedy
    temperature and logprobs agree to fp32 tolerance."""
    cfg, params = _arch_setup("gqa")
    probs = _ragged_probs()
    ec = EngineConfig(n_slots=4, max_len=16, prefill_chunk=4,
                      cache="paged", page_size=4, temperature=1e-4)
    eG = GenerationEngine(cfg, params, ec, _list_source(probs), seed=2)
    eK = GenerationEngine(cfg, params,
                          dataclasses.replace(ec, paged_attention="kernel"),
                          _list_source(probs), seed=2)
    assert eG.refill() == 4 and eK.refill() == 4
    outG = sorted(_drain(eG), key=lambda r: r.slot)
    outK = sorted(_drain(eK), key=lambda r: r.slot)
    assert len(outG) == len(outK) == 4
    for a, b in zip(outG, outK):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.behavior_logprobs, b.behavior_logprobs,
                                   atol=1e-5)
    _paged_done(eK)


# ---------------------------------------------------------------------------
# page-costed admission, eviction, crash hygiene
# ---------------------------------------------------------------------------

def test_can_admit_and_page_costing():
    cfg, params = _arch_setup("gqa")
    # two DISTINCT 13-token prompts (identical ones would fork for free);
    # cl=16, ps=4 -> 4 blocks/slot; 5 usable pages back one 13-token
    # prompt (4 blocks) but not a second
    probs = [Problem(list(range(2, 15)), 0), Problem(list(range(3, 16)), 0)]
    ec = EngineConfig(n_slots=2, max_len=16, prefill_chunk=4,
                      cache="paged", page_size=4, n_pages=6)
    e = GenerationEngine(cfg, params, ec, _list_source(probs), seed=1)
    assert e.pages_needed(13) == 4
    assert e.can_admit(13)
    assert e.refill() == 1          # second prompt deferred: no pages
    assert len(e._deferred) == 1
    assert not e.can_admit(13)
    assert e.last_admit_pages >= 3  # prefill blocks charged to the refill
    # slot engines cost 0 pages and admit on free slots alone
    eS = GenerationEngine(cfg, params,
                          dataclasses.replace(ec, cache="slots"),
                          _list_source(_ragged_probs((13, 13))), seed=1)
    assert eS.pages_needed(13) == 0 and eS.can_admit(13)
    assert eS.refill() == 2


def test_eviction_under_page_pressure_loses_nothing():
    """A pool far too small for the slot count: admission defers, decode
    preempts the least-progressed slot on page exhaustion, and every
    prompt still completes exactly once — with zero leaked pages."""
    cfg, params = _arch_setup("gqa")
    probs = [TASK.sample() for _ in range(8)]
    ec = EngineConfig(n_slots=4, max_len=16, prefill_chunk=4,
                      cache="paged", page_size=4, n_pages=7,
                      temperature=1e-4)
    e = GenerationEngine(cfg, params, ec, _list_source(probs), seed=5)
    done = []
    for _ in range(400):
        e.refill()
        done.extend(e.step(TASK))
        if e.n_active == 0 and not e._deferred:
            break
    assert len(done) == 8
    assert e.slots_preempted > 0
    _paged_done(e)


def test_reset_slots_releases_shared_pages():
    """Engine kill mid-group: every page reference — including the COW-
    shared prefix, whose refcount drops once per holding fork — returns
    to the pool, and the deferred queue is salvageable first."""
    cfg, params = _arch_setup("gqa")
    group = [Problem([3, 4, 5, 6, 7, 8], 0) for _ in range(4)]
    ec = EngineConfig(n_slots=2, max_len=16, prefill_chunk=4,
                      cache="paged", page_size=4, temperature=1e-4)
    e = GenerationEngine(cfg, params, ec, _list_source(group), seed=1)
    assert e.refill() == 2
    e.step(TASK)
    e._deferred.append(Problem([9, 9], 0))
    assert e.allocator.live_pages > 0
    salvaged = e.drain_deferred()
    assert [p.prompt_ids for p in salvaged] == [[9, 9]]
    lost = e.reset_slots()          # asserts zero leaked pages internally
    assert lost == 2
    assert e.allocator.live_pages == 0
    e.tables.check()
    # the table rows pushed to device are all trash-page zeros
    assert int(np.asarray(e._bt_jax).sum()) == 0


def test_engine_crash_under_faultplan_leaks_no_pages():
    """Fault-injection end to end: a paged engine crashed by the
    FaultPlan mid-decode salvages its prompts (live slots AND page-
    deferred ones) into the router, the pool re-admits them on the
    survivor, and the dead engine holds zero pages."""
    from repro.core.events import FaultPlan
    from repro.core.sim import HardwareModel
    task = TASK
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    ec = EngineConfig(n_slots=8, max_len=16, cache="paged", page_size=4)
    pc = PipelineConfig(batch_size=4, n_opt_steps=4, n_chips=8,
                        train_chips=4, pack_rows=2, pack_seq=48, n_engines=2)
    hw = HardwareModel(h_sat=16, bcast_bytes_per_flash=2e3)
    plan = FaultPlan().engine_crash(at=120.0, engine=1)   # permanent
    p = PipelineRL(cfg, params, task, ec, pc, hw=hw,
                   trainer=Trainer(cfg, params), seed=0, fault_plan=plan)
    p.run()
    ps = p.pool_stats()
    victim = ps["engines"][1]
    assert victim["failures"] == 1 and not victim["alive"]
    assert ps["prompts_salvaged"] > 0
    assert ps["prompts_requeued"] == ps["prompts_salvaged"]
    dead = p.engines[1]
    assert dead.allocator.live_pages == 0
    dead.tables.check()
    # the survivor drained the run; its pages net out to its live slots
    live = p.engines[0]
    held = sum(len(live.tables.owned_pages(s))
               for s in range(ec.n_slots))
    assert live.allocator.live_pages == held
    live.tables.check()


def test_router_declines_pull_when_pages_short():
    cfg, params = _arch_setup("gqa")
    ec = EngineConfig(n_slots=2, max_len=16, prefill_chunk=4,
                      cache="paged", page_size=4, n_pages=6)
    probs = [Problem(list(range(2, 15)), 0), Problem(list(range(3, 16)), 0)]
    router = PoolRouter(_list_source(probs))
    e = GenerationEngine(cfg, params, ec, None, seed=1)
    i = router.add_engine(e)
    e.prompt_source = router.source_for(i)
    assert e.refill() == 1          # first prompt takes all 4 blocks
    assert e.refill() == 0          # router declines: prompt stays pooled
    assert router.declined[i] >= 1
    assert len(router.pending) == 1
    assert len(e._deferred) == 0    # never parked inside the full engine


def test_server_defers_admission_until_pages_free():
    """Serving admission gate: with a pool that backs one request at a
    time, the second request WAITS (counted) instead of failing, and is
    served once the first completes."""
    cfg, params = _arch_setup("gqa")
    ec = EngineConfig(n_slots=2, max_len=16, prefill_chunk=4,
                      cache="paged", page_size=4, n_pages=6,
                      temperature=1e-4)
    srv = Server(cfg, params, ec, seed=0)
    srv.submit(list(range(2, 15)))      # 13 tokens -> all 4 usable pages
    srv.submit(list(range(2, 15)))
    served = []
    for _ in range(120):
        served += srv.step(1.0)
        if len(served) == 2:
            break
    m = srv.metrics()
    assert len(served) == 2
    assert m["admissions_deferred"] > 0
    assert m["requests_lost"] == 0
    assert srv.engine.allocator.live_pages == 0
