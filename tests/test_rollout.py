"""Generation engine: continuous batching, in-flight updates, lag records."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.configs.tiny import config as tiny_config
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values


@pytest.fixture(scope="module")
def setup():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return task, cfg, params


def _drain(engine, task, max_steps=200):
    out = []
    for _ in range(max_steps):
        out.extend(engine.step(task))
        if engine.n_active == 0:
            break
    return out


def test_engine_generates_and_finishes(setup):
    task, cfg, params = setup
    ec = EngineConfig(n_slots=4, max_len=20)
    eng = GenerationEngine(cfg, params, ec, task.sample, seed=1)
    eng.refill()
    rollouts = _drain(eng, task)
    assert len(rollouts) == 4
    for r in rollouts:
        assert r.prompt_len < r.length <= ec.max_len
        # prompt tokens must be the problem's prompt
        prob_len = r.prompt_len
        assert (r.behavior_logprobs[:prob_len] == 0).all()
        assert (r.behavior_logprobs[prob_len:] <= 0).all()


def test_engine_continuous_batching_refills(setup):
    task, cfg, params = setup
    ec = EngineConfig(n_slots=4, max_len=16)
    eng = GenerationEngine(cfg, params, ec, task.sample, seed=2)
    eng.refill()
    total = []
    for _ in range(60):
        total.extend(eng.step(task))
        eng.refill()
        assert eng.n_active == 4  # slots always full (in-flight admission)
    assert len(total) >= 8


def test_inflight_update_versions_tokens(setup):
    task, cfg, params = setup
    ec = EngineConfig(n_slots=2, max_len=32)
    eng = GenerationEngine(cfg, params, ec, task.sample, seed=3)
    eng.refill()
    for _ in range(5):
        eng.step(task)
    eng.set_weights(params, version=7)  # in-flight update mid-sequence
    rollouts = []
    for _ in range(100):
        rollouts.extend(eng.step(task))
        if rollouts:
            break
    assert rollouts
    r = rollouts[0]
    vers = r.weight_versions[r.prompt_len:]
    # mixed-policy sequence: early tokens v0, later tokens v7 (Fig. 3a)
    assert vers.min() == 0 and vers.max() == 7


def test_inflight_update_changes_distribution(setup):
    """After an in-flight update the engine must sample under NEW weights."""
    task, cfg, params = setup
    params2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(99)))
    ec = EngineConfig(n_slots=2, max_len=24, temperature=1e-4)  # ~greedy
    e1 = GenerationEngine(cfg, params, ec, task.sample, seed=4)
    e2 = GenerationEngine(cfg, params, ec, task.sample, seed=4)
    e1.refill(); e2.refill()
    for _ in range(3):
        e1.step(task); e2.step(task)
    e2.set_weights(params2, version=1)
    diverged = False
    for _ in range(10):
        e1.step(task); e2.step(task)
        t1 = np.asarray(e1.state["tokens"])
        t2 = np.asarray(e2.state["tokens"])
        if not np.array_equal(t1, t2):
            diverged = True
            break
    assert diverged


def test_recompute_kv_matches_fresh_prefill(setup):
    """§5.1 ablation path: cache recompute under new weights must equal a
    from-scratch prefill of the same tokens."""
    task, cfg, params = setup
    ec = EngineConfig(n_slots=2, max_len=16)
    eng = GenerationEngine(cfg, params, ec, task.sample, seed=5)
    eng.refill()
    for _ in range(4):
        eng.step(task)
    params2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(42)))
    eng.set_weights(params2, version=1, recompute_kv=True)
    st = eng.state
    toks = st["tokens"]
    H, T = toks.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (H, T))
    fresh = M.forward(params2, toks, pos, cfg, return_cache=True)["cache"]
    np.testing.assert_allclose(np.asarray(st["cache"]["k"], np.float32),
                               np.asarray(fresh["k"], np.float32),
                               atol=1e-5)


def test_ssm_state_reset_on_refill():
    task = MathTask(max_operand=5, ops="+")
    big = smoke_config(get_config("mamba2-2.7b"))
    cfg = dataclasses.replace(big, vocab_size=task.tok.vocab_size)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    # legacy admission path: state must be zero right after refill (the
    # chunked path immediately prefills the new prompt into the state —
    # covered by test_prefill.py)
    ec = EngineConfig(n_slots=2, max_len=12, prefill_chunk=0)
    eng = GenerationEngine(cfg, params, ec, task.sample, seed=6)
    eng.refill()
    _drain(eng, task)
    assert float(jnp.abs(eng.state["cache"]["ssd"]).max()) > 0
    eng.refill()
    assert float(jnp.abs(eng.state["cache"]["ssd"]).max()) == 0.0
