"""Property-based tests for the paged-KV block allocator (DESIGN.md §9).

Random interleavings of the four table mutations (admit / fork / write /
release) must preserve the allocator's conservation laws:

  * page conservation: free + live == n_pages - 1 (trash page excluded),
    no page both free and referenced, no duplicate in the free list;
  * refcounts match the live forks: every page's refcount equals the
    number of block-table entries referencing it;
  * no double free: releasing a row twice is a no-op on the second pass
    (entries were zeroed), and the allocator raises on a stray release;
  * COW never mutates a shared page: after `ensure_writable` the written
    entry's page has refcount exactly 1, and a former co-owner's page
    survives with its remaining references;
  * determinism: the same op sequence on a fresh allocator reproduces
    bit-identical tables, refcounts, and free lists (LIFO reuse).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need it; CPU image may lack it
from hypothesis import given, settings, strategies as st

from repro.kernels.paged_cache import (BlockTables, OutOfPages, PageAllocator,
                                       TRASH_PAGE)

N_SLOTS, N_BLOCKS, PAGE_SIZE = 4, 4, 8


def _op_strategy():
    slot = st.integers(0, N_SLOTS - 1)
    return st.one_of(
        st.tuples(st.just("admit"), slot, st.integers(0, N_BLOCKS)),
        st.tuples(st.just("fork"), slot, slot),
        st.tuples(st.just("write"), slot, st.integers(0, N_BLOCKS - 1)),
        st.tuples(st.just("release"), slot, st.just(0)),
    )


def _apply(tables: BlockTables, op) -> None:
    """One admission-machinery op; OutOfPages is a legal outcome whose
    rollback contract is asserted in place."""
    alloc = tables.alloc
    kind, a, b = op
    if kind == "admit":
        tables.release_row(a)
        free0, table0 = alloc.free_pages, tables.table.copy()
        try:
            n = tables.alloc_prefix(a, b)
            assert n == b
            assert alloc.free_pages == free0 - b
        except OutOfPages:
            # rollback: allocator and table bit-identical to before
            assert alloc.free_pages == free0
            np.testing.assert_array_equal(tables.table, table0)
    elif kind == "fork":
        if a == b:
            return
        tables.release_row(a)
        shared = tables.fork_row(a, b)
        assert shared == len(tables.owned_pages(b))
        np.testing.assert_array_equal(tables.table[a] != TRASH_PAGE,
                                      tables.table[b] != TRASH_PAGE)
    elif kind == "write":
        rc0 = alloc.refcount.copy()
        old = int(tables.table[a, b])
        try:
            pair = tables.ensure_writable(a, b)
        except OutOfPages:
            np.testing.assert_array_equal(alloc.refcount, rc0)
            return
        new = int(tables.table[a, b])
        # the enforced invariant: the written entry is exclusively owned
        assert new != TRASH_PAGE and alloc.refcount[new] == 1
        if pair is not None:           # COW: the shared source survives
            src, dst = pair
            assert (src, dst) == (old, new) and src != dst
            assert rc0[old] > 1 and alloc.refcount[old] == rc0[old] - 1
        elif old != TRASH_PAGE:        # already exclusive: untouched
            assert new == old
    else:
        dropped = tables.release_row(a)
        assert dropped == 0 or not tables.owned_pages(a)
        assert tables.release_row(a) == 0   # idempotent: entries zeroed


@settings(max_examples=200, deadline=None)
@given(ops=st.lists(_op_strategy(), max_size=60),
       n_pages=st.integers(2, 2 * N_SLOTS * N_BLOCKS))
def test_invariants_hold_under_random_interleavings(ops, n_pages):
    alloc = PageAllocator(n_pages, PAGE_SIZE)
    tables = BlockTables(N_SLOTS, N_BLOCKS, alloc)
    for op in ops:
        _apply(tables, op)
        tables.check()   # refcounts == table refs + conservation laws
    for s in range(N_SLOTS):
        tables.release_row(s)
    assert alloc.live_pages == 0 and alloc.free_pages == n_pages - 1
    tables.check()


@settings(max_examples=100, deadline=None)
@given(ops=st.lists(_op_strategy(), max_size=60),
       n_pages=st.integers(2, 2 * N_SLOTS * N_BLOCKS))
def test_determinism_given_op_sequence(ops, n_pages):
    """Same ops, fresh allocator -> bit-identical end state (the engine's
    differential tests lean on this: page numbering is reproducible)."""
    states = []
    for _ in range(2):
        alloc = PageAllocator(n_pages, PAGE_SIZE)
        tables = BlockTables(N_SLOTS, N_BLOCKS, alloc)
        for op in ops:
            _apply(tables, op)
        states.append((tables.table.copy(), alloc.refcount.copy(),
                       list(alloc._free), alloc.total_allocs,
                       alloc.cow_copies))
    np.testing.assert_array_equal(states[0][0], states[1][0])
    np.testing.assert_array_equal(states[0][1], states[1][1])
    assert states[0][2:] == states[1][2:]


@settings(max_examples=100, deadline=None)
@given(st.data())
def test_double_free_always_raises(data):
    """A stray release of a page the table no longer references must be
    loud — silent double frees corrupt the free list."""
    n_pages = data.draw(st.integers(3, 9))
    alloc = PageAllocator(n_pages, PAGE_SIZE)
    pages = [alloc.alloc() for _ in range(
        data.draw(st.integers(1, n_pages - 1)))]
    victim = data.draw(st.sampled_from(pages))
    alloc.release(victim)
    with pytest.raises(ValueError, match="double free"):
        alloc.release(victim)
    with pytest.raises(ValueError):
        alloc.release(TRASH_PAGE)
