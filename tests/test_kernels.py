"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 4, 4, 128, 64),    # MHA
    (2, 8, 2, 256, 64),    # GQA 4:1
    (1, 8, 1, 128, 128),   # MQA
    (2, 4, 4, 192, 32),    # S not a multiple of 128 -> smaller blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, KV, S, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
    bq = 64 if S % 64 == 0 else S
    out = ops.flash_attention(q, k, v, scale=D ** -0.5, block_q=bq, block_k=bq)
    expected = ref.flash_attention_ref(q, k, v, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,H,KV,CL,D,block", [
    (2, 8, 2, 128, 64, 32),
    (1, 4, 4, 256, 64, 64),
    (3, 8, 1, 64, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, H, KV, CL, D, block, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, CL, KV, D), dtype)
    vc = jax.random.normal(ks[2], (B, CL, KV, D), dtype)
    lengths = jnp.arange(1, B + 1) * (CL // (B + 1)) + 1
    out = ops.flash_decode(q, kc, vc, lengths, scale=D ** -0.5, block_k=block)
    expected = ref.flash_decode_ref(q, kc, vc, lengths, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def test_flash_decode_full_ring():
    """lengths == CL must attend to every slot (ring-buffer mode)."""
    B, H, KV, CL, D = 1, 4, 2, 64, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, CL, KV, D))
    vc = jax.random.normal(ks[2], (B, CL, KV, D))
    out = ops.flash_decode(q, kc, vc, jnp.full((B,), CL), scale=D ** -0.5,
                           block_k=32)
    expected = ref.flash_decode_ref(q, kc, vc, jnp.full((B,), CL),
                                    scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_max_len_hint():
    """A static hint >= max(lengths) shrinks the KV grid without changing
    the result (grid-level early exit)."""
    B, H, KV, CL, D, block = 2, 4, 2, 256, 32, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, CL, KV, D))
    vc = jax.random.normal(ks[2], (B, CL, KV, D))
    lengths = jnp.asarray([37, 70])
    full = ops.flash_decode(q, kc, vc, lengths, scale=D ** -0.5, block_k=block)
    for hint in (70, 96, 255):   # any hint >= max(lengths) is exact
        out = ops.flash_decode(q, kc, vc, lengths, scale=D ** -0.5,
                               block_k=block, max_len_hint=hint)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                                   atol=2e-5, rtol=2e-5, err_msg=str(hint))


@pytest.mark.parametrize("B,H,KV,C,CL,D,off,block", [
    (2, 4, 2, 16, 128, 32, 0, 64),     # first chunk: empty cache
    (2, 4, 2, 16, 128, 32, 48, 64),    # mid-prompt, full-length cache
    (1, 8, 1, 8, 64, 64, 64, 32),      # MQA, ring exactly full
    (1, 4, 4, 8, 32, 16, 72, 16),      # MHA, ring wrapped twice
    (2, 8, 2, 4, 32, 64, 36, 32),      # chunk straddling the ring window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_attention_sweep(B, H, KV, C, CL, D, off, block, dtype):
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, C, H, D), dtype)
    kh = jax.random.normal(ks[1], (B, C, KV, D), dtype)
    vh = jax.random.normal(ks[2], (B, C, KV, D), dtype)
    kc = jax.random.normal(ks[3], (B, CL, KV, D), dtype)
    vc = jax.random.normal(ks[4], (B, CL, KV, D), dtype)
    out = ops.prefill_attention(q, kh, vh, kc, vc, jnp.int32(off),
                                scale=D ** -0.5, block_k=block)
    expected = ref.prefill_attention_ref(q, kh, vh, kc, vc, off,
                                         scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **_tol(dtype))


def test_prefill_attention_matches_sequential_window():
    """Independent oracle: build the ring cache by sequential writes of an
    absolute K/V history, then check every chunk query attends exactly the
    sliding window [qp-CL+1, qp] of that history — the invariant that makes
    chunked admission equal the per-token decode loop on ring caches."""
    B, H, KV, D, CL, C = 1, 4, 2, 16, 8, 4
    rep = H // KV
    for off in (0, 4, 8, 12, 20):
        S = off + C
        ks = jax.random.split(jax.random.fold_in(KEY, off), 3)
        kfull = jax.random.normal(ks[0], (B, S, KV, D))
        vfull = jax.random.normal(ks[1], (B, S, KV, D))
        q = jax.random.normal(ks[2], (B, C, H, D))
        kc = jnp.zeros((B, CL, KV, D))
        vc = jnp.zeros((B, CL, KV, D))
        for p in range(off):            # the sequential decode loop's writes
            kc = kc.at[:, p % CL].set(kfull[:, p])
            vc = vc.at[:, p % CL].set(vfull[:, p])
        out = ops.prefill_attention(q, kfull[:, off:], vfull[:, off:],
                                    kc, vc, jnp.int32(off), scale=D ** -0.5,
                                    block_k=CL)
        exp = np.zeros((B, C, H, D), np.float32)
        for i in range(C):
            qp = off + i
            lo = max(0, qp - CL + 1)
            keys = np.asarray(kfull[:, lo:qp + 1])
            vals = np.asarray(vfull[:, lo:qp + 1])
            qr = np.asarray(q[:, i]).reshape(B, KV, rep, D)
            s = np.einsum("bgrd,bkgd->bgrk", qr, keys) * D ** -0.5
            pw = np.exp(s - s.max(-1, keepdims=True))
            pw /= pw.sum(-1, keepdims=True)
            exp[:, i] = np.einsum("bgrk,bkgd->bgrd", pw, vals).reshape(B, H, D)
        np.testing.assert_allclose(np.asarray(out, np.float32), exp,
                                   atol=2e-5, rtol=2e-5, err_msg=f"off={off}")


def test_prefill_attention_offset_hint():
    """A static offset_hint >= min(offset, CL) shrinks the cache-block
    grid without changing the result (grid-level early exit, the prefill
    mirror of flash_decode's max_len_hint). offset=0 launches no cache
    blocks at all."""
    B, H, KV, C, CL, D, block = 1, 4, 2, 8, 256, 32, 32
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, C, H, D))
    kh = jax.random.normal(ks[1], (B, C, KV, D))
    vh = jax.random.normal(ks[2], (B, C, KV, D))
    kc = jax.random.normal(ks[3], (B, CL, KV, D))
    vc = jax.random.normal(ks[4], (B, CL, KV, D))
    for off in (0, 40, 96, 300):    # 300 > CL: wrapped ring, all slots live
        full = ops.prefill_attention(q, kh, vh, kc, vc, jnp.int32(off),
                                     scale=D ** -0.5, block_k=block)
        lo = min(off, CL)
        for hint in (lo, -(-lo // block) * block, CL):
            out = ops.prefill_attention(q, kh, vh, kc, vc, jnp.int32(off),
                                        scale=D ** -0.5, block_k=block,
                                        offset_hint=hint)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(full), atol=2e-5, rtol=2e-5,
                err_msg=f"off={off} hint={hint}")


@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 8, 16),
    (2, 128, 4, 32, 2, 16, 32),
    (1, 96, 6, 16, 3, 8, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, l, h, p, g, n, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, l, g, n), dtype)
    C = jax.random.normal(ks[4], (b, l, g, n), dtype)
    out, st = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    expected, st_ref = ref.ssd_scan_ref(
        x.astype(jnp.float32), dt, A, B.astype(jnp.float32),
        C.astype(jnp.float32), chunk=chunk)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st, np.float32),
                               np.asarray(st_ref, np.float32), **tol)


def test_ssd_scan_state_carries_across_chunks():
    """A signal in chunk 0 must influence outputs in the last chunk."""
    b, l, h, p, g, n, chunk = 1, 64, 1, 8, 1, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jnp.zeros((b, l, h, p)).at[0, 3].set(1.0)
    dt = jnp.full((b, l, h), 0.05)
    A = -jnp.ones((h,)) * 0.01  # slow decay
    B = jnp.ones((b, l, g, n))
    C = jnp.ones((b, l, g, n))
    y, _ = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    assert float(jnp.abs(y[0, -1]).max()) > 1e-4
