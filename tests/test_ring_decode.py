"""Ring-buffer sliding-window decode (the long_500k serve path): decoding
with a window-sized ring cache must match the full-sequence forward with
sliding-window attention, once the ring is warm."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import kv_cache_specs
from repro.models import model as M
from repro.sharding import tree_values

KEY = jax.random.PRNGKey(5)


def test_ring_decode_matches_windowed_forward():
    W = 8
    cfg = dataclasses.replace(smoke_config(get_config("llama3-8b")),
                              attention_variant="sliding_window",
                              sliding_window=W, use_mtp=False)
    params = tree_values(M.init_params(cfg, KEY))
    B, S = 1, 20
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    # reference: full forward with the sliding-window mask
    ref = M.forward(params, toks, pos, cfg)["logits"]

    # ring decode: window-sized cache, token by token
    specs = kv_cache_specs(cfg, B, W)
    cache = {k: jnp.zeros(v.shape, v.dtype) for k, v in specs.items()}
    assert cache["k"].shape[2] == W  # the ring really is window-sized
    logits = []
    for t in range(S):
        out = M.decode_step(params, toks[:, t:t + 1], pos[:, t:t + 1],
                            cache, jnp.int32(t), cfg,
                            ring=(t >= W))  # masked until the ring is warm
        cache = out["cache"]
        logits.append(out["logits"][:, 0])
    dec = jnp.stack(logits, axis=1)

    # exact agreement once the ring is warm (and during warmup too, since
    # masking covers the cold slots)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(ref, np.float32),
                               atol=3e-4, rtol=3e-4)


def test_ring_recompute_kv_matches_sequential_writes():
    """`recompute_kv` on a sliding-window engine (the §3 ablation): the
    gathered ring cache must hold exactly what the sequential decode loop
    would have written under the new weights — slot j gets the most recent
    position p <= n_cached-1 with p ≡ j (mod CL)."""
    from repro.core.rollout import GenerationEngine

    W = 8
    cfg = dataclasses.replace(smoke_config(get_config("llama3-8b")),
                              attention_variant="sliding_window",
                              sliding_window=W, use_mtp=False)
    params = tree_values(M.init_params(cfg, KEY))
    new_params = tree_values(M.init_params(cfg, jax.random.PRNGKey(99)))
    H, T = 3, 20
    toks = jax.random.randint(KEY, (H, T), 0, cfg.vocab_size)
    n_cached = jnp.asarray([20, 5, 0])   # wrapped ring / cold ring / empty
    specs = kv_cache_specs(cfg, H, W)
    st = {
        "tokens": toks,
        "n_cached": n_cached,
        "cache": {k: jax.random.normal(KEY, v.shape).astype(v.dtype)
                  for k, v in specs.items()},   # stale garbage everywhere
    }
    assert st["cache"]["k"].shape[2] == W

    got = GenerationEngine._recompute_impl(new_params, st, cfg=cfg)

    pos = jnp.broadcast_to(jnp.arange(T)[None], (H, T))
    full = M.forward(new_params, toks, pos, cfg,
                     return_cache=True)["cache"]
    for key in ("k", "v"):
        # oracle: the sequential loop's ring writes of the full-length cache
        exp = np.zeros(st["cache"][key].shape, np.float32)
        valid = np.zeros((H, W), bool)
        for b, nc in enumerate(np.asarray(n_cached)):
            for p in range(int(nc)):
                exp[:, b, p % W] = np.asarray(full[key][:, b, p])
                valid[b, p % W] = True
        g = np.asarray(got[key], np.float32)
        for b in range(H):
            np.testing.assert_allclose(
                g[:, b][:, valid[b]], exp[:, b][:, valid[b]],
                atol=1e-5, rtol=1e-5, err_msg=f"{key} row {b}")
        # dead slots of empty rows must never be read anyway; nothing to
        # assert there (the gather clamps them to position 0)
