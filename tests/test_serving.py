"""Serving front: request lifecycle, back-pressure, in-flight updates under
load, and the preprocessor stage (reference-KL reward shaping)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.tiny import config as tiny_config
from repro.core.preprocess import Preprocessor, PreprocessConfig
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.serving import Server
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values


@pytest.fixture(scope="module")
def setup():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return task, cfg, params


def test_server_completes_all_requests(setup):
    task, cfg, params = setup
    srv = Server(cfg, params, EngineConfig(n_slots=4, max_len=16))
    rids = [srv.submit(task.sample().prompt_ids) for _ in range(10)]
    for _ in range(200):
        srv.step()
        if len(srv.done) == 10:
            break
    m = srv.metrics()
    assert m["served"] == 10
    assert m["waiting"] == 0 and m["in_flight"] == 0
    assert sorted(r.rid for r in srv.done) == sorted(rids)
    assert m["p99_latency"] >= m["p50_latency"] > 0
    # back-pressure existed: only 4 slots for 10 requests
    assert m["mean_admission_wait"] > 0


def test_server_inflight_update_drops_nothing(setup):
    task, cfg, params = setup
    params2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(9)))
    srv = Server(cfg, params, EngineConfig(n_slots=4, max_len=16))
    srv.connect_trainer(lambda: (params2, 3))
    for _ in range(8):
        srv.submit(task.sample().prompt_ids)
    for i in range(200):
        if i == 5:
            assert srv.request_weight_update() == 3
        srv.step()
        if len(srv.done) == 8:
            break
    assert len(srv.done) == 8
    # at least one completion must be mixed-version (sampled across the swap)
    assert any(r.weight_versions is not None and r.weight_versions.max() == 3
               for r in srv.done)


def test_server_idle_steps_safe(setup):
    _, cfg, params = setup
    srv = Server(cfg, params, EngineConfig(n_slots=2, max_len=8))
    for _ in range(3):
        assert srv.step() == []
    assert srv.metrics()["served"] == 0
    # idle steps still consume wall time (dt each): a request submitted
    # after an idle period must not get its latency backdated
    assert srv.clock == pytest.approx(3.0)


def test_server_streamed_update_installs_across_steps(setup):
    """Streamed publication on the serving front: one chunk per step, the
    version flips only after the final pointer swap, nothing dropped."""
    task, cfg, params = setup
    params2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(9)))
    srv = Server(cfg, params, EngineConfig(n_slots=4, max_len=16))
    srv.connect_trainer(lambda: (params2, 4))
    for _ in range(8):
        srv.submit(task.sample().prompt_ids)
    srv.step()
    assert srv.request_weight_update(streamed=True, n_chunks=3) == 4
    assert srv.engine.version == 0          # transfer not finished yet
    for i in range(200):
        srv.step()
        if len(srv.done) == 8:
            break
    assert srv.engine.version == 4          # pointer swap landed
    assert srv.metrics()["streams_completed"] == 1
    assert len(srv.done) == 8
    assert any(r.weight_versions is not None and r.weight_versions.max() == 4
               for r in srv.done)


# ---------------------------------------------------------------------------
# preprocessor stage
# ---------------------------------------------------------------------------

def test_preprocessor_ref_logprobs_and_kl_penalty(setup):
    task, cfg, params = setup
    ref_params = tree_values(M.init_params(cfg, jax.random.PRNGKey(7)))
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=4, max_len=16),
                           task.sample, seed=2)
    eng.refill()
    rollouts = []
    for _ in range(40):
        rollouts.extend(eng.step(task))
        if eng.n_active == 0:
            break
    pre = Preprocessor(cfg, ref_params,
                       PreprocessConfig(kl_coef=0.1, max_len=16))
    out = pre.process(rollouts)
    for r in out:
        assert r.ref_logprobs is not None
        assert r.token_rewards is not None
        L = len(r.token_rewards)
        assert (r.token_rewards[:r.prompt_len] == 0).all()
        # KL-shaped per-token rewards sum ~ reward - beta*KL(completion)
        mask = np.arange(L) >= r.prompt_len
        kl = float(((r.behavior_logprobs[:L] - r.ref_logprobs) * mask).sum())
        np.testing.assert_allclose(r.token_rewards.sum(),
                                   r.reward - 0.1 * kl, rtol=1e-4, atol=1e-4)


def test_preprocessor_self_reference_zero_kl(setup):
    """pi_ref == mu  =>  KL penalty ~ 0 (logprobs recorded at sampling match
    a fresh forward under the same weights)."""
    task, cfg, params = setup
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=4, max_len=16),
                           task.sample, seed=3)
    eng.refill()
    rollouts = []
    for _ in range(40):
        rollouts.extend(eng.step(task))
        if eng.n_active == 0:
            break
    pre = Preprocessor(cfg, params, PreprocessConfig(kl_coef=1.0, max_len=16))
    out = pre.process(rollouts)
    for r in out:
        L = len(r.ref_logprobs)
        mask = np.arange(L) >= r.prompt_len
        diff = np.abs((r.behavior_logprobs[:L] - r.ref_logprobs) * mask)
        assert diff.max() < 1e-3


def test_pipeline_with_preprocessor_stage(setup):
    task, cfg, params = setup
    ref_params = tree_values(M.init_params(cfg, jax.random.PRNGKey(7)))
    pre = Preprocessor(cfg, ref_params,
                       PreprocessConfig(kl_coef=0.05, max_len=16))
    p = PipelineRL(cfg, params, task,
                   EngineConfig(n_slots=8, max_len=16),
                   PipelineConfig(batch_size=4, n_opt_steps=3, n_chips=8,
                                  train_chips=4, pack_rows=2, pack_seq=48),
                   preprocessor=pre)
    log = p.run()
    assert len(log) == 3
    assert all(np.isfinite(r["loss"]) for r in log)
