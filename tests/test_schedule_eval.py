"""LR schedules + the periodic evaluator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.tiny import config as tiny_config
from repro.core.evaluator import Evaluator
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.optim.schedule import constant, warmup_constant, warmup_cosine
from repro.sharding import tree_values


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0)
    assert float(s(jnp.int32(55))) < 1.0
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)
    # monotone decay after warmup
    vals = [float(s(jnp.int32(t))) for t in range(10, 101, 10)]
    assert vals == sorted(vals, reverse=True)


def test_warmup_constant():
    s = warmup_constant(2.0, warmup_steps=4)
    assert float(s(jnp.int32(2))) == pytest.approx(1.0)
    assert float(s(jnp.int32(8))) == pytest.approx(2.0)


def test_trainer_with_schedule_reports_lr():
    task = MathTask(max_operand=3, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    tr = Trainer(cfg, params, adam=AdamConfig(lr=1e-3),
                 lr_schedule=warmup_constant(1e-3, warmup_steps=5))
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
        "segment_ids": jnp.ones((B, S), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "behavior_logprobs": jnp.full((B, S), -1.0),
        "rewards": jnp.full((B, S), 0.5),
    }
    m1 = tr.step(batch)
    m2 = tr.step(batch)
    assert m1["lr"] == pytest.approx(0.0)      # step counter starts at 0
    assert m2["lr"] == pytest.approx(2e-4)     # 1/5 of the way through warmup


def test_evaluator_runs_and_scores():
    task = MathTask(max_operand=3, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    ev = Evaluator(cfg, task, n_problems=8, max_len=12)
    m = ev.evaluate(params)
    assert m["n"] >= 8
    assert 0.0 <= m["success_rate"] <= 1.0
    assert m["mean_len"] > 0
    # deterministic problem set: same params -> same score
    m2 = ev.evaluate(params)
    assert m2["success_rate"] == m["success_rate"]
