"""Fault tolerance (DESIGN.md §8): fault injection, in-flight work
recovery, crash-restart checkpointing, and the serving front's graceful
degradation.

The structural claims under test:
  - chaos replay is deterministic: two identical-seed runs under the same
    FaultPlan produce bit-equal rollout streams
  - an engine kill loses only in-flight *decode* work: the victim's
    prompts are salvaged, requeued at the front of the router's pending
    buffer, and re-admitted by the survivors
  - a trainer crash restores params + optimizer moments + version from
    the last durable checkpoint, and the next optimizer step is
    bit-identical to the one an uninterrupted run would take
  - the Server never loses a request: every submission ends in exactly
    one of done/in-flight/waiting/backoff/rejected/shed
"""
import hashlib
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.configs.tiny import config as tiny_config
from repro.core.events import (
    EventLoop, FaultPlan, PreprocessStage, TrainerStage, WeightBroadcaster,
)
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.queues import QueueUnderflow, SampleQueue
from repro.core.rollout import EngineConfig
from repro.core.serving import Server
from repro.core.sim import HardwareModel
from repro.core.algo import RLConfig
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.data.packing import Rollout, pack
from repro.models import model as M
from repro.sharding import tree_values

# slow interconnect + saturated decode so the 4-step run spans ~600
# flashes (first optimizer step ~220): fault times below are tuned to hit
# live decode slots between the first and second step
HW = HardwareModel(h_sat=16, bcast_bytes_per_flash=2e3,
                   bcast_install_flash=1.0)
KILL_AT, RESTORE_AFTER = 120.0, 240.0


@pytest.fixture(scope="module")
def setup():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return task, cfg, params


def _pipe(setup, plan=None, steps=4, ckpt_dir=None, ckpt_every=0,
          record=None, lag=False):
    task, cfg, params = setup
    ec = EngineConfig(n_slots=8, max_len=16)
    pc = PipelineConfig(batch_size=4, n_opt_steps=steps, n_chips=8,
                        train_chips=4, pack_rows=2, pack_seq=48,
                        n_engines=2, ckpt_every=ckpt_every,
                        ckpt_dir=ckpt_dir,
                        max_lag=2 if lag else None)
    trainer = Trainer(cfg, params,
                      rl=RLConfig(lag_mode="token_is")) if lag \
        else Trainer(cfg, params)
    p = PipelineRL(cfg, params, task, ec, pc, hw=HW, trainer=trainer,
                   seed=0, fault_plan=plan)
    if record is not None:
        orig_put = p.queue.put

        def tap(rollouts):
            for r in rollouts:
                record.append(np.asarray(r.tokens).tobytes()
                              + np.asarray(r.weight_versions).tobytes())
            orig_put(rollouts)

        p.queue.put = tap
    return p


# ---------------------------------------------------------------------------
# FaultPlan: construction, parse DSL, replayable chunk-loss oracle
# ---------------------------------------------------------------------------

def test_fault_plan_parse_dsl():
    plan = FaultPlan.parse(
        "engine:1@300r150, trainer@500r100, pre@400, link:0@600d300p0.5")
    kinds = [f.kind for f in plan.faults]
    assert kinds == ["engine_crash", "trainer_crash", "preprocess_fail",
                     "link_degrade"]
    e, t, _, l = plan.faults
    assert (e.engine, e.at, e.restart_after) == (1, 300.0, 150.0)
    assert (t.at, t.restart_after) == (500.0, 100.0)
    assert (l.engine, l.at, l.duration, l.drop_prob) == (0, 600.0, 300.0, 0.5)
    # permanent crash: no restart group
    assert FaultPlan.parse("engine:0@10").faults[0].restart_after is None
    with pytest.raises(ValueError):
        FaultPlan.parse("flux-capacitor@88")


def test_fault_plan_chaos_seed_deterministic():
    a = FaultPlan.chaos(42, horizon=1000.0, n_engines=4, n_crashes=3,
                        link_windows=2)
    b = FaultPlan.chaos(42, horizon=1000.0, n_engines=4, n_crashes=3,
                        link_windows=2)
    assert [vars(f) for f in a.faults] == [vars(f) for f in b.faults]
    c = FaultPlan.chaos(43, horizon=1000.0, n_engines=4, n_crashes=3,
                        link_windows=2)
    assert [vars(f) for f in a.faults] != [vars(f) for f in c.faults]


def test_chunk_loss_oracle_is_order_independent():
    plan = FaultPlan(seed=9).degrade_link(at=0.0, duration=1e9,
                                          drop_prob=0.5)
    keys = [(e, v, k, a) for e in range(2) for v in range(3)
            for k in range(4) for a in range(2)]
    fwd = {key: plan.chunk_lost(*key, t=5.0) for key in keys}
    rev = {key: plan.chunk_lost(*key, t=5.0) for key in reversed(keys)}
    assert fwd == rev
    assert any(fwd.values()) and not all(fwd.values())
    # outside the window nothing is lost; drop_prob=1 loses everything
    assert not plan.chunk_lost(0, 0, 0, 0, t=-1.0)
    assert FaultPlan().degrade_link(at=0.0, duration=10.0).chunk_lost(
        0, 0, 0, 0, t=5.0)


def test_lossy_broadcast_deterministic_and_terminating():
    class StubActor:
        failed = False

        def __init__(self):
            self.streams = []

        def deliver_stream(self, params, version, arrivals, **kw):
            self.streams.append(list(arrivals))

    params = {"w": np.zeros((64, 64), np.float32)}
    plan = FaultPlan(seed=5).degrade_link(at=0.0, duration=1e9,
                                          drop_prob=0.4)
    runs = []
    for _ in range(2):
        actors = [StubActor(), StubActor()]
        bc = WeightBroadcaster(HW, actors, mode="streamed", n_chunks=8,
                               fault_plan=plan)
        bc.publish(params, version=3, now=0.0)
        runs.append([a.streams for a in actors])
        assert bc.chunks_lost > 0
        assert bc.retransmit_wait > 0
    assert runs[0] == runs[1]
    # arrivals stay strictly increasing (serialized cursor) per stream
    for streams in runs[0]:
        for arr in streams:
            assert all(b > a for a, b in zip(arr, arr[1:]))


def test_broadcaster_skips_failed_actors():
    class StubActor:
        def __init__(self, failed):
            self.failed = failed
            self.n = 0

        def deliver_atomic(self, *a, **kw):
            self.n += 1

    alive, dead = StubActor(False), StubActor(True)
    bc = WeightBroadcaster(HW, [alive, dead], mode="atomic")
    bc.publish({"w": np.zeros((4,), np.float32)}, version=1, now=0.0)
    assert (alive.n, dead.n) == (1, 0)
    assert bc.deliveries_skipped == 1


# ---------------------------------------------------------------------------
# SampleQueue recovery surface
# ---------------------------------------------------------------------------

def _mk_rollout(i, length=4):
    return Rollout(tokens=np.full(length, i % 7, np.int32), prompt_len=1,
                   behavior_logprobs=np.zeros(length, np.float32),
                   reward=float(i), weight_versions=np.zeros(length, np.int32),
                   prompt_key=i)


def test_requeue_front_order_and_counters():
    q = SampleQueue()
    q.put([_mk_rollout(i) for i in range(4)])
    salvaged = q.pop(2)
    q.requeue_front(salvaged)
    # original order restored, total_put not inflated
    assert [r.prompt_key for r in q.pop(4)] == [0, 1, 2, 3]
    assert q.total_put == 4
    assert q.requeued == 2


def test_requeue_front_respects_maxsize():
    q = SampleQueue(maxsize=3)
    q.put([_mk_rollout(i) for i in range(3)])
    q.requeue_front([_mk_rollout(97), _mk_rollout(98)])
    # drop-oldest evicts the salvaged (oldest) entries first: 97 then 98
    assert len(q) == 3
    assert q.dropped == 2
    assert [r.prompt_key for r in q.pop(3)] == [0, 1, 2]


def test_queue_underflow_carries_depth():
    q = SampleQueue()
    q.put([_mk_rollout(0)])
    with pytest.raises(QueueUnderflow) as ei:
        q.pop(3)
    assert (ei.value.depth, ei.value.requested) == (1, 3)
    assert isinstance(ei.value, ValueError)  # pre-existing handlers hold


# ---------------------------------------------------------------------------
# checkpoint hardening
# ---------------------------------------------------------------------------

def _tree():
    return {"layer": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                      "b": np.ones((3,), np.float32)},
            "step": np.asarray(7, np.int32)}


def test_checkpoint_roundtrip_normalizes_suffix(tmp_path):
    bare = str(tmp_path / "ckpt")           # no .npz
    checkpoint.save(bare, _tree())
    assert os.path.exists(bare + ".npz")
    like = jax.tree.map(np.zeros_like, _tree())
    out = checkpoint.load(bare, like)       # bare path loads too
    assert all(np.array_equal(a, b) for a, b in zip(
        jax.tree.leaves(out), jax.tree.leaves(_tree())))
    # atomic save leaves no temp droppings
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []


def test_checkpoint_corrupt_file_raises_checkpoint_error(tmp_path):
    path = str(tmp_path / "bad.npz")
    with open(path, "wb") as f:
        f.write(b"this is not an npz archive")
    with pytest.raises(checkpoint.CheckpointError, match="corrupt"):
        checkpoint.load(path, _tree())
    with pytest.raises(FileNotFoundError):
        checkpoint.load(str(tmp_path / "absent.npz"), _tree())


def test_checkpoint_key_and_shape_mismatches_are_named(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, _tree())
    like = _tree()
    like["extra"] = np.zeros((2,), np.float32)
    del like["step"]
    with pytest.raises(checkpoint.CheckpointError) as ei:
        checkpoint.load(path, like)
    assert "extra" in str(ei.value) and "step" in str(ei.value)
    like = _tree()
    like["layer"]["w"] = np.zeros((5, 5), np.float32)
    with pytest.raises(checkpoint.CheckpointError, match="layer/w"):
        checkpoint.load(path, like)


# ---------------------------------------------------------------------------
# trainer crash-restart: checkpoint parity
# ---------------------------------------------------------------------------

def _batch(task, cfg, seed):
    rng = np.random.default_rng(seed)
    rolls = []
    for i in range(4):
        toks = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
        rolls.append(Rollout(
            tokens=toks, prompt_len=3,
            behavior_logprobs=rng.normal(size=10).astype(np.float32) - 2.0,
            reward=float(rng.choice([-1.0, 1.0])),
            weight_versions=np.zeros(10, np.int32), prompt_key=i))
    b = pack(rolls, 2, 48)
    b.pop("packing_stats")
    return b


def test_trainer_restore_step_parity(setup, tmp_path):
    """After a crash, restoring the checkpoint and re-running the next
    batch must be bit-identical to the uninterrupted run — params, opt
    moments, and version all line up (same compiled step function)."""
    task, cfg, params = setup
    b1, b2 = _batch(task, cfg, 1), _batch(task, cfg, 2)
    tr = Trainer(cfg, params)
    tr.step(b1)
    ckpt = tr.save(str(tmp_path / "trainer_latest"))
    tr.step(b2)
    uninterrupted = jax.tree.map(np.asarray, tr.state)
    # crash: state diverges past the checkpoint; restore rolls it back
    tr.step(_batch(task, cfg, 3))
    assert tr.restore(ckpt) == 1
    tr.step(b2)
    restored = jax.tree.map(np.asarray, tr.state)
    flat_u = jax.tree.leaves(uninterrupted)
    flat_r = jax.tree.leaves(restored)
    assert all(np.array_equal(a, b) for a, b in zip(flat_u, flat_r))
    assert tr.version == 2


def test_pipeline_trainer_crash_restores_and_finishes(setup, tmp_path):
    plan = FaultPlan().trainer_crash(at=KILL_AT + RESTORE_AFTER,
                                     restart_after=60.0)
    p = _pipe(setup, plan, ckpt_dir=str(tmp_path), ckpt_every=2)
    p.run()
    tr = p.pool_stats()["trainer"]
    assert p.trainer.version >= 4
    assert tr["crashes"] == 1 and tr["recoveries"] == 1
    assert tr["ckpts_saved"] >= 2          # seed ckpt + periodic
    assert os.path.exists(os.path.join(str(tmp_path), "trainer_latest.npz"))
    kinds = [e["kind"] for e in p.fault_log]
    assert kinds.count("trainer_crash") == 1
    assert kinds.count("trainer_restore") == 1


# ---------------------------------------------------------------------------
# engine kill, salvage, requeue, elastic rejoin
# ---------------------------------------------------------------------------

def test_engine_kill_salvages_and_requeues(setup):
    plan = FaultPlan().engine_crash(at=KILL_AT, engine=1)  # permanent
    p = _pipe(setup, plan)
    p.run()
    ps = p.pool_stats()
    assert p.trainer.version >= 4          # survivor carries the run
    victim = ps["engines"][1]
    assert victim["failures"] == 1 and not victim["alive"]
    assert victim["rollouts_lost"] > 0     # mid-decode kill
    assert ps["prompts_salvaged"] == victim["prompts_salvaged"] > 0
    assert ps["prompts_requeued"] == ps["prompts_salvaged"]
    # every salvaged prompt was re-admitted by the survivor
    assert ps["requeues_readmitted"] == ps["prompts_requeued"]
    assert ps["requeue_latency_max"] >= ps["requeue_latency_mean"] >= 0.0


def test_engine_restore_catches_up_weights(setup):
    plan = FaultPlan().engine_crash(at=KILL_AT, engine=1,
                                    restart_after=RESTORE_AFTER)
    p = _pipe(setup, plan, steps=6)
    p.run()
    a = p.actors[1]
    assert a.failures == 1 and a.recoveries == 1
    assert a.downtime == pytest.approx(RESTORE_AFTER)
    restores = [e for e in p.fault_log if e["kind"] == "engine_restore"]
    assert len(restores) == 1
    # the catch-up atomic sync hands the engine the restore-time version
    assert p.engines[1].version >= restores[0]["version"] > 0
    assert p.router.alive[1]


def _failstop_plan():
    return (FaultPlan(seed=3)
            .engine_crash(at=KILL_AT, engine=1,
                          restart_after=RESTORE_AFTER)
            .degrade_link(at=KILL_AT, duration=RESTORE_AFTER,
                          drop_prob=0.3))


def _gray_plan():
    # every §10 gray fault kind at once: measured slowdown, wedged
    # engine, corrupted weight chunks, non-finite steps, poison prompt
    return (FaultPlan(seed=7)
            .engine_slowdown(at=50.0, duration=150.0, engine=0, factor=6.0)
            .engine_hang(at=KILL_AT, engine=1, restart_after=80.0)
            .chunk_corrupt(at=0.0, duration=1500.0, drop_prob=0.5)
            .nan_step(at=100.0, count=2)
            .poison_prompt(5))


@pytest.mark.parametrize("lag", [False, True], ids=["plain", "lag"])
@pytest.mark.parametrize("make_plan", [_failstop_plan, _gray_plan],
                         ids=["failstop", "gray"])
def test_chaos_replay_is_bit_equal(make_plan, lag):
    """Two identical-seed chaos runs stream bit-equal rollouts — including
    with the lag correction armed (token_is objective + max_lag=2 gate):
    the bounded-staleness barrier keys only on replayed state (trainer /
    engine versions), so it cannot desynchronize a replay."""
    digests = []
    for _ in range(2):
        # a fresh task per run: the prompt stream's RNG is part of the
        # replayed state (a shared task would advance between runs)
        task = MathTask(max_operand=5, ops="+")
        cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64,
                          n_layers=1)
        params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
        rec = []
        p = _pipe((task, cfg, params), make_plan(), record=rec, lag=lag)
        p.run()
        digests.append(hashlib.sha256(b"".join(rec)).hexdigest())
    assert digests[0] == digests[1]


def test_elastic_add_and_detach_engine(setup):
    p = _pipe(setup, steps=2)
    p.run()
    i = p.add_engine(speed=1.0)
    assert i == 2 and len(p.engines) == 3
    # catch-up sync before admission: the joiner starts at the trainer's
    # current version, never at 0
    assert p.engines[i].version == p.trainer.version > 0
    p.run(4)
    assert p.router.assigned[i] > 0        # the joiner pulled real work
    salvaged = p.detach_engine(i)
    assert p.actors[i].failed and not p.router.alive[i]
    assert salvaged >= 0
    p.run(5)                               # survivors finish the run
    assert p.trainer.version >= 5


# ---------------------------------------------------------------------------
# preprocessor failure: in-flight batch survives via requeue_front
# ---------------------------------------------------------------------------

def test_preprocess_fail_requeues_in_flight_batch():
    class StubPre:
        def process(self, rollouts):
            return rollouts

        def stage_time(self, n_tokens):
            return 10.0

    class StubTrainerStage:
        def __init__(self):
            self.got = []

        def inbox_waiting(self):
            return 0

        def submit(self, rollouts, t, raw_reward=None):
            self.got.append([r.prompt_key for r in rollouts])

    loop = EventLoop()
    q = SampleQueue()
    ts = StubTrainerStage()
    pre = PreprocessStage(loop, StubPre(), q, batch_size=4,
                          trainer_stage=ts)
    q.put([_mk_rollout(i) for i in range(4)])
    pre.kick(0.0)
    assert pre.busy
    n = pre.fail(2.0)   # mid-flight: batch salvaged, stage auto-restarts
    assert n == 4 and pre.batches_failed == 1
    assert pre.rollouts_requeued == 4
    assert pre.busy      # the immediate re-kick reprocesses the salvage
    loop.run()           # stale delivery no-ops; the retry delivers once
    assert ts.got == [[0, 1, 2, 3]]
    # idle failure salvages nothing but still counts
    assert pre.fail(20.0) == 0
    assert pre.batches_failed == 2


# ---------------------------------------------------------------------------
# serving front: deadlines, retry/backoff, shedding, zero lost requests
# ---------------------------------------------------------------------------

def test_server_deadline_retry_shed_accounting(setup):
    task, cfg, params = setup
    srv = Server(cfg, params, EngineConfig(n_slots=4, max_len=16),
                 deadline=24.0, max_retries=2, retry_backoff=4.0,
                 queue_limit=16)
    srv.connect_trainer(lambda: (params, srv._updates + 1))
    n_sub = 24
    for _ in range(n_sub):
        srv.submit(task.sample().prompt_ids)
    # queue_limit=16 bounds the *waiting* queue (admission is lazy — no
    # step has run yet); the remaining 8 shed at the door
    assert srv.metrics()["requests_shed"] == n_sub - 16
    steps = 0
    while (srv.waiting or srv.in_flight or srv._backoff) and steps < 600:
        srv.step()
        steps += 1
        if steps % 16 == 0:
            srv.request_weight_update(streamed=True)
    m = srv.metrics()
    assert m["requests_lost"] == 0                     # the invariant
    assert m["requests_shed"] > 0
    assert m["deadline_misses"] > 0 and m["requests_retried"] > 0
    assert (m["served"] + m["requests_rejected"] + m["requests_shed"]
            == n_sub)
    assert m["retry_p99_latency"] >= m["retry_p50_latency"] >= 0.0
    # retried-but-served requests paid their backoff in the SLO metric
    retried_done = [r for r in srv.done if r.retries]
    for r in retried_done:
        assert r.latency > r.finished_at - r.submitted_at


def test_server_no_deadline_is_unchanged(setup):
    """Defaults (no deadline/retries/shed) keep the legacy behavior:
    nothing rejected, nothing retried, everything eventually served."""
    task, cfg, params = setup
    srv = Server(cfg, params, EngineConfig(n_slots=4, max_len=16))
    for _ in range(8):
        srv.submit(task.sample().prompt_ids)
    steps = 0
    while (srv.waiting or srv.in_flight) and steps < 400:
        srv.step()
        steps += 1
    m = srv.metrics()
    assert m["served"] == 8
    assert m["requests_lost"] == 0
    assert m["deadline_misses"] == m["requests_retried"] == 0
    assert m["requests_shed"] == 0
