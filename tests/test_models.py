"""Per-arch smoke tests (reduced same-family variants): one forward and one
train step on CPU, asserting output shapes and no NaNs; plus decode/forward
numerical consistency across every attention/mixer family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.core.algo import RLConfig
from repro.core.trainer import Trainer
from repro.models import model as M
from repro.sharding import tree_values

KEY = jax.random.PRNGKey(0)


def _setup(arch):
    cfg = smoke_config(get_config(arch))
    params = tree_values(M.init_params(cfg, KEY))
    return cfg, params


def _inputs(cfg, B=2, S=32):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kw = {}
    if cfg.n_prefix_tokens:
        kw["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_tokens, cfg.d_model), jnp.float32)
    return toks, pos, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg, params = _setup(arch)
    B, S = 2, 32
    toks, pos, kw = _inputs(cfg, B, S)
    out = M.forward(params, toks, pos, cfg, **kw)
    assert out["logits"].shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(out["logits"], np.float32)).all()
    if cfg.use_value_head:
        assert out["values"].shape == (B, S)
    if cfg.use_mtp:
        assert out["mtp_logits"].shape == (B, S - 1, cfg.vocab_size)


# prefix-token (prompt-tuning) archs are excluded at parametrize time
# rather than runtime-skipped: the RL trainer path is text-prompt based,
# permanently — there is nothing a skip would be waiting on
TRAIN_ARCH_IDS = [a for a in ARCH_IDS
                  if not get_config(a).n_prefix_tokens]


@pytest.mark.parametrize("arch", TRAIN_ARCH_IDS)
def test_smoke_train_step(arch):
    cfg, params = _setup(arch)
    B, S = 2, 32
    toks, pos, _ = _inputs(cfg, B, S)
    batch = {
        "tokens": toks,
        "positions": pos,
        "segment_ids": jnp.ones((B, S), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "behavior_logprobs": jnp.full((B, S), -1.0, jnp.float32),
        "rewards": jnp.ones((B, S), jnp.float32) * 0.5,
    }
    tr = Trainer(cfg, params)
    m = tr.step(batch)
    assert np.isfinite(m["loss"])
    assert np.isfinite(m["grad_norm"])
    assert tr.version == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg, params = _setup(arch)
    cfg = dataclasses.replace(cfg, use_mtp=False)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
    kw = {}
    if cfg.n_prefix_tokens:
        kw["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_tokens, cfg.d_model), jnp.float32)
    full = M.forward(params, toks, pos, cfg, **kw)
    pre = M.forward(params, toks[:, :S], pos[:, :S], cfg, return_cache=True, **kw)
    cache = pre["cache"]

    def pad(k, v):  # headroom so decode can write at index S
        if k in ("k", "v"):
            return jnp.pad(v, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
        if k in ("c_kv", "k_rope"):
            return jnp.pad(v, ((0, 0), (0, 0), (0, 4), (0, 0)))
        return v

    cache = {k: pad(k, v) for k, v in cache.items()}
    # multimodal prefix rows live at the head of the cache: offset the write
    # index and RoPE positions by n_prefix
    npre = cfg.n_prefix_tokens if cfg.n_prefix_tokens else 0
    dout = M.decode_step(params, toks[:, S:S + 1], pos[:, S:S + 1] + npre,
                         cache, jnp.int32(S + npre), cfg)
    a = np.asarray(full["logits"][:, S], np.float32)
    b = np.asarray(dout["logits"][:, 0], np.float32)
    np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_segment_ids_isolate_sequences():
    """Packed rows must not attend across segment boundaries."""
    cfg = smoke_config(get_config("llama3-8b"))
    params = tree_values(M.init_params(cfg, KEY))
    S = 32
    toks = jax.random.randint(KEY, (1, S), 3, cfg.vocab_size)
    pos = jnp.concatenate([jnp.arange(16), jnp.arange(16)])[None]
    seg = jnp.concatenate([jnp.ones(16), jnp.full(16, 2)])[None].astype(jnp.int32)
    packed = M.forward(params, toks, pos, cfg, segment_ids=seg)
    solo = M.forward(params, toks[:, 16:], pos[:, 16:], cfg,
                     segment_ids=seg[:, 16:])
    np.testing.assert_allclose(
        np.asarray(packed["logits"][0, 16:], np.float32),
        np.asarray(solo["logits"][0], np.float32), atol=2e-4, rtol=2e-4)


def test_sliding_window_limits_attention():
    cfg = dataclasses.replace(smoke_config(get_config("llama3-8b")),
                              attention_variant="sliding_window",
                              sliding_window=8)
    params = tree_values(M.init_params(cfg, KEY))
    S = 32
    toks = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    pos = jnp.arange(S)[None]
    out_w = M.forward(params, toks, pos, cfg)
    # perturb a token far outside the window of the last position
    toks2 = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab_size)
    out_w2 = M.forward(params, toks2, pos, cfg)
    last = np.asarray(out_w["logits"][0, -1], np.float32)
    last2 = np.asarray(out_w2["logits"][0, -1], np.float32)
    np.testing.assert_allclose(last, last2, atol=1e-5)
