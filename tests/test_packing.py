"""Online sequence packing: roundtrip, isolation and budget properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CPU image may lack it
from hypothesis import given, settings, strategies as st

from repro.data.packing import Rollout, pack


def _mk_rollout(rng, T, prompt_len, reward=1.0, version=3):
    return Rollout(
        tokens=rng.randint(3, 20, size=T).astype(np.int32),
        prompt_len=prompt_len,
        behavior_logprobs=rng.randn(T).astype(np.float32),
        reward=reward,
        weight_versions=np.full(T, version, np.int32),
    )


def test_pack_roundtrip_single():
    rng = np.random.RandomState(0)
    r = _mk_rollout(rng, 10, 4)
    b = pack([r], batch=2, seq=16)
    np.testing.assert_array_equal(b["tokens"][0, :10], r.tokens)
    np.testing.assert_array_equal(b["positions"][0, :10], np.arange(10))
    assert b["segment_ids"][0, 0] == 1
    assert (b["loss_mask"][0, :4] == 0).all()
    assert (b["loss_mask"][0, 4:10] == 1).all()
    assert (b["rewards"][0, :10] == 1.0).all()
    assert b["packing_stats"]["dropped"] == 0


@given(st.lists(st.integers(2, 20), min_size=1, max_size=20),
       st.integers(2, 6), st.integers(24, 64))
@settings(max_examples=40, deadline=None)
def test_pack_properties(lengths, batch, seq):
    rng = np.random.RandomState(1)
    rollouts = [_mk_rollout(rng, T, 1) for T in lengths]
    b = pack(rollouts, batch=batch, seq=seq)
    seg = b["segment_ids"]
    pos = b["positions"]
    # (1) positions restart at each segment start
    for row in range(batch):
        ids = seg[row]
        for s in np.unique(ids[ids > 0]):
            span = np.where(ids == s)[0]
            np.testing.assert_array_equal(pos[row, span],
                                          np.arange(span.size))
    # (2) packed token count + dropped == total
    packed_tokens = int((seg > 0).sum())
    total = sum(min(T, seq) for T in lengths)
    assert packed_tokens <= total
    # (3) loss never on padding
    assert (b["loss_mask"][seg == 0] == 0).all()
    # (4) fill fraction consistent
    assert b["packing_stats"]["fill"] == pytest.approx(
        packed_tokens / (batch * seq))


def test_pack_drops_when_full():
    rng = np.random.RandomState(2)
    rollouts = [_mk_rollout(rng, 16, 2) for _ in range(5)]
    b = pack(rollouts, batch=2, seq=16)
    assert b["packing_stats"]["dropped"] == 3
