"""Gray-failure self-healing (DESIGN.md §10): straggler/hang watchdog,
broadcast integrity gate, NaN-rollback training, and quarantine.

The structural claims under test:
  - a healthy run with the HealthMonitor enabled and the trainer guard
    armed is bit-identical to one with both disabled — detection only
    observes until a threshold trips
  - a wedged engine (ticks stop, no crash) is detected by the missed
    heartbeat deadline and healed through the §8 fail/salvage/requeue
    path; stranded prompts are salvaged, repeat offenders quarantined,
    and nothing is lost (salvaged == requeued + quarantined)
  - declared-slow engines in a heterogeneous pool are NEVER flagged as
    stragglers (the progress statistic is speed-normalized); a genuinely
    degraded engine is demoted in router scoring and restored when the
    degradation window ends
  - a corrupt weight chunk can never install: per-chunk checksums reject
    damaged transmissions at the engine and the shadow buffer's digest
    is verified before the pointer swap
  - a non-finite trainer step is dropped *inside* the jitted step (old
    params survive bitwise), counted, and K consecutive bad steps roll
    the trainer back to the newest intact checkpoint — rotation keeps
    fallback targets, and a truncated/corrupted file is skipped
  - the Server's quarantine terminal state is counted and covered by the
    `requests_lost == 0` invariant
"""
import hashlib
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.configs.base import HealthConfig
from repro.configs.tiny import config as tiny_config
from repro.core.events import (
    EventLoop, Fault, FaultPlan, TrainerStage, _fault_sort_key,
)
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.serving import Server
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.data.packing import Rollout, pack
from repro.models import model as M
from repro.sharding import tree_values

# same flash scale as test_faults: the healthy 4-step run spans ~600
# flashes, so fault windows below land on live decode work
HW = HardwareModel(h_sat=16, bcast_bytes_per_flash=2e3,
                   bcast_install_flash=1.0)


@pytest.fixture(scope="module")
def setup():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return task, cfg, params


def _pipe(cfg, params, plan=None, steps=4, monitor=True, guard=True,
          ckpt_dir=None, record=None, speeds=None, interval=15.0):
    """Fresh pipeline with a FRESH task (the prompt stream's RNG is part
    of replayed state — a shared task would advance between runs)."""
    task = MathTask(max_operand=5, ops="+")
    pc = PipelineConfig(
        batch_size=4, n_opt_steps=steps, n_chips=8, train_chips=4,
        pack_rows=2, pack_seq=48, n_engines=2, engine_speeds=speeds,
        ckpt_every=2 if ckpt_dir else 0, ckpt_dir=ckpt_dir,
        health=HealthConfig(enabled=monitor, interval=interval))
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=8, max_len=16),
                   pc, hw=HW, trainer=Trainer(cfg, params, guard=guard),
                   seed=0, fault_plan=plan)
    if record is not None:
        orig_put = p.queue.put

        def tap(rollouts):
            for r in rollouts:
                record.append(np.asarray(r.tokens).tobytes()
                              + np.asarray(r.weight_versions).tobytes())
            orig_put(rollouts)

        p.queue.put = tap
    p.run()
    return p


def _digest(p, rec):
    h = hashlib.sha256()
    for b in rec:
        h.update(b)
    for leaf in jax.tree.leaves(p.trainer.params):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# FaultPlan: None-safe ordering, gray builders, DSL
# ---------------------------------------------------------------------------

def test_fault_sort_key_is_total_and_none_safe():
    """engine=None vs engine=0 (and restart_after None vs float) must
    order deterministically — no TypeError, no insertion-order
    dependence."""
    f_none = Fault("chunk_corrupt", 10.0, engine=None, duration=5.0)
    f_zero = Fault("chunk_corrupt", 10.0, engine=0, duration=5.0)
    f_r = Fault("engine_crash", 10.0, engine=0, restart_after=3.0)
    f_nr = Fault("engine_crash", 10.0, engine=0)
    fwd = sorted([f_none, f_zero, f_r, f_nr], key=_fault_sort_key)
    rev = sorted([f_nr, f_r, f_zero, f_none], key=_fault_sort_key)
    assert [vars(f) for f in fwd] == [vars(f) for f in rev]
    # None (pool-wide) sorts before a targeted engine at the same time
    corr = [f for f in fwd if f.kind == "chunk_corrupt"]
    assert corr[0].engine is None and corr[1].engine == 0


def test_gray_dsl_parse():
    plan = FaultPlan.parse(
        "slow:0@300d200x4,hang:1@300r60,corrupt@300d200p0.5,"
        "nan@500x3,poison@7", n_engines=2)
    kinds = [f.kind for f in plan.faults]
    assert kinds == ["engine_slowdown", "engine_hang", "chunk_corrupt",
                     "nan_step", "poison_prompt"]
    slow, hang, corr, nan, poison = plan.faults
    assert (slow.engine, slow.at, slow.duration, slow.factor) == (
        0, 300.0, 200.0, 4.0)
    assert (hang.engine, hang.at, hang.restart_after) == (1, 300.0, 60.0)
    assert (corr.engine, corr.duration, corr.drop_prob) == (None, 200.0, 0.5)
    assert (nan.at, nan.count) == (500.0, 3)
    assert plan.poison_ordinals() == [7]
    # defaults: no restart, factor 4, full corruption
    assert FaultPlan.parse("hang:0@10").faults[0].restart_after is None
    assert FaultPlan.parse("corrupt@10d5").faults[0].drop_prob == 1.0


def test_chaos_gray_knobs_deterministic():
    kw = dict(horizon=1000.0, n_engines=2, n_crashes=1, slowdowns=1,
              hangs=1, corrupt_windows=1, nan_bursts=1, poison_prompts=1)
    a = FaultPlan.chaos(seed=11, **kw)
    b = FaultPlan.chaos(seed=11, **kw)
    c = FaultPlan.chaos(seed=12, **kw)
    assert [vars(f) for f in a.faults] == [vars(f) for f in b.faults]
    assert [vars(f) for f in a.faults] != [vars(f) for f in c.faults]
    kinds = {f.kind for f in a.faults}
    assert {"engine_slowdown", "engine_hang", "chunk_corrupt", "nan_step",
            "poison_prompt"} <= kinds
    # gray knobs default to 0: pre-§10 call signatures reproduce
    # fail-stop-only plans
    old = FaultPlan.chaos(seed=11, horizon=1000.0, n_engines=2, n_crashes=1)
    assert all(f.kind in ("engine_crash", "link_degrade")
               for f in old.faults)


def test_slowdown_factor_windows():
    plan = (FaultPlan()
            .engine_slowdown(at=10.0, duration=10.0, engine=0, factor=3.0)
            .engine_slowdown(at=15.0, duration=10.0, engine=0, factor=2.0))
    assert plan.slowdown_factor(0, 5.0) == 1.0
    assert plan.slowdown_factor(0, 12.0) == 3.0
    assert plan.slowdown_factor(0, 17.0) == 6.0   # overlap multiplies
    assert plan.slowdown_factor(1, 12.0) == 1.0   # other engine untouched
    assert plan.chunk_corrupted(0, 0, 0, 0, t=12.0) is False  # no corrupt


# ---------------------------------------------------------------------------
# healthy-path bit-equality (the §10 acceptance bar)
# ---------------------------------------------------------------------------

def test_healthy_run_bit_identical_with_watchdog_and_guard(setup):
    """Monitor enabled + trainer guard armed, no faults: rollout streams,
    per-token weight versions, and final params are bit-identical to a
    run with both disabled."""
    _, cfg, params = setup
    rec_on, rec_off = [], []
    p_on = _pipe(cfg, params, monitor=True, guard=True, record=rec_on)
    p_off = _pipe(cfg, params, monitor=False, guard=False, record=rec_off)
    assert p_on.monitor is not None and p_on.monitor.sweeps > 0
    assert p_off.monitor is None
    assert _digest(p_on, rec_on) == _digest(p_off, rec_off)
    # and the watchdog saw nothing to mitigate
    h = p_on.monitor.stats()
    assert h["hangs_detected"] == 0 and h["stragglers_demoted"] == 0
    assert p_on.pool_stats()["trainer"]["bad_steps"] == 0


# ---------------------------------------------------------------------------
# hang detection + straggler soundness
# ---------------------------------------------------------------------------

def test_hang_detected_and_healed(setup):
    _, cfg, params = setup
    plan = FaultPlan().engine_hang(at=120.0, engine=1, restart_after=60.0)
    p = _pipe(cfg, params, plan=plan)
    ps = p.pool_stats()
    h = ps["health"]
    assert h["hangs_detected"] >= 1
    assert all(lat > 0 for lat in h["hang_detect_latency"])
    kinds = [f["kind"] for f in ps["fault_log"]]
    assert "engine_hang" in kinds           # injected
    assert "engine_hang_detected" in kinds  # watchdog escalation
    assert "engine_restore" in kinds        # healed
    assert p.actors[1].hangs == 1 and p.actors[1].recoveries >= 1
    # zero-lost: every salvaged prompt requeued or counted quarantined
    assert ps["prompts_salvaged"] == (ps["prompts_requeued"]
                                      + ps["prompts_quarantined"])
    assert p.trainer.version >= 4           # the run finished


def test_declared_slow_engine_never_flagged(setup):
    """A 4x-slower *declared* engine (engine_speeds) normalizes to the
    same progress statistic as the fast one: no straggler demotion, no
    hang false-positive from its longer ticks."""
    _, cfg, params = setup
    p = _pipe(cfg, params, speeds=[1.0, 0.25], steps=4)
    h = p.pool_stats()["health"]
    assert h["sweeps"] > 0
    assert h["hangs_detected"] == 0
    assert h["stragglers_demoted"] == 0
    assert p.router.health == [1.0, 1.0]


def test_straggler_demoted_and_restored(setup):
    """A gray slowdown window (not declared — measured) demotes the
    engine in router scoring for the window and restores it after."""
    _, cfg, params = setup
    plan = FaultPlan().engine_slowdown(at=30.0, duration=600.0, engine=0,
                                       factor=8.0)
    p = _pipe(cfg, params, plan=plan, steps=8)
    h = p.pool_stats()["health"]
    assert h["stragglers_demoted"] >= 1
    assert h["stragglers_restored"] >= 1
    assert h["hangs_detected"] == 0     # slow, not dead
    assert p.router.health == [1.0, 1.0]  # restored post-window


def test_poison_prompt_quarantined(setup):
    """The poisoned prompt wedges engine after engine until its failure
    attribution crosses the threshold; then it is quarantined and the
    run completes."""
    _, cfg, params = setup
    plan = FaultPlan().poison_prompt(5)
    p = _pipe(cfg, params, plan=plan, steps=4)
    ps = p.pool_stats()
    assert ps["prompts_quarantined"] >= 1
    assert any(getattr(q, "_poison", False)
               for q in p.monitor.quarantined)
    assert ps["health"]["hangs_detected"] >= p.pc.health.quarantine_after
    assert ps["prompts_salvaged"] == (ps["prompts_requeued"]
                                      + ps["prompts_quarantined"])
    assert p.trainer.version >= 4


# ---------------------------------------------------------------------------
# broadcast integrity gate
# ---------------------------------------------------------------------------

def test_corrupt_chunks_rejected_and_retransmitted(setup):
    _, cfg, params = setup
    plan = FaultPlan(seed=5).chunk_corrupt(at=0.0, duration=1e9,
                                           drop_prob=0.5)
    p = _pipe(cfg, params, plan=plan)
    bc = p.pool_stats()["broadcast"]
    assert bc["chunks_corrupt"] > 0          # the oracle fired
    assert bc["wchunks_rejected"] > 0        # engines rejected them
    assert bc["retransmit_wait"] > 0         # backoff machinery engaged
    assert p.trainer.version >= 4            # run still completed
    # replays stay bit-equal under corruption
    recs = []
    for _ in range(2):
        rec = []
        _pipe(cfg, params, plan=plan, record=rec)
        recs.append(hashlib.sha256(b"".join(rec)).hexdigest())
    assert recs[0] == recs[1]


def test_integrity_gate_blocks_torn_install(setup):
    """Unit-level gate check: a chunk with a wrong checksum token is
    rejected (cursor does not advance), and a stream whose final digest
    mismatches is discarded without touching the live weights."""
    from repro.core.events import chunk_token, stream_digest
    from repro.core.rollout import GenerationEngine
    task, cfg, params = setup
    eng = GenerationEngine(cfg, params, EngineConfig(n_slots=2, max_len=16),
                           task.sample, seed=0)
    sizes = eng.begin_weight_stream(params, version=7, n_chunks=4)
    good = [chunk_token(7, k, sizes[k]) for k in range(len(sizes))]
    # corrupt first transmission: rejected, then the retransmit lands
    assert eng.stream_weight_chunk(token=good[0] ^ 0x5AD0BAD) is False
    assert eng.wchunks_rejected == 1
    for k in range(len(sizes)):
        done = eng.stream_weight_chunk(token=good[k])
    assert done and eng.last_stream_installed
    assert eng.version == 7
    # torn stream: correct per-chunk tokens but a digest that does not
    # match -> the pointer swap is refused
    sizes = eng.begin_weight_stream(params, version=8, n_chunks=4,
                                    expect_digest=stream_digest(good) ^ 1)
    for k in range(len(sizes)):
        done = eng.stream_weight_chunk(token=chunk_token(8, k, sizes[k]))
    assert done
    assert not eng.last_stream_installed
    assert eng.wstreams_torn == 1
    assert eng.version == 7                  # old weights survived


# ---------------------------------------------------------------------------
# NaN-robust trainer: in-step guard, skip-and-count, rollback
# ---------------------------------------------------------------------------

def _batch(cfg, seed):
    rng = np.random.default_rng(seed)
    rolls = []
    for i in range(4):
        toks = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
        rolls.append(Rollout(
            tokens=toks, prompt_len=3,
            behavior_logprobs=rng.normal(size=10).astype(np.float32) - 2.0,
            reward=float(rng.choice([-1.0, 1.0])),
            weight_versions=np.zeros(10, np.int32), prompt_key=i))
    b = pack(rolls, 2, 48)
    b.pop("packing_stats")
    return b


def test_guarded_step_bit_identical_when_healthy(setup):
    _, cfg, params = setup
    b = _batch(cfg, 1)
    tg = Trainer(cfg, params, guard=True)
    tu = Trainer(cfg, params, guard=False)
    tg.step(b)
    tu.step(b)
    assert not tg.last_nonfinite()
    for a, c in zip(jax.tree.leaves(tg.params), jax.tree.leaves(tu.params)):
        assert np.array_equal(np.asarray(a), np.asarray(c))
    assert tg.version == tu.version == 1


def test_guard_drops_poisoned_step_bitwise(setup):
    """A NaN-gradient step must not move params, opt state, or version —
    and the very next healthy step proceeds normally."""
    _, cfg, params = setup
    tr = Trainer(cfg, params, guard=True)
    tr.step(_batch(cfg, 1))
    before = jax.tree.map(np.asarray, tr.state)
    tr.step(_batch(cfg, 2), poison=True)
    assert tr.last_nonfinite()
    assert tr.nonfinite_steps == 1
    after = jax.tree.map(np.asarray, tr.state)
    for a, c in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        assert np.array_equal(a, c)
    assert tr.version == 1                   # did not advance
    tr.step(_batch(cfg, 2))
    assert not tr.last_nonfinite() and tr.version == 2


def test_nan_burst_skipped_and_rolled_back(setup, tmp_path):
    """4 consecutive poisoned steps cross the rollback threshold (3):
    the trainer restores the newest intact checkpoint and still reaches
    the target step count."""
    _, cfg, params = setup
    plan = FaultPlan().nan_step(at=360.0, count=4)
    p = _pipe(cfg, params, plan=plan, ckpt_dir=str(tmp_path))
    tr = p.pool_stats()["trainer"]
    assert tr["bad_steps"] >= 4
    assert tr["nonfinite_steps"] >= 4
    assert tr["rollbacks"] >= 1
    assert p.trainer.version >= 4


# ---------------------------------------------------------------------------
# checkpoint rotation + newest-intact fallback
# ---------------------------------------------------------------------------

def test_checkpoint_content_checksum_rejects_corruption(setup, tmp_path):
    _, cfg, params = setup
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, {"w": np.arange(8, dtype=np.float32)})
    assert checkpoint.verify(path)
    # truncation: unreadable archive
    data = open(path, "rb").read()
    open(path, "wb").write(data[:len(data) // 2])
    assert not checkpoint.verify(path)
    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.load(path, {"w": np.zeros(8, np.float32)})
    # bit rot that still unzips: flip payload bytes, keep a valid zip
    checkpoint.save(path, {"w": np.arange(8, dtype=np.float32)})
    import zipfile
    with np.load(path) as d:
        flat = dict(d)
    flat["w"] = flat["w"] + 1.0              # content changes, crc stale
    with zipfile.ZipFile(path, "w") as z:
        for k, v in flat.items():
            import io
            buf = io.BytesIO()
            np.lib.format.write_array(buf, np.asarray(v))
            z.writestr(f"{k}.npy", buf.getvalue())
    assert not checkpoint.verify(path)
    with pytest.raises(checkpoint.CheckpointError, match="checksum"):
        checkpoint.load(path, {"w": np.zeros(8, np.float32)})


def test_rotation_keeps_k_and_falls_back_to_intact(setup, tmp_path):
    """TrainerStage keeps the newest K rotated checkpoints; when the
    newest ones are truncated, restore falls back to the newest INTACT
    file and counts the corrupt ones."""
    _, cfg, params = setup
    tr = Trainer(cfg, params)
    ts = TrainerStage(EventLoop(), tr, queue=None, batch_size=0,
                      train_time=lambda n: 1.0, ckpt_dir=str(tmp_path),
                      ckpt_keep=2)
    for v in (1, 2, 3):
        ts._save_ckpt(v)
    rotated = sorted(f for f in os.listdir(tmp_path)
                     if f.startswith("trainer_step_"))
    assert rotated == ["trainer_step_000002.npz", "trainer_step_000003.npz"]
    assert os.path.exists(tmp_path / "trainer_latest.npz")
    # damage latest + newest rotated: fallback lands on step 2
    for name in ("trainer_latest.npz", "trainer_step_000003.npz"):
        f = tmp_path / name
        f.write_bytes(f.read_bytes()[:100])
    used = ts.restore_newest_intact()
    assert used is not None and used.endswith("trainer_step_000002.npz")
    assert ts.ckpts_corrupt == 2


# ---------------------------------------------------------------------------
# Server quarantine terminal state
# ---------------------------------------------------------------------------

def test_server_quarantine_accounting(setup):
    task, cfg, params = setup
    srv = Server(cfg, params, EngineConfig(n_slots=2, max_len=16))
    rids = [srv.submit(task.sample().prompt_ids) for _ in range(4)]
    for _ in range(3):
        srv.step()
    assert rids[0] in srv.in_flight or srv.done
    # quarantine one in-flight and one waiting request
    in_flight = next(iter(srv.in_flight)) if srv.in_flight else None
    waiting = srv.waiting[0].rid if srv.waiting else None
    n_q = 0
    if in_flight is not None:
        assert srv.quarantine(in_flight, reason="poison")
        n_q += 1
        # the quarantined request freed its decode slot immediately
        assert srv.engine.problems.count(None) >= 1
    if waiting is not None:
        assert srv.quarantine(waiting, reason="repeat-offender")
        n_q += 1
    assert n_q > 0
    assert not srv.quarantine(9999)          # unknown rid refused
    for _ in range(60):
        if not (srv.waiting or srv.in_flight):
            break
        srv.step()
    m = srv.metrics()
    assert m["requests_quarantined"] == n_q
    assert m["requests_lost"] == 0           # the extended invariant
    assert all(r.quarantined and r.rejected and r.fail_reason
               for r in srv.quarantined)
