"""Fused linear-cross-entropy trainer hot path (DESIGN.md §5-6): kernel
value + gradient equivalence vs the jnp twin, and end-to-end train_step
parity fused vs unfused across attention/MoE families, tied and untied."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.configs.tiny import config as tiny_config
from repro.core.trainer import Trainer, init_train_state, train_step
from repro.core.algo import RLConfig
from repro.kernels import ops, ref
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.sharding import tree_values

KEY = jax.random.PRNGKey(11)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


def _inputs(N, D, V, transpose_head, dtype):
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (N, D), dtype)
    w = jax.random.normal(
        ks[1], (V, D) if transpose_head else (D, V), dtype) * 0.3
    t = jax.random.randint(ks[2], (N,), 0, V)
    return h, w, t


@pytest.mark.parametrize("N,D,V,bn,bv", [
    (32, 64, 128, 8, 64),     # vocab tiled in two blocks
    (64, 32, 96, 128, 512),   # blocks larger than the problem
    (16, 64, 50, 8, 16),      # odd V % block remainder (50 = 3*16 + 2)
    (24, 32, 33, 4, 7),       # pathological blocks, V % block != 0
])
@pytest.mark.parametrize("transpose_head", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_logprob_value_sweep(N, D, V, bn, bv, transpose_head, dtype):
    h, w, t = _inputs(N, D, V, transpose_head, dtype)
    out = ops.fused_logprob(h, w, t, transpose_head=transpose_head,
                            block_n=bn, block_v=bv)
    exp = ref.fused_logprob_ref(h, w, t, transpose_head=transpose_head)
    for o, e, name in zip(out, exp, ("logprob", "lse", "entropy")):
        assert o.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                                   **_tol(dtype), err_msg=name)


@pytest.mark.parametrize("N,D,V,bn,bv", [
    (32, 64, 128, 8, 64),
    (16, 64, 50, 8, 16),      # odd V % block remainder
])
@pytest.mark.parametrize("transpose_head", [False, True])
def test_fused_logprob_grad_matches_twin(N, D, V, bn, bv, transpose_head):
    """Custom-VJP gradients (to hidden *and* head, through all three
    outputs) must match autodiff of the full-logits twin."""
    h, w, t = _inputs(N, D, V, transpose_head, jnp.float32)
    cts = jax.random.normal(jax.random.fold_in(KEY, 1), (3, N))

    def scalar(fn):
        def f(h, w):
            lp, lse, ent = fn(h, w)
            return (cts[0] * lp).sum() + (cts[1] * lse).sum() \
                + (cts[2] * ent).sum()
        return f

    g_k = jax.grad(scalar(lambda h, w: ops.fused_logprob(
        h, w, t, transpose_head=transpose_head, block_n=bn, block_v=bv)),
        argnums=(0, 1))(h, w)
    g_r = jax.grad(scalar(lambda h, w: ref.fused_logprob_ref(
        h, w, t, transpose_head=transpose_head)), argnums=(0, 1))(h, w)
    for a, b, name in zip(g_k, g_r, ("dhidden", "dhead")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4, err_msg=name)


@pytest.mark.parametrize("N,D,V,bv", [
    (32, 64, 128, 64),
    (16, 64, 50, 16),         # odd V % block remainder
])
@pytest.mark.parametrize("transpose_head", [False, True])
def test_blocked_twin_matches_oracle(N, D, V, bv, transpose_head):
    """The compiled lax.scan twin (the model's non-Pallas fused path) must
    match the full-logits oracle on values and gradients too."""
    from repro.kernels.fused_logprob import fused_logprob_blocked

    h, w, t = _inputs(N, D, V, transpose_head, jnp.float32)
    out = fused_logprob_blocked(h, w, t, transpose_head=transpose_head,
                                block_v=bv)
    exp = ref.fused_logprob_ref(h, w, t, transpose_head=transpose_head)
    for o, e, name in zip(out, exp, ("logprob", "lse", "entropy")):
        np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                                   atol=2e-5, rtol=2e-5, err_msg=name)

    cts = jax.random.normal(jax.random.fold_in(KEY, 3), (3, N))

    def scalar(fn):
        def f(h, w):
            lp, lse, ent = fn(h, w)
            return (cts[0] * lp).sum() + (cts[1] * lse).sum() \
                + (cts[2] * ent).sum()
        return f

    g_k = jax.grad(scalar(lambda h, w: fused_logprob_blocked(
        h, w, t, transpose_head=transpose_head, block_v=bv)),
        argnums=(0, 1))(h, w)
    g_r = jax.grad(scalar(lambda h, w: ref.fused_logprob_ref(
        h, w, t, transpose_head=transpose_head)), argnums=(0, 1))(h, w)
    for a, b, name in zip(g_k, g_r, ("dhidden", "dhead")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4, err_msg=name)


@pytest.mark.parametrize("dw_chunks", [2, 3])
@pytest.mark.parametrize("transpose_head", [False, True])
def test_fused_logprob_dw_chunks_grad_parity(dw_chunks, transpose_head):
    """Chunked dhead accumulation in the backward (dw_chunks>1 splits the
    row dim into chunks and sums per-chunk dw) must be exact vs the
    single-pass kernel (dw_chunks=1 is the unchanged original path)."""
    from repro.kernels.fused_logprob import fused_logprob

    h, w, t = _inputs(48, 32, 64, transpose_head, jnp.float32)

    def grads(dwc):
        def loss(h, w):
            lp, lse, ent = fused_logprob(h, w, t, block_n=8,
                                         transpose_head=transpose_head,
                                         dw_chunks=dwc)
            return (lp - 0.5 * lse + 0.2 * ent).sum()
        return jax.jit(jax.grad(loss, argnums=(0, 1)))(h, w)

    base = grads(1)
    got = grads(dw_chunks)
    for a, b, name in zip(got, base, ("dhidden", "dhead")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5, err_msg=name)


def test_mtp_fused_head_matches_logits_oracle():
    """MTP draft-head stats through the fused lm-head call (satellite of
    DESIGN.md §11): mtp_token_logprobs / mtp_lse / mtp_entropy must match
    the full (B,S-1,V) mtp_logits oracle, and the fused forward must not
    emit mtp_logits at all."""
    cfg = dataclasses.replace(smoke_config(get_config("deepseek-v3-671b")),
                              use_mtp=True, fused_loss=True,
                              use_pallas=False)
    params = tree_values(M.init_params(cfg, KEY))
    B, S = 2, 16
    ks = jax.random.split(jax.random.fold_in(KEY, 7), 1)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    tgt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    out = M.forward(params, tokens, positions, cfg, loss_targets=tgt)
    assert "mtp_logits" not in out and "mtp_token_logprobs" in out

    logits = M.forward(params, tokens, positions, cfg)["mtp_logits"]
    f32 = logits.astype(jnp.float32)
    ls = jax.nn.log_softmax(f32, axis=-1)
    mtp_tgt = jnp.concatenate([tokens[:, 2:], tokens[:, -1:]], axis=1)
    lp_ref = jnp.take_along_axis(ls, mtp_tgt[..., None], axis=-1)[..., 0]
    lse_ref = jax.nn.logsumexp(f32, axis=-1)
    ent_ref = lse_ref - (jax.nn.softmax(f32, -1) * f32).sum(-1)
    for got, exp, name in ((out["mtp_token_logprobs"], lp_ref, "logprob"),
                           (out["mtp_lse"], lse_ref, "lse"),
                           (out["mtp_entropy"], ent_ref, "entropy")):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   atol=2e-4, rtol=2e-4, err_msg=name)


def test_fused_logprob_grad_bf16_hidden():
    """bf16 hidden/head still accumulate gradients in f32 (loose tol only
    because the twin contracts in a different order)."""
    h, w, t = _inputs(32, 64, 96, False, jnp.bfloat16)

    def s(fn):
        return lambda h, w: sum(x.sum() for x in fn(h, w))

    g_k = jax.grad(s(lambda h, w: ops.fused_logprob(h, w, t, block_n=8,
                                                    block_v=32)),
                   argnums=(0, 1))(h, w)
    g_r = jax.grad(s(lambda h, w: ref.fused_logprob_ref(h, w, t)),
                   argnums=(0, 1))(h, w)
    for a, b in zip(g_k, g_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-2, rtol=5e-2)


# ---------------------------------------------------------------------------
# end-to-end train_step parity
# ---------------------------------------------------------------------------

def _train_batch(cfg, B=2, S=32, ragged=True):
    """Packed-batch stand-in with ragged loss_mask (second row masks a
    shorter completion) and multi-segment rows."""
    ks = jax.random.split(jax.random.fold_in(KEY, 2), 2)
    mask = np.ones((B, S), np.float32)
    mask[:, :6] = 0.0
    if ragged:
        mask[1, S // 2:] = 0.0       # row 1: shorter completion
    return {
        "tokens": np.asarray(jax.random.randint(ks[0], (B, S), 0,
                                                cfg.vocab_size), np.int32),
        "positions": np.broadcast_to(np.arange(S)[None], (B, S)).copy(),
        "segment_ids": np.ones((B, S), np.int32),
        "loss_mask": mask,
        "behavior_logprobs": np.asarray(
            jax.random.normal(ks[1], (B, S)) - 2.0, np.float32),
        "rewards": np.full((B, S), 0.5, np.float32),
    }


def _step_metrics(cfg, params, batch):
    tr = Trainer(cfg, params, rl=RLConfig(entropy_coef=0.003))
    m = tr.step(dict(batch))
    return {k: m[k] for k in ("loss", "grad_norm", "pg_loss", "entropy",
                              "token_kl", "ess")}


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v3-671b",
                                  "granite-moe-1b-a400m"])
@pytest.mark.parametrize("tied", [False, True])
def test_train_step_parity_fused_vs_unfused(arch, tied):
    """Acceptance: fused and unfused train_step agree on loss/grad-norm
    within tolerance across GQA / MLA / MoE families, tied and untied."""
    cfg = dataclasses.replace(smoke_config(get_config(arch)),
                              tie_embeddings=tied, use_mtp=False)
    params = tree_values(M.init_params(cfg, KEY))
    batch = _train_batch(cfg)
    base = _step_metrics(cfg, params, batch)
    for repl in (dict(fused_loss=True),
                 dict(fused_loss=True, use_pallas=True)):
        got = _step_metrics(dataclasses.replace(cfg, **repl), params, batch)
        for k in base:
            np.testing.assert_allclose(
                got[k], base[k], atol=2e-4, rtol=2e-4,
                err_msg=f"{arch} tied={tied} {repl} {k}")


def test_fused_train_step_jaxpr_has_no_logits():
    """The acceptance-criterion structural check: the jaxpr of the fused
    train_step contains no (B,S,V)- or (B*S,V)-shaped intermediate — the
    logits and their gradient are truly never materialized. (The unfused
    jaxpr contains several, which also validates the detector.)"""
    # sized so kernel blocks are strict sub-tiles of (B*S, V) — this only
    # traces (make_jaxpr), so the inflated shapes cost nothing
    B, S, V = 4, 128, 4096
    cfg = tiny_config(vocab_size=V, d_model=32, n_layers=1)
    params = tree_values(M.init_params(cfg, KEY))
    batch = {k: jnp.asarray(v) for k, v in _train_batch(cfg, B, S).items()}

    def avals(jaxpr):
        from jax._src import core as jcore
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                yield v.aval
            for p in eqn.params.values():
                stack = [p]
                while stack:
                    q = stack.pop()
                    if isinstance(q, jcore.ClosedJaxpr):
                        yield from avals(q.jaxpr)
                    elif isinstance(q, jcore.Jaxpr):
                        yield from avals(q)
                    elif isinstance(q, (list, tuple)):
                        stack.extend(q)

    def logits_like(cfg):
        state = init_train_state(params)
        fn = lambda st, b: train_step(st, b, cfg, RLConfig(), AdamConfig())
        jaxpr = jax.make_jaxpr(fn)(state, batch)
        return [a.shape for a in avals(jaxpr.jaxpr)
                if getattr(a, "shape", None) in ((B, S, V), (B * S, V))]

    assert logits_like(cfg)  # unfused: logits present (detector works)
    fused_cfg = dataclasses.replace(cfg, fused_loss=True, use_pallas=True,
                                    pallas_interpret=True)
    assert logits_like(fused_cfg) == []
    # the compiled blocked jnp twin (non-Pallas fused path) holds it too
    assert logits_like(dataclasses.replace(cfg, fused_loss=True)) == []


def test_trainer_metrics_stay_on_device_until_read():
    """Device-resident loop: step() must not sync; values appear on first
    access, and fetch_metrics materializes the full history."""
    cfg = tiny_config(vocab_size=37, d_model=32, n_layers=1)
    params = tree_values(M.init_params(cfg, KEY))
    tr = Trainer(cfg, params)
    batch = _train_batch(cfg)
    m1 = tr.step(dict(batch))
    m2 = tr.step(dict(batch))
    assert m1._host is None and m2._host is None   # nothing synced yet
    assert np.isfinite(m2["loss"])                 # first read syncs m2
    assert m2._host is not None and m1._host is None
    hist = tr.fetch_metrics()                      # batched sync of the rest
    assert m1._host is not None
    assert len(hist) == 2 and np.isfinite(hist[0]["grad_norm"])
    assert tr.version == 2
