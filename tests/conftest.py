import os
import signal
import threading

import jax
import pytest

from repro.configs.tiny import config as tiny_config
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values

# ---------------------------------------------------------------------------
# per-test timeout: use pytest-timeout when installed (CI), else fall back
# to a SIGALRM watchdog so a hung event loop / chaos test fails loudly
# instead of wedging the whole suite. The fallback only arms on the main
# thread of a platform that has SIGALRM (i.e. not Windows).
# ---------------------------------------------------------------------------

_TIMEOUT_S = float(os.environ.get("PYTEST_PER_TEST_TIMEOUT", "300"))

try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


def pytest_configure(config):
    if _HAVE_PYTEST_TIMEOUT and config.getoption("timeout", None) is None \
            and not config.getini("timeout"):
        config.option.timeout = _TIMEOUT_S


if not _HAVE_PYTEST_TIMEOUT:
    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        can_alarm = (hasattr(signal, "SIGALRM") and _TIMEOUT_S > 0
                     and threading.current_thread()
                     is threading.main_thread())
        if not can_alarm:
            yield
            return

        def _on_alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded {_TIMEOUT_S:.0f}s "
                f"(PYTEST_PER_TEST_TIMEOUT fallback watchdog)")

        prev = signal.signal(signal.SIGALRM, _on_alarm)
        signal.setitimer(signal.ITIMER_REAL, _TIMEOUT_S)
        try:
            yield
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, prev)


@pytest.fixture(scope="session")
def task():
    return MathTask(max_operand=5, ops="+")


@pytest.fixture(scope="session")
def tiny_cfg(task):
    return tiny_config(vocab_size=task.tok.vocab_size)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return tree_values(M.init_params(tiny_cfg, jax.random.PRNGKey(0)))
