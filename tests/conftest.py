import jax
import pytest

from repro.configs.tiny import config as tiny_config
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values


@pytest.fixture(scope="session")
def task():
    return MathTask(max_operand=5, ops="+")


@pytest.fixture(scope="session")
def tiny_cfg(task):
    return tiny_config(vocab_size=task.tok.vocab_size)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return tree_values(M.init_params(tiny_cfg, jax.random.PRNGKey(0)))
