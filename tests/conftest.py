import os
import signal
import threading

import jax
import pytest

from repro.configs.tiny import config as tiny_config
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values

# ---------------------------------------------------------------------------
# per-test timeout: use pytest-timeout when installed (CI), else fall back
# to a SIGALRM watchdog so a hung event loop / chaos test fails loudly
# instead of wedging the whole suite. The fallback only arms on the main
# thread of a platform that has SIGALRM (i.e. not Windows).
# ---------------------------------------------------------------------------

_TIMEOUT_S = float(os.environ.get("PYTEST_PER_TEST_TIMEOUT", "300"))

try:
    import pytest_timeout  # noqa: F401
    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


def pytest_configure(config):
    if _HAVE_PYTEST_TIMEOUT and config.getoption("timeout", None) is None \
            and not config.getini("timeout"):
        config.option.timeout = _TIMEOUT_S
    config.addinivalue_line(
        "markers", "dryrun: exercises the CLI dry-run path")
    config.addinivalue_line(
        "markers", "slow: long multi-stage system test")


# ---------------------------------------------------------------------------
# skip hygiene: every skip in this suite must name a reason on the
# allowlist below. Conditions that are *permanent* (an arch that cannot
# take a code path by construction) belong in the parametrization, not in
# runtime skips; what remains is exactly the optional-dependency gates,
# which CI installs and runs. A skip with any other reason fails the run
# so dead tests can't hide behind an unexplained `pytest.skip`.
# ---------------------------------------------------------------------------

_ALLOWED_SKIP_REASONS = (
    # property suites: hypothesis is absent from the slim CPU image and
    # installed in CI (test_algo, test_attention_variants, test_packing,
    # test_paged_cache, test_sim, test_substrate)
    "could not import 'hypothesis'",
    # real-mesh runtime suite (test_mesh_runtime): XLA fixes the device
    # count at backend init, so the default single-device run skips it;
    # CI's multi-device job re-runs the suite with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8
    "needs 8 devices",
)


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    outcome = yield
    rep = outcome.get_result()
    if rep.skipped and not item.get_closest_marker("skip"):
        lr = rep.longrepr
        reason = lr[2] if isinstance(lr, tuple) else str(lr)
        if not any(pat in reason for pat in _ALLOWED_SKIP_REASONS):
            rep.outcome = "failed"
            rep.longrepr = (
                f"unexplained skip: {reason!r} — either fix the test, "
                f"exclude the case at parametrize time, or add the reason "
                f"to _ALLOWED_SKIP_REASONS in tests/conftest.py")


if not _HAVE_PYTEST_TIMEOUT:
    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        can_alarm = (hasattr(signal, "SIGALRM") and _TIMEOUT_S > 0
                     and threading.current_thread()
                     is threading.main_thread())
        if not can_alarm:
            yield
            return

        def _on_alarm(signum, frame):
            raise TimeoutError(
                f"test exceeded {_TIMEOUT_S:.0f}s "
                f"(PYTEST_PER_TEST_TIMEOUT fallback watchdog)")

        prev = signal.signal(signal.SIGALRM, _on_alarm)
        signal.setitimer(signal.ITIMER_REAL, _TIMEOUT_S)
        try:
            yield
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, prev)


@pytest.fixture(scope="session")
def task():
    return MathTask(max_operand=5, ops="+")


@pytest.fixture(scope="session")
def tiny_cfg(task):
    return tiny_config(vocab_size=task.tok.vocab_size)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return tree_values(M.init_params(tiny_cfg, jax.random.PRNGKey(0)))
