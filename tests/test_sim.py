"""Appendix-A analytical model: paper case-study numbers and invariants."""
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CPU image may lack it
from hypothesis import given, settings, strategies as st

from repro.core.sim import (
    HardwareModel, best_pipeline_config, conventional_throughput,
    fig9_curves, pipeline_throughput,
)

HW = HardwareModel()


def test_train_throughput_matches_paper():
    # paper A.4: r_conv_train = 26.02 at N=128, tau=4.92
    _, _, r_train = conventional_throughput(HW, 128, 128, 134, 2048)
    assert r_train == pytest.approx(26.02, rel=0.01)


def test_case_study_conventional():
    # paper A.4: r_conv ~ 10.7, r_gen ~ 18.3 (our U(h) is a clean linear
    # ramp; the paper's measured curve has padding bumps -> ~10% tolerance)
    r_conv, r_gen, _ = conventional_throughput(HW, 128, 128, 134, 2048)
    assert r_conv == pytest.approx(10.7, rel=0.10)
    assert r_gen == pytest.approx(18.3, rel=0.10)


def test_case_study_pipeline():
    # paper A.4: best r_pipeline ~ 16.9 at g_max <= 133
    best = best_pipeline_config(HW, 128, 128, 2048, g_max_limit=133)
    assert best[0] == pytest.approx(16.9, rel=0.05)


def test_speedup_at_g133_close_to_paper():
    # paper: "PipelineRL can be up to 1.57x faster for g_max ~ 133"
    rows = {r["g_max"]: r for r in fig9_curves(HW, g_grid=(133,))}
    assert rows[133]["speedup"] == pytest.approx(1.57, rel=0.08)


@given(st.integers(2, 256))
@settings(max_examples=30, deadline=None)
def test_pipeline_never_slower_at_equal_lag(g):
    """Fig 3b/9: at equal max lag, PipelineRL throughput >= Conventional."""
    r_conv, _, _ = conventional_throughput(HW, 128, 128, max(g, 1), 2048)
    best = best_pipeline_config(HW, 128, 128, 2048, g_max_limit=g)
    if best is not None:
        assert best[0] >= r_conv * 0.98


@given(st.integers(1, 127), st.integers(1, 512))
@settings(max_examples=50, deadline=None)
def test_pipeline_throughput_is_min_of_stages(I, H):
    r, r_gen, r_train, g = pipeline_throughput(HW, 128, 128, I, H, 2048)
    assert r == pytest.approx(min(r_gen, r_train))
    assert g >= 1


def test_utilization_monotonic_saturating():
    assert HW.U(0) == 0
    assert HW.U(128) < HW.U(256)
    assert HW.U(256) == HW.U(1024) == HW.u_max
