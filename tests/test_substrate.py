"""Optimizer, checkpointing, tokenizer/task, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CPU image may lack it
from hypothesis import given, settings, strategies as st

from repro.checkpoint import checkpoint
from repro.data.math_task import MathTask
from repro.data.tokenizer import CharTokenizer
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.sharding import logical_to_spec


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

def test_adam_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adam_init(params)
    cfg = AdamConfig(lr=0.1, grad_clip=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adam_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adam_first_step_is_lr_sized():
    """Bias correction: |delta| == lr on step 1 regardless of grad scale."""
    for g in (0.01, 1.0, 100.0):
        params = {"w": jnp.zeros(())}
        state = adam_init(params)
        cfg = AdamConfig(lr=0.5, grad_clip=0.0)
        new, _, _ = adam_update(params, {"w": jnp.asarray(g)}, state, cfg)
        assert float(jnp.abs(new["w"])) == pytest.approx(0.5, rel=1e-3)


def test_adam_grad_clip():
    params = {"w": jnp.zeros((4,))}
    state = adam_init(params)
    cfg = AdamConfig(lr=1.0, grad_clip=1.0)
    _, _, gnorm = adam_update(params, {"w": jnp.full((4,), 100.0)}, state, cfg)
    assert float(gnorm) == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones(4), jnp.zeros(2)]}
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, tree)
    loaded = checkpoint.load(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# tokenizer / task
# ---------------------------------------------------------------------------

def test_tokenizer_roundtrip():
    tok = CharTokenizer()
    s = "12+(3*4)= 0"
    assert tok.decode(tok.encode(s, bos=True)) == s


def test_math_task_reward():
    task = MathTask(max_operand=9, ops="+")
    prob = task.sample()
    good = task.tok.encode(str(prob.answer)) + [task.tok.EOS]
    bad = task.tok.encode(str(prob.answer + 1)) + [task.tok.EOS]
    assert task.reward(prob, good, max_new_tokens=16) == 1.0
    assert task.reward(prob, bad, max_new_tokens=16) == 0.0


def test_math_task_soft_length_penalty():
    task = MathTask()
    prob = task.sample()
    long_completion = task.tok.encode(str(prob.answer)) + \
        [task.tok.stoi[" "]] * 14
    r = task.reward(prob, long_completion, max_new_tokens=16)
    assert r < 1.0  # penalized for approaching the limit


# ---------------------------------------------------------------------------
# sharding rules (stub mesh: logical_to_spec only reads axis_names + shape)
# ---------------------------------------------------------------------------

class _StubMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


MESH = _StubMesh((16, 16), ("data", "model"))
POD = _StubMesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_basic_tp():
    spec = logical_to_spec(("p_embed", "p_mlp"), (4096, 14336), MESH)
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_spec_divisibility_fallback():
    # 8 kv heads cannot shard over model=16 -> replicated
    spec = logical_to_spec(("p_kv_heads",), (8,), MESH)
    assert spec == jax.sharding.PartitionSpec(None)


def test_spec_axis_used_once():
    # batch takes data; cache_seq picks up the model axis (flash-decode
    # sequence parallelism, §Perf-2) but cannot reuse data
    spec = logical_to_spec(("batch", "cache_seq"), (128, 32768), MESH)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # batch=1 cannot use data; cache_seq takes both axes
    spec = logical_to_spec(("batch", "cache_seq"), (1, 524288), MESH)
    assert spec == jax.sharding.PartitionSpec(None, ("data", "model"))


def test_spec_multi_axis_batch():
    spec = logical_to_spec(("batch", "seq"), (256, 4096), POD)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"), None)


@given(st.integers(1, 4096), st.integers(1, 4096))
@settings(max_examples=60, deadline=None)
def test_spec_never_invalid(d1, d2):
    """Property: any produced spec keeps dims divisible by shard counts."""
    sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
    spec = logical_to_spec(("p_embed", "p_mlp"), (d1, d2), MESH)
    for dim, entry in zip((d1, d2), spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        total = 1
        for a in axes:
            total *= sizes[a]
        assert dim % total == 0
