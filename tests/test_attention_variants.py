"""Deeper attention-variant coverage: MLA absorbed-decode equivalence,
blocked-vs-naive flash equivalence, MoE capacity behaviour, write_cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CPU image may lack it
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, smoke_config
from repro.models import moe as moe_mod
from repro.models.attention import (
    blocked_causal_attention, _naive_causal_attention, write_cache,
)

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# blocked flash == naive reference (segment ids, windows)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 16])
def test_blocked_equals_naive(window):
    B, S, H, KV, D = 2, 256, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    blocked = blocked_causal_attention(q, k, v, scale=0.2, window=window,
                                       q_block=64, kv_block=64)
    naive = _naive_causal_attention(q, k, v, scale=0.2, window=window)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(naive),
                               atol=2e-5, rtol=2e-5)


def test_blocked_segment_ids():
    B, S, H, KV, D = 1, 128, 2, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    seg = jnp.concatenate([jnp.ones(64), jnp.full(64, 2)])[None].astype(jnp.int32)
    blocked = blocked_causal_attention(q, k, v, scale=0.25,
                                       segment_ids=seg, q_block=32,
                                       kv_block=32)
    naive = _naive_causal_attention(q, k, v, scale=0.25, segment_ids=seg)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(naive),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# ring-buffer cache writes
# ---------------------------------------------------------------------------

def test_write_cache_scalar_wraps():
    cache = jnp.zeros((2, 4, 3))
    new = jnp.ones((2, 1, 3))
    out = write_cache(cache, new, jnp.int32(5))  # 5 % 4 == 1
    assert float(out[:, 1].sum()) == 6.0
    assert float(out.sum()) == 6.0


@given(st.lists(st.integers(0, 30), min_size=2, max_size=2))
@settings(max_examples=20, deadline=None)
def test_write_cache_per_slot(idx):
    CL = 8
    cache = jnp.zeros((2, CL, 3))
    new = jnp.ones((2, 1, 3))
    out = write_cache(cache, new, jnp.asarray(idx))
    for b in range(2):
        assert float(out[b, idx[b] % CL].sum()) == 3.0
    assert float(out.sum()) == 6.0


# ---------------------------------------------------------------------------
# MoE routing invariants
# ---------------------------------------------------------------------------

def _moe_setup(T=64, E=4, k=2, d=16, F=32):
    cfg = dataclasses.replace(
        smoke_config(get_config("granite-moe-1b-a400m")),
        n_experts=E, experts_per_token=k, moe_d_ff=F, d_model=d,
        capacity_factor=2.0)
    ks = jax.random.split(KEY, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, E)),
        "gate": jax.random.normal(ks[1], (E, d, F)) * 0.05,
        "up": jax.random.normal(ks[2], (E, d, F)) * 0.05,
        "down": jax.random.normal(ks[3], (E, F, d)) * 0.05,
    }
    x = jax.random.normal(KEY, (T, d))
    return cfg, p, x


def test_moe_output_finite_and_shaped():
    cfg, p, x = _moe_setup()
    out, aux = moe_mod._moe_local(p, x, cfg, cfg.n_experts, 0, None)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-5  # Switch aux loss lower bound is 1


def test_moe_capacity_drops_tokens():
    """With capacity_factor -> tiny, most tokens overflow and get zero
    contribution (dropped), so the output norm shrinks."""
    cfg, p, x = _moe_setup()
    lo = dataclasses.replace(cfg, capacity_factor=2.0)
    hi_drop = dataclasses.replace(cfg, capacity_factor=0.01)
    out_full, _ = moe_mod._moe_local(p, x, lo, cfg.n_experts, 0, None)
    out_drop, _ = moe_mod._moe_local(p, x, hi_drop, cfg.n_experts, 0, None)
    assert float(jnp.linalg.norm(out_drop)) < float(jnp.linalg.norm(out_full))


def test_moe_expert_partition_sums_to_whole():
    """Sum of per-shard contributions (disjoint expert ranges) must equal
    the all-experts-local result — the shard_map psum invariant."""
    cfg, p, x = _moe_setup(E=4)
    full, _ = moe_mod._moe_local(p, x, cfg, 4, 0, None)
    parts = []
    for off in (0, 2):
        pl = {k: (v[off:off + 2] if k != "router" else v)
              for k, v in p.items()}
        part, _ = moe_mod._moe_local(pl, x, cfg, 2, off, None)
        parts.append(part)
    np.testing.assert_allclose(np.asarray(sum(parts)), np.asarray(full),
                               atol=1e-5, rtol=1e-5)
