"""Lag-aware training path (DESIGN.md §12): the typed staleness contract
engine -> pack -> loss, the staleness-corrected objectives, and the
periodic-asynchrony bounded-staleness barrier.

Structural claims under test:
  - on-policy parity: lag_mode="off" — and every armed mode on an
    all-lag-0 batch — produces bit-identical loss, gradients, and shared
    metrics to the historical objective (the modes are trace-time
    branches built from exact identities, not epsilon-close rewrites)
  - an all-masked batch is an explicit zero-loss no-op (zero grads,
    empty_batch metric), not a 1e-30-epsilon artifact
  - pack() stamps lag exactly: elementwise trainer_version - stamp on
    completion positions, 0 elsewhere, across streamed installs /
    preemption resumes, slots & paged caches, 1/2-engine pools
  - max_lag=B guarantees no trained token exceeds B (hard mask), down to
    B=0 reproducing conventional-RL all-fresh batches, while the actor
    gate engages to throttle stale sampling
  - Server.metrics() reports per-request weight-lag; PipelineRL
    .lag_stats() is self-consistent (histogram mass == trained tokens)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.tiny import config as tiny_config
from repro.core.algo import RLConfig, ess, reinforce_loss, token_logprobs
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.serving import Server
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.data.packing import Rollout, pack
from repro.models import model as M
from repro.sharding import tree_values

# slow interconnect (same knob as test_faults): streamed installs span
# many decode steps, so rollouts routinely cross a version boundary and
# the lag gate's wait times are visible
HW = HardwareModel(h_sat=16, bcast_bytes_per_flash=2e3,
                   bcast_install_flash=1.0)


@pytest.fixture(scope="module")
def setup():
    task = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=64, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    return task, cfg, params


def _fake_batch(key, B=2, S=16, V=11, off_policy=0.0):
    ks = jax.random.split(key, 2)
    logits = jax.random.normal(ks[0], (B, S, V))
    tokens = jax.random.randint(ks[1], (B, S), 0, V)
    mask = jnp.ones((B, S)).at[:, :4].set(0.0)
    beh = token_logprobs(logits, tokens) + off_policy
    return logits, {
        "tokens": tokens, "loss_mask": mask,
        "behavior_logprobs": beh,
        "rewards": jnp.ones((B, S)) * 0.5,
    }


def _loss_grads_metrics(logits, batch, cfg):
    def f(lg):
        return reinforce_loss(lg, None, batch, cfg)
    (loss, metrics), grads = jax.value_and_grad(f, has_aux=True)(logits)
    return np.asarray(loss), np.asarray(grads), \
        {k: np.asarray(v) for k, v in metrics.items()}


# ---------------------------------------------------------------------------
# on-policy parity: armed modes with lag==0 are BITWISE the off path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["token_is", "truncated"])
def test_armed_mode_zero_lag_bitwise_parity(mode):
    """decay**0 == 1, mask*1.0, where(True, x, _) are exact identities:
    an armed objective on an all-fresh batch must match "off" to the bit
    — loss, gradient, and every shared metric."""
    logits, batch = _fake_batch(jax.random.PRNGKey(1), off_policy=0.3)
    l0, g0, m0 = _loss_grads_metrics(logits, batch, RLConfig())
    lagged = dict(batch, lag=jnp.zeros_like(batch["loss_mask"]),
                  truncated=jnp.zeros_like(batch["loss_mask"]))
    l1, g1, m1 = _loss_grads_metrics(logits, lagged,
                                     RLConfig(lag_mode=mode))
    assert l0.tobytes() == l1.tobytes()
    assert g0.tobytes() == g1.tobytes()
    for k in m0:   # armed mode adds bucket metrics; shared keys are exact
        assert m0[k].tobytes() == m1[k].tobytes(), k


@pytest.mark.parametrize("mode", ["token_is", "truncated"])
def test_armed_mode_missing_lag_field_falls_back_fresh(mode):
    """Legacy callers pack no lag field: armed modes treat the batch as
    all-fresh (zeros fallback) instead of crashing — still bit-equal."""
    logits, batch = _fake_batch(jax.random.PRNGKey(2), off_policy=0.3)
    l0, g0, _ = _loss_grads_metrics(logits, batch, RLConfig())
    l1, g1, _ = _loss_grads_metrics(logits, batch, RLConfig(lag_mode=mode))
    assert l0.tobytes() == l1.tobytes()
    assert g0.tobytes() == g1.tobytes()


def test_off_mode_ignores_lag_fields():
    """off never reads the lag fields: a wildly stale batch changes
    nothing (the trainer additionally drops the fields pre-jit)."""
    logits, batch = _fake_batch(jax.random.PRNGKey(3), off_policy=0.3)
    l0, g0, _ = _loss_grads_metrics(logits, batch, RLConfig())
    stale = dict(batch, lag=jnp.full_like(batch["loss_mask"], 50.0),
                 truncated=jnp.ones_like(batch["loss_mask"]))
    l1, g1, _ = _loss_grads_metrics(logits, stale, RLConfig())
    assert l0.tobytes() == l1.tobytes()
    assert g0.tobytes() == g1.tobytes()


# ---------------------------------------------------------------------------
# the armed modes actually bite on stale tokens
# ---------------------------------------------------------------------------

def test_token_is_lag_conditional_clamp_tightens():
    """Huge ratios everywhere: fresh tokens clip at is_clamp, stale
    tokens at the decayed ceiling — mean clamped weight must drop as lag
    grows, flooring at lag_clamp_min."""
    logits, batch = _fake_batch(jax.random.PRNGKey(4))
    batch["behavior_logprobs"] = batch["behavior_logprobs"] - 5.0
    cfg = RLConfig(lag_mode="token_is", is_clamp=4.0,
                   lag_clamp_decay=0.5, lag_clamp_min=1.0)

    def pg_at(lag_val):
        b = dict(batch, lag=jnp.full_like(batch["loss_mask"], lag_val))
        _, m = reinforce_loss(logits, None, b, cfg)
        return float(m["pg_loss"])

    # pg_loss = -mean(clamp * adv * lp): |pg| shrinks as the clamp decays
    assert abs(pg_at(1)) < abs(pg_at(0))
    assert abs(pg_at(2)) < abs(pg_at(1))
    # floor: beyond the decay horizon the clamp is lag_clamp_min exactly
    assert pg_at(10) == pg_at(20)
    # and clip_frac counts against the per-token ceiling
    b = dict(batch, lag=jnp.full_like(batch["loss_mask"], 10.0))
    _, m = reinforce_loss(logits, None, b, cfg)
    assert float(m["clip_frac"]) == pytest.approx(1.0)


def test_truncated_mode_masks_beyond_horizon():
    logits, batch = _fake_batch(jax.random.PRNGKey(5), off_policy=0.2)
    cfg = RLConfig(lag_mode="truncated", lag_horizon=4)
    # every completion token over the horizon: objective empties out
    b = dict(batch, lag=jnp.full_like(batch["loss_mask"], 5.0))
    loss, m = reinforce_loss(logits, None, b, cfg)
    assert float(loss) == 0.0 and float(m["empty_batch"]) == 1.0
    # exactly at the horizon: everything kept, parity with off
    b = dict(batch, lag=jnp.full_like(batch["loss_mask"], 4.0))
    l1, m1 = reinforce_loss(logits, None, b, cfg)
    l0, _ = reinforce_loss(logits, None, batch, RLConfig())
    assert np.asarray(l1).tobytes() == np.asarray(l0).tobytes()
    assert float(m1["empty_batch"]) == 0.0


def test_truncated_weight_downweights_truncated_rollouts():
    logits, batch = _fake_batch(jax.random.PRNGKey(6), off_policy=0.2)
    lag0 = jnp.zeros_like(batch["loss_mask"])
    # mixed batch: row 1 hit max_len, row 0 finished cleanly — uniform
    # downweighting would cancel in the mask-normalized pg, a *mixed*
    # batch shifts the balance toward the untruncated row
    tr = jnp.zeros_like(lag0).at[1, :].set(1.0)
    mixed = dict(batch, lag=lag0, truncated=tr)
    cfg_half = RLConfig(lag_mode="truncated", truncated_weight=0.5)
    _, m_half = reinforce_loss(logits, None, mixed, cfg_half)
    _, m_full = reinforce_loss(logits, None, mixed,
                               RLConfig(lag_mode="truncated"))
    assert float(m_half["pg_loss"]) != float(m_full["pg_loss"])
    # weight 1.0 is the exact no-op even with the flag set
    _, m_off = reinforce_loss(logits, None, batch, RLConfig())
    assert np.asarray(m_full["pg_loss"]).tobytes() \
        == np.asarray(m_off["pg_loss"]).tobytes()


def test_bucket_metrics_partition_the_mask():
    """Per-lag-bucket ESS/clamp: tokens land in exactly one bucket, empty
    buckets report 0, and a two-population batch shows per-bucket ESS
    where the global ESS blurs them."""
    logits, batch = _fake_batch(jax.random.PRNGKey(7), B=2, S=16)
    lag = jnp.zeros((2, 16)).at[1, :].set(4.0)     # row 0 fresh, row 1 stale
    b = dict(batch, lag=lag)
    # non-constant drift on the stale row only (ESS is scale-invariant,
    # so a constant shift would still read 1.0)
    noise = jax.random.normal(jax.random.PRNGKey(70), (2, 16)) * 0.5
    b["behavior_logprobs"] = batch["behavior_logprobs"] \
        + noise * (lag > 0)
    cfg = RLConfig(lag_mode="token_is")
    _, m = reinforce_loss(logits, None, b, cfg)
    assert float(m["ess_lag0"]) == pytest.approx(1.0, abs=1e-5)  # on-policy
    assert float(m["ess_lag4"]) < 0.999                          # shifted
    for empty in (1, 2, 8):
        assert float(m[f"ess_lag{empty}"]) == 0.0
        assert float(m[f"clamp_lag{empty}"]) == 0.0


# ---------------------------------------------------------------------------
# degenerate all-masked batch: explicit no-op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["off", "token_is", "truncated"])
def test_all_masked_batch_is_zero_loss_noop(mode):
    logits, batch = _fake_batch(jax.random.PRNGKey(8))
    batch["loss_mask"] = jnp.zeros_like(batch["loss_mask"])
    if mode != "off":
        batch["lag"] = jnp.zeros_like(batch["loss_mask"])
    loss, grads, m = _loss_grads_metrics(logits, batch,
                                         RLConfig(lag_mode=mode))
    assert loss == 0.0
    assert float(m["empty_batch"]) == 1.0
    assert float(m["ess"]) == 0.0
    assert np.all(grads == 0.0) and np.all(np.isfinite(grads))


def test_ess_zero_mask_is_zero():
    assert float(ess(jnp.ones((2, 8)), jnp.zeros((2, 8)))) == 0.0


# ---------------------------------------------------------------------------
# pack(): the typed staleness contract
# ---------------------------------------------------------------------------

def _rollout(tokens, prompt_len, versions, truncated=False):
    t = np.asarray(tokens, np.int32)
    return Rollout(tokens=t, prompt_len=prompt_len,
                   behavior_logprobs=np.zeros(len(t), np.float32),
                   reward=1.0,
                   weight_versions=np.asarray(versions, np.int32),
                   truncated=truncated)


def test_pack_lag_fields_exact():
    # mixed-version rollout: prompt stamped 0, completion crosses 3 -> 5
    r1 = _rollout([5, 6, 7, 8, 9, 2], 2, [0, 0, 3, 3, 4, 5], truncated=False)
    r2 = _rollout([5, 6, 7, 8], 2, [0, 0, 5, 5], truncated=True)
    out = pack([r1, r2], 1, 16, trainer_version=6)
    lag, mask = out["lag"], out["loss_mask"]
    # elementwise: trainer_version - stamp on loss positions, 0 elsewhere
    exp = np.zeros(16, np.int32)
    exp[2:6] = 6 - np.array([3, 3, 4, 5])    # r1 completion
    exp[8:10] = 6 - np.array([5, 5])         # r2 completion
    np.testing.assert_array_equal(lag[0], exp)
    assert np.all(lag[mask == 0] == 0)
    # per-segment truncated flag broadcast over the segment's tokens
    np.testing.assert_array_equal(out["truncated"][0, :6], 0.0)
    np.testing.assert_array_equal(out["truncated"][0, 6:10], 1.0)
    assert out["packing_stats"].get("lag_masked", 0) == 0


def test_pack_without_version_is_legacy_bytes():
    r = _rollout([5, 6, 7, 2], 1, [0, 1, 1, 2])
    legacy = pack([r], 1, 8)
    assert "lag" not in legacy and "truncated" not in legacy
    assert "lag_masked" not in legacy["packing_stats"]
    typed = pack([r], 1, 8, trainer_version=3)
    for k in legacy:
        if k == "packing_stats":
            continue
        assert legacy[k].tobytes() == typed[k].tobytes(), k


def test_pack_max_lag_hard_masks_and_counts():
    r = _rollout([5, 6, 7, 8, 9, 2], 2, [0, 0, 1, 2, 3, 4])
    out = pack([r], 1, 8, trainer_version=5, max_lag=2)
    # lags on completion: 4,3,2,1 -> the first two exceed the bound
    assert out["packing_stats"]["lag_masked"] == 2
    np.testing.assert_array_equal(out["loss_mask"][0, :6],
                                  [0, 0, 0, 0, 1, 1])
    # the lag field itself is preserved (observability), only loss masked
    np.testing.assert_array_equal(out["lag"][0, 2:6], [4, 3, 2, 1])
    # rollback safety: stamps from the future clip at lag 0
    fut = pack([r], 1, 8, trainer_version=0)
    assert fut["lag"].min() == 0


# ---------------------------------------------------------------------------
# end-to-end stamp exactness: engine -> queue -> pack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cache", ["slots", "paged"])
@pytest.mark.parametrize("n_engines", [1, 2])
def test_stamp_exactness_across_streamed_installs(setup, cache, n_engines):
    """Rollouts that cross a streamed install + preemption resume carry
    per-token stamps such that pack(trainer_version=V) reproduces
    lag == V - stamp elementwise — no off-by-one at install boundaries,
    for both cache backends and pool sizes."""
    task, cfg, params = setup
    rec = []
    p = PipelineRL(
        cfg, params, task,
        EngineConfig(n_slots=8, max_len=16, cache=cache),
        PipelineConfig(batch_size=4, n_opt_steps=6, n_chips=8,
                       train_chips=4, pack_rows=2, pack_seq=48,
                       n_engines=n_engines, broadcast="streamed"),
        hw=HW)
    orig_put = p.queue.put

    def tap(rollouts):
        rec.extend(rollouts)
        orig_put(rollouts)

    p.queue.put = tap
    p.run()
    assert rec
    # the slow interconnect forces mid-decode installs: some rollout must
    # have sampled under >= 2 distinct versions
    stamps = [np.unique(r.weight_versions[r.prompt_len:]) for r in rec]
    assert any(len(s) >= 2 for s in stamps)
    V = p.trainer.version + 3   # arbitrary reference version
    out = pack(rec, 8, 48, trainer_version=V)
    comp = out["loss_mask"] > 0
    expect = np.maximum(V - out["weight_versions"], 0) * comp
    np.testing.assert_array_equal(out["lag"], expect.astype(np.int32))
    assert np.all(out["lag"][~comp] == 0)


# ---------------------------------------------------------------------------
# periodic asynchrony: the max_lag barrier
# ---------------------------------------------------------------------------

def _bounded_pipe(setup, bound, steps=4, broadcast="streamed"):
    task, cfg, params = setup
    return PipelineRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=16),
        PipelineConfig(batch_size=4, n_opt_steps=steps, n_chips=8,
                       train_chips=4, pack_rows=2, pack_seq=48,
                       n_engines=2, broadcast=broadcast, max_lag=bound),
        hw=HW,
        trainer=Trainer(cfg, params, rl=RLConfig(lag_mode="token_is")))


@pytest.mark.parametrize("bound", [0, 2])
def test_max_lag_bounds_every_trained_token(setup, bound):
    p = _bounded_pipe(setup, bound)
    log = p.run()
    assert len(log) == 4
    ls = p.lag_stats()
    assert ls["bound"] == bound
    assert ls["trained_tokens"] > 0
    # the hard guarantee, read from the packed lag fields: no trained
    # token ever exceeds the bound
    assert ls["histogram"] and max(ls["histogram"]) <= bound
    assert ls["max_lag"] <= bound
    # the gate engaged (this HW makes unbounded runs reach lag > 2)
    assert ls["gate"]["blocks"] > 0
    assert sum(ls["histogram"].values()) == ls["trained_tokens"]


def test_max_lag_zero_is_conventional_all_fresh(setup):
    """bound 0 = conventional-RL lockstep: every trained token sampled
    under the learner's current weights."""
    p = _bounded_pipe(setup, 0)
    p.run()
    ls = p.lag_stats()
    assert set(ls["histogram"]) == {0}
    # per-step log agrees with the packed fields
    assert all(r["max_lag"] == 0 and r["mean_lag"] == 0 for r in p.log)


def test_bound_interpolates_throughput_and_lag(setup):
    """Loosening the bound buys sim time back and widens the lag
    distribution: the conventional <-> free-running interpolation."""
    runs = {b: _bounded_pipe(setup, b) for b in (0, None)}
    for p in runs.values():
        p.run()
    t0 = runs[0].log[-1]["time"]
    t_free = runs[None].log[-1]["time"]
    assert t_free < t0                       # barrier costs wall-clock
    free_ls = runs[None].lag_stats()
    assert free_ls["max_lag"] > 0            # staleness exists unbounded
    assert free_ls["masked_tokens"] == 0     # no bound, nothing masked
    assert runs[0].lag_stats()["gate"]["parks"] > 0


def test_max_lag_validation(setup):
    task, cfg, params = setup
    ec = EngineConfig(n_slots=8, max_len=16)
    with pytest.raises(ValueError):
        PipelineRL(cfg, params, task, ec,
                   PipelineConfig(batch_size=4, n_opt_steps=2, n_chips=8,
                                  train_chips=4, pack_rows=2, pack_seq=48,
                                  max_lag=-1))
    # unpublished versions would park the pool forever
    with pytest.raises(ValueError):
        PipelineRL(cfg, params, task, ec,
                   PipelineConfig(batch_size=4, n_opt_steps=2, n_chips=8,
                                  train_chips=4, pack_rows=2, pack_seq=48,
                                  max_lag=1, update_every=2))


def test_lag_stats_unbounded_invariants(setup):
    task, cfg, params = setup
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=8, max_len=16),
                   PipelineConfig(batch_size=4, n_opt_steps=4, n_chips=8,
                                  train_chips=4, pack_rows=2, pack_seq=48,
                                  n_engines=2), hw=HW)
    p.run()
    ls = p.lag_stats()
    assert ls["bound"] is None and "gate" not in ls
    assert sum(ls["histogram"].values()) == ls["trained_tokens"] > 0
    assert 0 <= ls["mean_lag"] <= ls["max_lag"]
    for e in ls["engines"]:
        assert e["behind"] >= 0
        assert e["lag_pauses"] == 0          # no gate armed
    # per-step log lag agrees with the histogram's support
    assert max(r["max_lag"] for r in p.log) == ls["max_lag"]


# ---------------------------------------------------------------------------
# Server: per-request weight-lag metrics
# ---------------------------------------------------------------------------

def test_server_request_lag_metrics(setup):
    task, cfg, params = setup
    params2 = tree_values(M.init_params(cfg, jax.random.PRNGKey(9)))
    srv = Server(cfg, params, EngineConfig(n_slots=4, max_len=16))
    srv.connect_trainer(lambda: (params2, 3))
    for _ in range(8):
        srv.submit(task.sample().prompt_ids)
    for i in range(200):
        if i == 5:
            srv.request_weight_update()
        srv.step()
        if len(srv.done) == 8:
            break
    m = srv.metrics()
    # the in-flight swap produced mixed-version requests, and the stats
    # summarize the within-request spread newest - per-token stamp
    assert m["requests_mixed_version"] >= 1
    assert m["request_lag_max"] >= 1.0
    assert 0.0 < m["request_lag_mean"] <= m["request_lag_max"]
    # and they match a direct recomputation from the stamps
    maxes = [float((r.weight_versions.max() - r.weight_versions).max())
             for r in srv.done if r.weight_versions is not None
             and len(r.weight_versions)]
    assert m["request_lag_max"] == max(maxes)


def test_server_request_lag_zero_without_updates(setup):
    task, cfg, params = setup
    srv = Server(cfg, params, EngineConfig(n_slots=4, max_len=16))
    for _ in range(4):
        srv.submit(task.sample().prompt_ids)
    for _ in range(200):
        srv.step()
        if len(srv.done) == 4:
            break
    m = srv.metrics()
    assert m["request_lag_mean"] == 0.0
    assert m["request_lag_max"] == 0.0
    assert m["requests_mixed_version"] == 0
