"""One benchmark per paper table/figure. Each returns (name, us_per_call,
derived) rows for the CSV emitted by benchmarks.run."""
from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import tiny_setup, time_call
from repro.core.algo import RLConfig
from repro.core.conventional import ConventionalConfig, ConventionalRL
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.sim import HardwareModel, conventional_throughput, fig9_curves
from repro.core.trainer import Trainer
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.sharding import tree_values

Row = Tuple[str, float, str]
FAST = os.environ.get("BENCH_FAST", "1") == "1"


# ---------------------------------------------------------------------------
# Fig. 2: generation throughput / batch-size decay during a drain
# ---------------------------------------------------------------------------

def fig2_generation() -> List[Row]:
    task, cfg, params = tiny_setup()
    rows: List[Row] = []
    # (a) throughput vs batch size (real decode-step wall time on CPU)
    for H in (4, 16, 64):
        ec = EngineConfig(n_slots=H, max_len=16)
        eng = GenerationEngine(cfg, params, ec, task.sample, seed=0)
        eng.refill()
        us, _ = time_call(lambda: eng.step(task), iters=5, warmup=2)
        rows.append((f"fig2a/decode_step_H{H}", us,
                     f"tokens_per_step={H}"))
    # (b) batch size decays as sequences finish (drain, no refill)
    ec = EngineConfig(n_slots=32, max_len=20)
    eng = GenerationEngine(cfg, params, ec, task.sample, seed=1)
    eng.refill()
    decay = []
    for _ in range(24):
        decay.append(eng.n_active)
        eng.step(task)
        if eng.n_active == 0:
            break
    rows.append(("fig2b/drain_batch_decay", 0.0,
                 "active=" + "|".join(map(str, decay))))
    return rows


# ---------------------------------------------------------------------------
# Fig. 5: learning speed — PipelineRL vs Conventional (R(t) and R(S))
# ---------------------------------------------------------------------------

def fig5_learning() -> List[Row]:
    """CPU-scale twin of the paper's 128-GPU comparison. The hardware model
    is scaled so the toy per-chip batches sit where the paper's H100 batches
    sit on U(h): h_sat=16 plays the role of the H100's h_sat~256. The
    pipeline concentrates generation on N-T chips at a saturating slot count
    (H=64 -> 16/chip) while Conventional RL spreads B*G sequences over all N
    chips (4/chip, underutilized) and pays the drain tail — the exact
    mechanism of the paper's ~2x (Fig. 5a/5c)."""
    steps = 10 if FAST else 60
    rows: List[Row] = []
    results: Dict[str, list] = {}
    hw = HardwareModel(h_sat=16)

    task, cfg, params = tiny_setup(d_model=96, n_layers=2)
    t0 = time.perf_counter()
    trainer = Trainer(cfg, params, rl=RLConfig(entropy_coef=0.003),
                      adam=AdamConfig(lr=3e-3))
    # balanced stage rates (Appendix A.3): r_gen(U(24/3)*3) ~ r_train(5/tau);
    # N=8 is the paper's "scarce compute" limitation regime, so the co-sim
    # gain is modest — the full-scale 1.57x/2x claims are validated by the
    # fig9 analytic reproduction at N=128
    p = PipelineRL(cfg, params, task,
                   EngineConfig(n_slots=24, max_len=16),
                   PipelineConfig(batch_size=16, n_opt_steps=steps,
                                  n_chips=8, train_chips=5,
                                  pack_rows=4, pack_seq=80),
                   hw=hw, trainer=trainer)
    log = p.run()
    results["pipeline"] = log
    rows.append(("fig5/pipeline", (time.perf_counter() - t0) * 1e6 / steps,
                 f"simtime={log[-1]['time']:.0f}f reward_last="
                 f"{np.mean([r['reward'] for r in log[-5:]]):.3f} "
                 f"max_lag={max(r['max_lag'] for r in log):.0f}"))

    for G in (2, 4, 8):
        task, cfg, params = tiny_setup(d_model=96, n_layers=2)
        t0 = time.perf_counter()
        trainer = Trainer(cfg, params, rl=RLConfig(entropy_coef=0.003),
                          adam=AdamConfig(lr=3e-3))
        c = ConventionalRL(cfg, params, task,
                           EngineConfig(n_slots=16, max_len=16),
                           ConventionalConfig(batch_size=16, g_steps=G,
                                              n_opt_steps=steps, n_chips=8,
                                              pack_rows=4, pack_seq=80),
                           hw=hw, trainer=trainer)
        log = c.run()
        results[f"conv_G{G}"] = log
        rows.append((f"fig5/conventional_G{G}",
                     (time.perf_counter() - t0) * 1e6 / steps,
                     f"simtime={log[-1]['time']:.0f}f reward_last="
                     f"{np.mean([r['reward'] for r in log[-5:]]):.3f}"))

    # headline: sim wall-clock to process the same number of samples.
    # the matched-lag comparison is G=8 (pipeline max_lag ~ 8, Fig 5b/6a)
    tp = results["pipeline"][-1]["time"]
    for G in (2, 4, 8):
        tc = results[f"conv_G{G}"][-1]["time"]
        rows.append((f"fig5/speedup_vs_G{G}", 0.0,
                     f"pipeline_t={tp:.0f} conv_t={tc:.0f} "
                     f"speedup={tc / tp:.2f}x"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 6: max lag and ESS over training
# ---------------------------------------------------------------------------

def fig6_lag_ess() -> List[Row]:
    steps = 8 if FAST else 40
    rows: List[Row] = []
    task, cfg, params = tiny_setup()
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    p = PipelineRL(cfg, params, task,
                   EngineConfig(n_slots=16, max_len=16),
                   PipelineConfig(batch_size=8, n_opt_steps=steps, n_chips=8,
                                  train_chips=4, pack_rows=3, pack_seq=64),
                   trainer=trainer)
    plog = p.run()
    rows.append(("fig6a/pipeline_max_lag", 0.0,
                 f"max={max(r['max_lag'] for r in plog):.0f} "
                 f"mean={np.mean([r['mean_lag'] for r in plog]):.2f}"))
    rows.append(("fig6b/pipeline_ess", 0.0,
                 f"min={min(r['ess'] for r in plog):.3f} "
                 f"mean={np.mean([r['ess'] for r in plog]):.3f}"))

    for G in (4, 8):  # fig10 mechanism: ESS decays as G grows
        task, cfg, params = tiny_setup()
        trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
        c = ConventionalRL(cfg, params, task,
                           EngineConfig(n_slots=16, max_len=16),
                           ConventionalConfig(batch_size=8, g_steps=G,
                                              n_opt_steps=steps, n_chips=8,
                                              pack_rows=3, pack_seq=64),
                           trainer=trainer)
        clog = c.run()
        rows.append((f"fig6a/conv_G{G}_max_lag", 0.0,
                     f"max={max(r['max_lag'] for r in clog):.0f}"))
        rows.append((f"fig6b/conv_G{G}_ess", 0.0,
                     f"min={min(r['ess'] for r in clog):.3f} "
                     f"mean={np.mean([r['ess'] for r in clog]):.3f}"))
    return rows


# ---------------------------------------------------------------------------
# beyond-paper ablation: in-flight update frequency (paper §4 discussion:
# "depending on how frequently one can make weight updates")
# ---------------------------------------------------------------------------

def ablation_update_every() -> List[Row]:
    steps = 8 if FAST else 24
    rows: List[Row] = []
    for every in (1, 2, 4):
        task, cfg, params = tiny_setup()
        trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
        p = PipelineRL(cfg, params, task,
                       EngineConfig(n_slots=16, max_len=16),
                       PipelineConfig(batch_size=8, n_opt_steps=steps,
                                      n_chips=8, train_chips=4, pack_rows=3,
                                      pack_seq=64, update_every=every),
                       trainer=trainer)
        log = p.run()
        rows.append((f"ablation/update_every_{every}", 0.0,
                     f"max_lag={max(r['max_lag'] for r in log):.0f} "
                     f"mean_lag={np.mean([r['mean_lag'] for r in log]):.2f} "
                     f"ess={np.mean([r['ess'] for r in log]):.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 7 (§5.1): KL of mixed-policy (in-flight, stale KV) vs lagged policies
# ---------------------------------------------------------------------------

def fig7_kl() -> List[Row]:
    """Train a few checkpoints C_0..C_g; compare behavior distributions:
      - conventional lag k: sample everything from C_0, evaluate under C_g
      - in-flight (stale KV): swap weights every L/g tokens during sampling
      - in-flight + recomputed KV: same but recompute the cache at each swap
    KL estimated as E_mu[log mu - log pi_final] over sampled tokens."""
    g_max = 4
    task, cfg, params = tiny_setup(d_model=96, n_layers=2)
    # moderate per-step weight deltas: the paper's regime is lr 1e-6 on a 7B
    # model; too-large deltas make the stale-KV perturbation dominate, too
    # small ones drown the KL in Monte-Carlo noise
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=7e-4),
                      rl=RLConfig(entropy_coef=0.003))
    # build consecutive checkpoints with real RL training
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=16, max_len=16),
                   PipelineConfig(batch_size=8, n_opt_steps=1, n_chips=8,
                                  train_chips=4, pack_rows=3, pack_seq=64),
                   trainer=trainer)
    ckpts = [trainer.state.params]
    for _ in range(g_max):
        p.run(trainer.version + 1)
        ckpts.append(trainer.state.params)

    def sample_and_eval(update_schedule, recompute):
        """update_schedule: list of (step_index, ckpt_index)."""
        ec = EngineConfig(n_slots=128, max_len=24)
        eng = GenerationEngine(cfg, ckpts[0], ec, task.sample, seed=11)
        eng.refill()
        sched = dict(update_schedule)
        rollouts = []
        for step in range(96):
            if step in sched:
                eng.set_weights(ckpts[sched[step]], sched[step],
                                recompute_kv=recompute)
            rollouts.extend(eng.step(task))
            if eng.n_active == 0:
                break
        # evaluate the sampled tokens under the final checkpoint
        tot, n = 0.0, 0
        final = ckpts[g_max]
        for r in rollouts:
            T = r.length
            toks = jnp.asarray(r.tokens)[None]
            pos = jnp.arange(T)[None]
            out = M.forward(final, toks, pos, cfg)
            lp = jax.nn.log_softmax(out["logits"][0].astype(jnp.float32), -1)
            for t in range(r.prompt_len, T):
                cur = float(lp[t - 1, r.tokens[t]])
                tot += r.behavior_logprobs[t] - cur
                n += 1
        return tot / max(n, 1)

    L = 24  # == EngineConfig.max_len of sample_and_eval
    inflight_sched = [(max(1, (k + 1) * L // (g_max + 1)), k + 1)
                      for k in range(g_max)]
    rows: List[Row] = []
    for lag in (g_max, g_max // 2, 0):
        kl = sample_and_eval([(0, g_max - lag)], recompute=False)
        rows.append((f"fig7/conventional_lag{lag}", 0.0, f"kl={kl:.4f}"))
    kl_inflight = sample_and_eval(inflight_sched, recompute=False)
    rows.append(("fig7/inflight_stale_kv", 0.0, f"kl={kl_inflight:.4f}"))
    kl_recomp = sample_and_eval(inflight_sched, recompute=True)
    rows.append(("fig7/inflight_recomputed_kv", 0.0, f"kl={kl_recomp:.4f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 8: utilization curve U(h)
# ---------------------------------------------------------------------------

def fig8_utilization() -> List[Row]:
    hw = HardwareModel()
    pts = {h: float(hw.U(h)) for h in (1, 16, 64, 128, 192, 256, 512)}
    return [("fig8/U(h)", 0.0,
             " ".join(f"{h}:{u:.3f}" for h, u in pts.items()))]


# ---------------------------------------------------------------------------
# Fig. 9 + A.4 case study: throughput vs max lag
# ---------------------------------------------------------------------------

def fig9_pareto() -> List[Row]:
    hw = HardwareModel()
    rows: List[Row] = []
    t0 = time.perf_counter()
    curves = fig9_curves(hw)
    us = (time.perf_counter() - t0) * 1e6 / len(curves)
    for r in curves:
        rows.append((f"fig9/g{r['g_max']}", us,
                     f"r_conv={r['r_conv']:.2f} r_pipe={r['r_pipe']:.2f} "
                     f"speedup={r['speedup']:.2f} I={r['I']} H={r['H']}"))
    r_conv, r_gen, r_train = conventional_throughput(hw, 128, 128, 134, 2048)
    rows.append(("figA4/case_study", 0.0,
                 f"r_conv={r_conv:.1f}(paper 10.7) r_gen={r_gen:.1f}(18.3) "
                 f"r_train={r_train:.2f}(26.02)"))
    return rows


# ---------------------------------------------------------------------------
# Table 1 analogue: success rate before/after RL on the math task
# ---------------------------------------------------------------------------

def table1_success() -> List[Row]:
    """Exact-match success before/after PipelineRL training. lr matters the
    way the paper's Fig. 10 says it does: 3e-3 diverges (policy collapses to
    repeated digits), 1e-3 learns. Dense shaping (partial_credit) stands in
    for a pretrained base model's head start."""
    steps = 12 if FAST else 400
    from repro.data.math_task import MathTask
    from repro.configs.tiny import config as tiny_config
    from repro.sharding import tree_values
    from repro.models import model as M
    import jax as _jax
    task = MathTask(max_operand=2, ops="+", partial_credit=True)
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=96, n_layers=2)
    params = tree_values(M.init_params(cfg, _jax.random.PRNGKey(0)))

    def success_rate(p_eval, n=32):
        ec = EngineConfig(n_slots=n, max_len=14, temperature=1e-4)
        eng = GenerationEngine(cfg, p_eval, ec, task.sample, seed=123)
        eng.refill()
        rolls = []
        for _ in range(64):
            rolls.extend(eng.step(task))
            if eng.n_active == 0:
                break
        return float(np.mean([r.reward > 0.5 for r in rolls])) if rolls else 0.0

    base = success_rate(params)
    trainer = Trainer(cfg, params, rl=RLConfig(entropy_coef=0.01),
                      adam=AdamConfig(lr=1e-3))
    p = PipelineRL(cfg, params, task, EngineConfig(n_slots=16, max_len=14),
                   PipelineConfig(batch_size=16, n_opt_steps=steps, n_chips=8,
                                  train_chips=4, pack_rows=4, pack_seq=72),
                   trainer=trainer)
    p.run()
    trained = success_rate(trainer.state.params)
    return [("table1/success_rate", 0.0,
             f"base={base:.3f} pipeline_rl={trained:.3f} steps={steps}")]
