"""Real-mesh runtime benchmarks (DESIGN.md §11) on forced host devices.

Measures, on an 8-way host-device mesh (true multi-device SPMD on CPU —
the same GSPMD partitioning a TPU pod would run, minus the interconnect):

  - decode step time: replicated single-device engine vs the same tiny
    config mesh-sharded through `sharding_context` (the absolute numbers
    are CPU-host noise; the point is the sharded program compiles, runs,
    and stays token-identical — parity is asserted in the test suite)
  - executed streamed broadcast: per-chunk reshard+install wall time from
    the engine's `wexec_log` vs the atomic `set_weights` transfer, and
    the measured decode pause per weight update
  - co-sim calibration: `record_cosim_trace` replayed through the
    EventLoop twin — predicted vs measured totals and pause accounting
  - the executed trainer→generator weight-update reshard
    (`execute_weight_update`): measured per-chunk t_exec_s, the runtime
    companion of the dry-run's compiled t_collective_s estimate

Emits ``BENCH_mesh.json``. When the current process has fewer than 8
devices (XLA fixes the device count at backend init), the group respawns
itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and relays the
rows.

    PYTHONPATH=src python -m benchmarks.run --only mesh
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List, Tuple

Row = Tuple[str, float, str]

JSON_PATH = "BENCH_mesh.json"
N_DEV = 8
N_CHUNKS = 4


def _median(xs):
    return sorted(xs)[len(xs) // 2]


def _step_time(engine, task, iters=15):
    import jax
    engine.refill()
    times = []
    for i in range(iters + 3):
        if engine.n_active == 0:
            engine.refill()
        t0 = time.perf_counter()
        engine.step(task)
        jax.block_until_ready(engine.state["tokens"])
        if i >= 3:    # first rounds pay compile
            times.append(time.perf_counter() - t0)
    return _median(times)


def _run() -> List[Row]:
    import jax

    from repro.configs.tiny import config as tiny_config
    from repro.core.events import chunk_spans, chunk_token, span_bytes, \
        stream_digest
    from repro.core.rollout import EngineConfig, GenerationEngine
    from repro.data.math_task import MathTask
    from repro.launch.meshrt import record_cosim_trace, replay_trace
    from repro.launch.steps import execute_weight_update
    from repro.models import model as M
    from repro.sharding import tree_values

    mesh = jax.make_mesh((N_DEV,), ("model",))
    backend = jax.default_backend()
    # identically-seeded tasks give each engine the same prompt sequence
    task_a = MathTask(max_operand=5, ops="+")
    task_b = MathTask(max_operand=5, ops="+")
    cfg = tiny_config(vocab_size=task_a.tok.vocab_size, d_model=64,
                      n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    params2 = jax.tree.map(lambda x: x + 0.01, params)
    ec = EngineConfig(n_slots=4, max_len=24)

    ref = GenerationEngine(cfg, params, ec, task_a.sample, seed=1)
    eng = GenerationEngine(cfg, params, ec, task_b.sample, seed=1, mesh=mesh)
    t_rep = _step_time(ref, task_a)
    t_shard = _step_time(eng, task_b)

    # executed streamed install: integrity gate armed, every chunk a real
    # resharding transfer measured by the engine itself
    leaves = jax.tree_util.tree_leaves(params2)
    sizes = span_bytes(leaves, chunk_spans(leaves, N_CHUNKS))
    toks = [chunk_token(2, k, sizes[k]) for k in range(len(sizes))]
    eng.wexec_log.clear()
    eng.begin_weight_stream(params2, 2, n_chunks=N_CHUNKS,
                            expect_digest=stream_digest(toks))
    for tk in toks:
        eng.stream_weight_chunk(token=tk)
    assert eng.last_stream_installed and eng.version == 2
    chunk_s = [r["seconds"] for r in eng.wexec_log if r["kind"] == "chunk"]
    eng.wexec_log.clear()
    eng.set_weights(params, 3)
    atomic_s = eng.wexec_log[-1]["seconds"]

    # co-sim: record a real decode+install timeline, replay it in the sim
    task_c = MathTask(max_operand=5, ops="+")
    eng2 = GenerationEngine(cfg, params, ec, task_c.sample, seed=2,
                            mesh=mesh)
    trace = record_cosim_trace(eng2, params2, n_ticks=24, publish_every=8,
                               n_chunks=N_CHUNKS, task=task_c)
    rep = replay_trace(trace)
    rel = (abs(rep["sim_total_s"] - rep["measured_total_s"])
           / max(rep["measured_total_s"], 1e-12))

    # executed trainer->generator reshard (the dry-run estimate's twin)
    wu = execute_weight_update(cfg, mesh, n_chunks=N_CHUNKS)

    rows: List[Row] = [
        ("mesh/decode_step_replicated", t_rep * 1e6,
         f"backend={backend};n_dev=1"),
        ("mesh/decode_step_sharded", t_shard * 1e6,
         f"backend={backend};n_dev={N_DEV};"
         f"sharded/replicated={t_shard / max(t_rep, 1e-12):.2f}x"),
        ("mesh/broadcast_chunk_install", _median(chunk_s) * 1e6,
         f"n_chunks={N_CHUNKS};max_us={max(chunk_s) * 1e6:.1f};"
         f"sum_us={sum(chunk_s) * 1e6:.1f}"),
        ("mesh/broadcast_atomic", atomic_s * 1e6,
         f"atomic/max_chunk={atomic_s / max(max(chunk_s), 1e-12):.2f}x"),
        ("mesh/pause_per_update_measured",
         rep["measured_pause_per_update"] * 1e6,
         f"sim_us={rep['sim_pause_per_update'] * 1e6:.1f};"
         f"updates={rep['updates_measured']}"),
        ("mesh/cosim_total", rep["measured_total_s"] * 1e6,
         f"sim_us={rep['sim_total_s'] * 1e6:.1f};rel_err={rel:.4f};"
         f"lag_sim={rep['mean_lag_sim']:.2f};"
         f"lag_meas={rep['mean_lag_measured']:.2f}"),
        ("mesh/weight_update_exec", sum(c["t_exec_s"] for c in wu) * 1e6,
         f"n_chunks={len(wu)};"
         f"max_chunk_us={max(c['t_exec_s'] for c in wu) * 1e6:.1f}"),
    ]

    payload = {
        "config": {"n_dev": N_DEV, "n_chunks": N_CHUNKS, "backend": backend,
                   "d_model": 64, "n_layers": 1},
        "decode_step_s": {"replicated": t_rep, "sharded": t_shard},
        "broadcast": {"chunk_s": chunk_s, "atomic_s": atomic_s,
                      "chunk_nbytes": [int(s) for s in sizes]},
        "pause_per_update_s": {
            "measured": rep["measured_pause_per_update"],
            "sim": rep["sim_pause_per_update"]},
        "cosim": {"sim_total_s": rep["sim_total_s"],
                  "measured_total_s": rep["measured_total_s"],
                  "rel_total_err": rel,
                  "updates_sim": rep["updates_sim"],
                  "updates_measured": rep["updates_measured"],
                  "mean_lag_sim": rep["mean_lag_sim"],
                  "mean_lag_measured": rep["mean_lag_measured"]},
        "weight_update_exec": wu,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("mesh/json", 0.0, os.path.abspath(JSON_PATH)))
    return rows


def mesh_benchmarks() -> List[Row]:
    import jax
    if jax.device_count() >= N_DEV:
        return _run()
    # XLA fixes the device count when the backend initializes; respawn
    # with forced host devices and relay the rows
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={N_DEV}"
                        ).strip()
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-m", "benchmarks.mesh_bench"],
                          env=env, cwd=root, capture_output=True, text=True,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError("mesh bench subprocess failed:\n"
                           + proc.stdout[-1000:] + proc.stderr[-2000:])
    rows: List[Row] = []
    for line in proc.stdout.splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3 and parts[0].startswith("mesh/"):
            rows.append((parts[0], float(parts[1]), parts[2]))
    return rows


if __name__ == "__main__":
    for r in mesh_benchmarks():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
