"""Assemble EXPERIMENTS.md tables from the results/*.json artifacts.

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _fmt(x, digits=3):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) < 1e-2 or abs(x) >= 1e4:
            return f"{x:.2e}"
        return f"{x:.{digits}g}"
    return str(x)


def dryrun_table(name, title):
    rs = _load(name)
    if rs is None:
        print(f"(missing {name})")
        return
    print(f"\n### {title}\n")
    print("| arch | shape | mesh | t_compute | t_memory | t_collective |"
          " dominant | useful FLOPs | mem GB/dev | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rs:
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAILED: "
                  f"{r.get('error','?')[:60]} | | | | | | |")
            continue
        mem = r.get("memory_analysis", {}).get("peak_gb",
                                               r.get("mem_gb_per_dev"))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {_fmt(r['t_compute_s'])} | {_fmt(r['t_memory_s'])} "
              f"| {_fmt(r['t_collective_s'])} | {r['bottleneck']} "
              f"| {_fmt(r.get('useful_flops_ratio'))} | {_fmt(mem)} "
              f"| {r.get('t_compile_s', r.get('t_total_s'))} |")


def weight_update_table():
    rs = _load("dryrun_1pod.json")
    if rs is None:
        return
    print("\n### In-flight weight-update transfer (trainer->generator "
          "reshard, 16x16 mesh)\n")
    print("| arch | collective GB/dev | t_collective |")
    print("|---|---|---|")
    seen = set()
    for r in rs:
        wu = r.get("weight_update")
        if not wu or r["arch"] in seen:
            continue
        seen.add(r["arch"])
        print(f"| {r['arch']} | {_fmt(wu['coll_gbytes_per_dev'])} "
              f"| {_fmt(wu['t_collective_s'])} |")


def main():
    dryrun_table("dryrun_1pod.json",
                 "Baseline (paper-faithful defaults), single pod 16x16, "
                 "uncalibrated cost_analysis")
    dryrun_table("dryrun_2pod.json", "Multi-pod 2x16x16 (512 chips)")
    dryrun_table("dryrun_1pod_calibrated_optimized.json",
                 "Calibrated + optimized (remat+microbatch for train, "
                 "GEN_RULES+donation for inference), single pod")
    weight_update_table()


if __name__ == "__main__":
    main()
