"""Trainer hot path: fused linear-cross-entropy vs the textbook lm-head
loss, plus the device-resident metrics loop (DESIGN.md §6).

Measures, on an inflated-vocab `tiny` config (vocab is what makes the
(B,S,V) logits dominate trainer activations — the structural win
transfers to llama3-8B/128k-vocab scale):

  - peak activation (temp buffer) bytes of the compiled `train_step`, via
    XLA's compile-time memory analysis — the fused path must cut it >= 2x
  - a structural check that the fused train_step jaxpr contains no
    (B,S,V)- or (B*S,V)-shaped intermediate (logits and their gradient
    are never materialized)
  - wall-clock per optimizer step, fused vs unfused
  - the metrics sync overhead: per-step blocking float() of every metric
    (the old Trainer.step) vs the device-resident LazyMetrics loop with
    one batched fetch at the end

Emits ``BENCH_trainer.json`` next to the CSV so the perf trajectory is
machine-readable across PRs.

    PYTHONPATH=src python -m benchmarks.run --only trainer
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.tiny import config as tiny_config
from repro.core.algo import RLConfig
from repro.core.trainer import init_train_state, train_step
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.sharding import tree_values

Row = Tuple[str, float, str]

VOCAB = 6144        # inflated: logits dominate trainer activations
B, S = 4, 128
D_MODEL, N_LAYERS = 128, 2
STEP_ITERS = 7
JSON_PATH = "BENCH_trainer.json"


VARIANTS = {
    # fused: the blocked jnp twin (what a CPU co-sim runs — compiled by
    # XLA, no logits materialization); fused_pallas: the Pallas kernel in
    # interpret mode (kernel-body validation; pays python dispatch per
    # grid step, so its CPU time overstates the compiled-TPU cost)
    "unfused": {},
    "fused": dict(fused_loss=True),
    "fused_pallas": dict(fused_loss=True, use_pallas=True),
}


def _setup(variant: str):
    cfg = tiny_config(vocab_size=VOCAB, d_model=D_MODEL, n_layers=N_LAYERS)
    cfg = dataclasses.replace(cfg, **VARIANTS[variant])
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, VOCAB),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
        "segment_ids": jnp.ones((B, S), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32).at[:, :8].set(0.0),
        "behavior_logprobs": jnp.full((B, S), -1.0),
        "rewards": jnp.full((B, S), 0.5),
    }
    return cfg, params, batch


def _jaxpr_logits_count(cfg, params, batch) -> int:
    """Count (B,S,V)/(B*S,V)-shaped intermediates in the train_step jaxpr."""
    from jax._src import core as jcore

    def avals(jaxpr):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                yield v.aval
            for p in eqn.params.values():
                stack = [p]
                while stack:
                    q = stack.pop()
                    if isinstance(q, jcore.ClosedJaxpr):
                        yield from avals(q.jaxpr)
                    elif isinstance(q, jcore.Jaxpr):
                        yield from avals(q)
                    elif isinstance(q, (list, tuple)):
                        stack.extend(q)

    state = init_train_state(params)
    fn = lambda st, b: train_step(st, b, cfg, RLConfig(), AdamConfig())
    jaxpr = jax.make_jaxpr(fn)(state, batch)
    forbidden = ((B, S, VOCAB), (B * S, VOCAB))
    return sum(1 for a in avals(jaxpr.jaxpr)
               if getattr(a, "shape", None) in forbidden)


def _measure_variants():
    """Compile every variant, then interleave the timing rounds so shared
    machine noise hits all variants equally; per-variant median."""
    prepared = {}
    for variant in VARIANTS:
        cfg, params, batch = _setup(variant)
        state = init_train_state(params)
        fn = jax.jit(functools.partial(train_step, cfg=cfg, rl=RLConfig(),
                                       adam=AdamConfig()))
        # AOT-compile once and reuse the executable for warmup + timing
        # (calling the jit wrapper would retrace and compile a second time)
        compiled = fn.lower(state, batch).compile()
        try:
            temp_bytes = int(compiled.memory_analysis().temp_size_in_bytes)
        except Exception:   # backend without memory analysis
            temp_bytes = -1
        st, m = compiled(state, batch)
        jax.block_until_ready(m["loss"])
        prepared[variant] = dict(
            fn=compiled, state=state, batch=batch, times=[],
            temp_bytes=temp_bytes, loss=float(m["loss"]),
            jaxpr_logits_intermediates=_jaxpr_logits_count(cfg, params,
                                                           batch))
    for _ in range(STEP_ITERS):
        for p in prepared.values():
            t0 = time.perf_counter()
            _, m = p["fn"](p["state"], p["batch"])
            jax.block_until_ready(m["loss"])
            p["times"].append(time.perf_counter() - t0)
    return {
        v: dict(temp_bytes=p["temp_bytes"], loss=p["loss"],
                jaxpr_logits_intermediates=p["jaxpr_logits_intermediates"],
                step_s=sorted(p["times"])[len(p["times"]) // 2])
        for v, p in prepared.items()
    }


def _measure_metrics_sync():
    """Device-resident metrics: the old Trainer.step blocked on one
    float(v) per metric per step; the new loop keeps metrics on device and
    fetches once at the end. Measured on a small config so the sync cost
    is not hidden under compute (the absolute gap grows with device
    latency — on TPU every float() is a host round trip). Returns
    (eager_s, lazy_s, syncs_per_step_eager)."""
    cfg = tiny_config(vocab_size=64, d_model=32, n_layers=1)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(0)))
    state = init_train_state(params)
    key = jax.random.PRNGKey(2)
    b, s = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, 64),
        "positions": jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
        "segment_ids": jnp.ones((b, s), jnp.int32),
        "loss_mask": jnp.ones((b, s), jnp.float32),
        "behavior_logprobs": jnp.full((b, s), -1.0),
        "rewards": jnp.full((b, s), 0.5),
    }
    fn = jax.jit(functools.partial(train_step, cfg=cfg, rl=RLConfig(),
                                   adam=AdamConfig()))
    st, m = fn(state, batch)
    jax.block_until_ready(m["loss"])
    n_metrics = len(m)
    steps = 50

    def run(sync_every_step: bool) -> float:
        st, pending = state, []
        t0 = time.perf_counter()
        for _ in range(steps):
            st, m = fn(st, batch)
            if sync_every_step:
                {k: float(v) for k, v in m.items()}   # the old step()
            else:
                pending.append(m)
        if pending:
            jax.device_get(pending)                   # one batched fetch
        else:
            jax.block_until_ready(st)
        return (time.perf_counter() - t0) / steps

    # alternate the two modes and take medians: at this scale the sync
    # overhead is a few hundred us/step and CPU noise is comparable
    eager, lazy = [], []
    for _ in range(5):
        eager.append(run(True))
        lazy.append(run(False))
    return sorted(eager)[2], sorted(lazy)[2], n_metrics


def trainer_benchmarks() -> List[Row]:
    rows: List[Row] = []
    res = _measure_variants()
    backend = jax.default_backend()
    for name, r in res.items():
        rows.append((f"trainer/step_time_{name}", r["step_s"] * 1e6,
                     f"temp_bytes={r['temp_bytes']};backend={backend}"))
    rows.append(("trainer/step_time_speedup", 0.0,
                 f"unfused/fused="
                 f"{res['unfused']['step_s'] / max(res['fused']['step_s'], 1e-12):.2f}x"))
    ratio = res["unfused"]["temp_bytes"] / max(res["fused"]["temp_bytes"], 1)
    rows.append(("trainer/peak_activation_ratio", 0.0,
                 f"unfused/fused={ratio:.2f}x;"
                 f"logits_intermediates {res['unfused']['jaxpr_logits_intermediates']}"
                 f"->{res['fused']['jaxpr_logits_intermediates']}"))
    # modeled logits HBM traffic the fused path eliminates (fwd write +
    # f32 upcast + backward grad = 3 (N,V) tensors/step): the step-time
    # lever on memory-bound accelerators. Interpret mode (the CPU
    # validation path above) pays python dispatch per grid step, so
    # measured CPU step time understates the compiled-TPU win.
    logits_gb = 3 * B * S * VOCAB * 4 / 1e9
    rows.append(("trainer/modeled_logits_traffic",
                 0.0, f"eliminated_gb_per_step={logits_gb:.3f};"
                 f"llama3_8b_128k_vocab_gb="
                 f"{3 * 4096 * 128256 * 4 / 1e9:.1f}"))
    eager, lazy, n_metrics = _measure_metrics_sync()
    rows.append(("trainer/metrics_sync_per_step", eager * 1e6,
                 f"lazy_us={lazy * 1e6:.1f};"
                 f"speedup={eager / max(lazy, 1e-9):.2f}x;"
                 f"host_syncs_per_step {n_metrics}->0"))

    payload = {
        "config": {"vocab": VOCAB, "batch": B, "seq": S,
                   "d_model": D_MODEL, "n_layers": N_LAYERS,
                   "backend": backend},
        **res,
        "activation_ratio": ratio,
        "step_time_ratio": res["unfused"]["step_s"]
            / max(res["fused"]["step_s"], 1e-12),
        "metrics_sync": {"eager_s_per_step": eager, "lazy_s_per_step": lazy,
                         "host_syncs_per_step_before": n_metrics,
                         "host_syncs_per_step_after": 0},
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("trainer/json", 0.0, os.path.abspath(JSON_PATH)))
    return rows


if __name__ == "__main__":
    for r in trainer_benchmarks():
        print(",".join(str(c) for c in r))
