"""Pallas kernel microbenchmarks (interpret mode on CPU: numbers measure the
reference execution, not TPU performance — the derived column reports the
analytic FLOPs so TPU projections use the roofline, not these timings)."""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ops

Row = Tuple[str, float, str]
KEY = jax.random.PRNGKey(0)


def kernel_benchmarks() -> List[Row]:
    rows: List[Row] = []

    B, H, KV, S, D = 1, 8, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, KV, S, D))
    v = jax.random.normal(ks[2], (B, KV, S, D))
    us, _ = time_call(ops.flash_attention, q, k, v, scale=D ** -0.5,
                      block_q=128, block_k=128, iters=3)
    flops = 4 * B * H * S * S * D
    rows.append(("kernel/flash_attention_256", us, f"flops={flops:.0f}"))

    CL = 512
    kc = jax.random.normal(ks[1], (B, CL, KV, D))
    vc = jax.random.normal(ks[2], (B, CL, KV, D))
    qd = jax.random.normal(ks[0], (B, H, D))
    us, _ = time_call(ops.flash_decode, qd, kc, vc,
                      jnp.full((B,), CL), scale=D ** -0.5, iters=3)
    rows.append(("kernel/flash_decode_512", us,
                 f"flops={4 * B * H * CL * D:.0f}"))

    b, l, h, p, g, n = 1, 256, 4, 32, 1, 32
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[1], (b, l, g, n))
    Cm = jax.random.normal(ks[2], (b, l, g, n))
    us, _ = time_call(ops.ssd_scan, x, dt, A, Bm, Cm, chunk=64, iters=3)
    rows.append(("kernel/ssd_scan_256", us,
                 f"flops~{2 * b * l * h * p * n * 3:.0f}"))
    return rows
