"""Generation-engine hot path: chunked-prefill admission vs the legacy
token-at-a-time prompt loop.

Measures, on the `tiny` CPU config (relative numbers — the structural win,
fewer model invocations per admitted prompt, transfers to TPU):

  - model invocations until the first sampled token of an admitted prompt
    (P decode steps vs ceil((P-1)/chunk) prefill forwards + 1 step)
  - time-to-first-token for a freshly admitted batch (refill + steps)
  - end-to-end tokens/sec running a full admitted batch to completion

plus a ring-buffer (sliding-window) variant: chunked admission over a
CL=32 ring cache — the long-context serve path that used to fall back to
the legacy loop.

Paged-KV rows (DESIGN.md §9, written to BENCH_paged.json and folded into
BENCH_engine.json):

  - GRPO admission amortization: a G-way group of identical prompts is
    prefilled ONCE on the paged engine (G-1 copy-on-write forks) vs G
    full prefills on the slot array — prompt prefills, prefill tokens,
    pages charged, and TTFT for the group
  - capacity at fixed memory: with a pool holding HALF the slot-array's
    cache footprint, the paged engine still admits every short prompt
    (pages are allocated per block actually written) while the
    slot-array equivalent covers half the batch

    PYTHONPATH=src python -m benchmarks.run --only engine
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import tiny_setup
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.data.math_task import Problem

Row = Tuple[str, float, str]

PROMPT_LEN = 48
N_SLOTS = 8
MAX_LEN = 96
CHUNK = 16
RING_WINDOW = 32
PAGE_SIZE = 16
JSON_PATH = "BENCH_engine.json"
PAGED_JSON_PATH = "BENCH_paged.json"


def _source(vocab: int, n: int):
    """n fixed-length synthetic prompts (cycling valid token ids)."""
    probs = [Problem([1 + (i + j) % (vocab - 3) for j in range(PROMPT_LEN)], 0)
             for i in range(n)]
    it = iter(probs)
    return lambda: next(it, None)


def _bench(chunk: int, ring: bool = False):
    """Returns (ttft_s, invocations_to_first_sample, tokens_per_sec)."""
    task, cfg, params = tiny_setup(d_model=64, n_layers=2)
    if ring:
        cfg = dataclasses.replace(cfg, attention_variant="sliding_window",
                                  sliding_window=RING_WINDOW)
    ec = EngineConfig(n_slots=N_SLOTS, max_len=MAX_LEN, prefill_chunk=chunk,
                      temperature=1.0, eos_id=-1)   # no early EOS: fixed work
    eng = GenerationEngine(cfg, params, ec,
                           _source(cfg.vocab_size, 2 * N_SLOTS), seed=0)
    # warm-up round on the same engine (jit caches are per engine): admit
    # the first batch and run it to completion
    eng.refill()
    while eng.n_active:
        eng.step(task)

    pre_inv = eng.prefill_invocations
    t0 = time.perf_counter()
    eng.refill()
    steps_to_first = 0
    ttft = None
    while eng.n_active:
        eng.step(task)
        if ttft is None:
            steps_to_first += 1
            if (eng._host_ncached >= eng._host_prompt_len).all():
                np.asarray(eng.state["tokens"])   # force device sync
                ttft = time.perf_counter() - t0
    np.asarray(eng.state["tokens"])
    total_t = time.perf_counter() - t0
    invocations = (eng.prefill_invocations - pre_inv) + steps_to_first
    sampled = N_SLOTS * (MAX_LEN - PROMPT_LEN)    # useful completion tokens
    return ttft, invocations, sampled / total_t


def _bench_paged_grpo(cache: str):
    """G=N_SLOTS identical prompts (one GRPO group): admission cost and
    TTFT, slots vs paged-with-prefix-sharing. Returns the stats dict."""
    task, cfg, params = tiny_setup(d_model=64, n_layers=2)
    prompt = [1 + j % (cfg.vocab_size - 3) for j in range(PROMPT_LEN)]
    probs = [Problem(list(prompt), 0) for _ in range(2 * N_SLOTS)]
    it = iter(probs)
    ec = EngineConfig(n_slots=N_SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                      temperature=1.0, eos_id=-1, cache=cache,
                      page_size=PAGE_SIZE)
    eng = GenerationEngine(cfg, params, ec, lambda: next(it, None), seed=0)
    eng.refill()                      # warm-up admission (jit compile)
    while eng.n_active:
        eng.step(task)
    t0 = time.perf_counter()
    eng.refill()
    eng.step(task)
    np.asarray(eng.state["tokens"])   # force device sync
    ttft = time.perf_counter() - t0
    return {
        "prompt_prefills": eng.prompt_prefills,
        "prefill_tokens": eng.last_admit_prefill_tokens,
        "pages_allocated": eng.last_admit_pages,
        "prefix_forks": getattr(eng, "prefix_forks", 0),
        "group_ttft_s": ttft,
    }


def _bench_paged_capacity():
    """Concurrent short prompts admitted under a fixed memory budget of
    HALF the slot-array footprint. The slot array cannot shrink below one
    max_len stripe per sequence; the paged pool backs only blocks that
    are actually written."""
    task, cfg, params = tiny_setup(d_model=64, n_layers=2)
    short = 8
    probs = [Problem([1 + (i + j) % (cfg.vocab_size - 3)
                      for j in range(short)], 0) for i in range(N_SLOTS)]
    it = iter(probs)
    blocks_per_slot = MAX_LEN // PAGE_SIZE
    half_pool = (N_SLOTS * blocks_per_slot) // 2 + 1   # + trash page
    ec = EngineConfig(n_slots=N_SLOTS, max_len=MAX_LEN, prefill_chunk=CHUNK,
                      temperature=1.0, eos_id=-1, cache="paged",
                      page_size=PAGE_SIZE, n_pages=half_pool)
    eng = GenerationEngine(cfg, params, ec, lambda: next(it, None), seed=0)
    admitted_paged = eng.refill()
    slot_equivalent = (half_pool - 1) // blocks_per_slot
    return {
        "pool_pages": half_pool - 1,
        "slot_array_capacity": slot_equivalent,
        "paged_admitted": admitted_paged,
        "pages_allocated": eng.last_admit_pages,
        "capacity_x": admitted_paged / max(slot_equivalent, 1),
    }


def engine_benchmarks() -> List[Row]:
    rows: List[Row] = []
    results = {}
    for name, chunk in (("legacy", 0), ("chunked", CHUNK)):
        ttft, inv, tps = _bench(chunk)
        results[name] = (ttft, inv, tps)
        rows.append((f"engine/ttft_{name}", ttft * 1e6,
                     f"invocations_to_first_sample={inv}"))
        rows.append((f"engine/tokens_per_sec_{name}", 1e6 / max(tps, 1e-9),
                     f"tok_s={tps:.1f}"))
    sp_ttft = results["legacy"][0] / max(results["chunked"][0], 1e-9)
    sp_tps = results["chunked"][2] / max(results["legacy"][2], 1e-9)
    rows.append(("engine/speedup", 0.0,
                 f"ttft_x={sp_ttft:.2f};tok_s_x={sp_tps:.2f};"
                 f"invocations {results['legacy'][1]}->"
                 f"{results['chunked'][1]}"))
    # ring-buffer (sliding-window) cache: chunked admission over CL=32
    ttft, inv, tps = _bench(CHUNK, ring=True)
    results["chunked_ring"] = (ttft, inv, tps)
    rows.append(("engine/ttft_chunked_ring", ttft * 1e6,
                 f"invocations_to_first_sample={inv};window={RING_WINDOW}"))
    rows.append(("engine/tokens_per_sec_chunked_ring", 1e6 / max(tps, 1e-9),
                 f"tok_s={tps:.1f}"))
    # paged KV cache (DESIGN.md §9): GRPO admission amortization + fixed-
    # memory capacity
    grpo = {c: _bench_paged_grpo(c) for c in ("slots", "paged")}
    cap = _bench_paged_capacity()
    amort = (grpo["slots"]["prefill_tokens"]
             / max(grpo["paged"]["prefill_tokens"], 1))
    rows.append((
        "engine/paged_grpo_prefill_tokens", grpo["paged"]["prefill_tokens"],
        f"slots={grpo['slots']['prefill_tokens']};"
        f"prefills {grpo['slots']['prompt_prefills']}->"
        f"{grpo['paged']['prompt_prefills']};"
        f"forks={grpo['paged']['prefix_forks']};amortization_x={amort:.1f}"))
    rows.append((
        "engine/paged_grpo_ttft", grpo["paged"]["group_ttft_s"] * 1e6,
        f"slots_ttft_us={grpo['slots']['group_ttft_s'] * 1e6:.0f};"
        f"pages={grpo['paged']['pages_allocated']}"))
    rows.append((
        "engine/paged_capacity_at_half_memory", cap["capacity_x"],
        f"paged_admitted={cap['paged_admitted']};"
        f"slot_capacity={cap['slot_array_capacity']};"
        f"pages={cap['pages_allocated']}/{cap['pool_pages']}"))
    # machine-readable perf trajectory, same schema discipline as
    # BENCH_trainer.json: a config block + one record per variant + the
    # headline ratios (uploaded by CI next to the CSV)
    import jax
    payload = {
        "config": {"prompt_len": PROMPT_LEN, "n_slots": N_SLOTS,
                   "max_len": MAX_LEN, "chunk": CHUNK,
                   "ring_window": RING_WINDOW,
                   "backend": jax.default_backend()},
        **{name: {"ttft_s": r[0], "invocations_to_first_sample": r[1],
                  "tokens_per_sec": r[2]}
           for name, r in results.items()},
        "ttft_ratio": sp_ttft,
        "tokens_per_sec_ratio": sp_tps,
    }
    paged_payload = {
        "config": {"prompt_len": PROMPT_LEN, "n_slots": N_SLOTS,
                   "max_len": MAX_LEN, "chunk": CHUNK,
                   "page_size": PAGE_SIZE,
                   "backend": jax.default_backend()},
        "grpo_group": grpo,
        "grpo_prefill_amortization_x": amort,
        "capacity_at_half_memory": cap,
    }
    payload["paged"] = paged_payload
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    with open(PAGED_JSON_PATH, "w") as f:
        json.dump(paged_payload, f, indent=2)
    rows.append(("engine/json", 0.0, os.path.abspath(JSON_PATH)))
    rows.append(("engine/paged_json", 0.0, os.path.abspath(PAGED_JSON_PATH)))
    return rows


if __name__ == "__main__":
    for r in engine_benchmarks():
        print(",".join(str(c) for c in r))
