"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9] [--full]

Prints ``name,us_per_call,derived`` CSV. BENCH_FAST=0 (or --full) runs the
long learning-curve variants.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark group name")
    ap.add_argument("--full", action="store_true",
                    help="long variants (learning curves at full length)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset: engine hot path + analytic groups only")
    args = ap.parse_args()
    if args.full:
        os.environ["BENCH_FAST"] = "0"

    # imports after BENCH_FAST is settled
    from benchmarks import figures
    from benchmarks.engine_bench import engine_benchmarks
    from benchmarks.kernels_bench import kernel_benchmarks
    from benchmarks.lag_bench import lag_benchmarks
    from benchmarks.mesh_bench import mesh_benchmarks
    from benchmarks.orchestrator_bench import (chaos_benchmarks,
                                               gray_benchmarks,
                                               orchestrator_benchmarks)
    from benchmarks.roofline_bench import roofline_rows
    from benchmarks.trainer_bench import trainer_benchmarks

    groups = {
        "fig2": figures.fig2_generation,
        "fig5": figures.fig5_learning,
        "fig6": figures.fig6_lag_ess,
        "fig7": figures.fig7_kl,
        "fig8": figures.fig8_utilization,
        "fig9": figures.fig9_pareto,
        "table1": figures.table1_success,
        "ablation": figures.ablation_update_every,
        "kernels": kernel_benchmarks,
        "roofline": roofline_rows,
        "engine": engine_benchmarks,
        "trainer": trainer_benchmarks,
        "orchestrator": orchestrator_benchmarks,
        "chaos": chaos_benchmarks,
        "gray": gray_benchmarks,
        "lag": lag_benchmarks,
        "mesh": mesh_benchmarks,
    }
    if args.smoke:
        # fast, deterministic-cost groups so per-PR CI can catch tokens/sec
        # regressions in the generation hot path, activation-memory /
        # step-time regressions in the trainer hot path, broadcast-pause /
        # throughput regressions in the orchestration layer, recovery
        # regressions in the fault-tolerance paths (fail-stop chaos +
        # gray-failure detection scenarios), and lag-distribution /
        # bounded-staleness regressions in the lag-aware training path
        groups = {k: groups[k] for k in ("engine", "trainer", "orchestrator",
                                         "chaos", "gray", "lag",
                                         "fig8", "fig9")}

    print("name,us_per_call,derived")
    failed = []
    for name, fn in groups.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # keep the harness running
            failed.append(name)
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.1f},{derived}")
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmark groups failed: {failed}")


if __name__ == "__main__":
    # support `python benchmarks/run.py` as well as `python -m benchmarks.run`
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    main()
