"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax

from repro.configs.tiny import config as tiny_config
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.sharding import tree_values


def tiny_setup(d_model=64, n_layers=1, max_operand=5, seed=0):
    task = MathTask(max_operand=max_operand, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=d_model,
                      n_layers=n_layers)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(seed)))
    return task, cfg, params


def time_call(fn, *args, iters=10, warmup=2, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6, out  # us/call
