"""Lag-vs-throughput study (DESIGN.md §12): the sim as an instrument for
the paper's central trade — in-flight weight updates keep the pipeline
busy at the price of off-policy staleness, and `PipelineConfig.max_lag`
interpolates between conventional RL (bound 0) and the free-running
pipeline (bound None).

Grew out of `examples/inflight_kl_study.py` (which sweeps update_every
against the KL-to-behavior proxy): this sweeps broadcast mode x engine
count x lag bound — with a router slice on a heterogeneous pool — and
reads the *typed* staleness contract back out of the training path
(`PipelineRL.lag_stats()`: per-token lag histogram packed into every
batch, bound-masked token counts, gate pauses) next to throughput, plus
the per-lag-bucket ESS the `token_is` objective logs.

Emits ``BENCH_lag.json``.

    PYTHONPATH=src python -m benchmarks.run --only lag
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import tiny_setup
from repro.core.algo import RLConfig
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.optim.adam import AdamConfig

Row = Tuple[str, float, str]

JSON_PATH = "BENCH_lag.json"
STEPS = 4
BATCH = 4
N_CHIPS, TRAIN_CHIPS = 8, 4
# slow interconnect (same knob as the orchestrator bench) so broadcast
# arrival times — what the lag gate waits on — are visible against the
# tiny model's decode steps
HW = HardwareModel(h_sat=16, bcast_bytes_per_flash=2e3,
                   bcast_install_flash=1.0)
BOUNDS: Tuple[Optional[int], ...] = (None, 2, 0)


def _run(broadcast: str, n_engines: int, bound: Optional[int],
         router: str = "fifo",
         engine_speeds: Optional[List[float]] = None) -> Dict:
    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, rl=RLConfig(lag_mode="token_is"),
                     adam=AdamConfig(lr=1e-3))
    p = PipelineRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=16),
        PipelineConfig(batch_size=BATCH, n_opt_steps=STEPS,
                       n_chips=N_CHIPS, train_chips=TRAIN_CHIPS,
                       pack_rows=2, pack_seq=48, n_engines=n_engines,
                       broadcast=broadcast, router=router,
                       engine_speeds=engine_speeds, max_lag=bound),
        hw=HW, trainer=trainer)
    p.run()
    ls = p.lag_stats()
    t = p.log[-1]["time"]
    tokens = sum(e.tokens_generated for e in p.engines)
    hist = ls["histogram"]
    expanded = np.repeat(list(hist.keys()), list(hist.values())) \
        if hist else np.zeros(1)
    # per-lag-bucket ESS: mean over optimizer steps of the armed
    # objective's LazyMetrics (empty buckets report 0 and are excluded)
    bucket_ess = {}
    for b in RLConfig().lag_buckets:
        vals = [r[f"ess_lag{b}"] for r in p.log
                if r.get(f"ess_lag{b}", 0.0) > 0.0]
        bucket_ess[f"lag{b}"] = float(np.mean(vals)) if vals else None
    per_eng = p.broadcast_stats()["engines"]
    return {
        "broadcast": broadcast, "engines": n_engines, "router": router,
        "bound": bound,
        "sim_time_flashes": t,
        "tokens_generated": tokens,
        "tokens_per_flash": tokens / max(t, 1e-9),
        "lag_histogram": {str(k): v for k, v in hist.items()},
        "trained_tokens": ls["trained_tokens"],
        "lag_mean": ls["mean_lag"],
        "lag_max": ls["max_lag"],
        "lag_p99": float(np.percentile(expanded, 99)),
        "masked_tokens": ls["masked_tokens"],
        "gate": ls.get("gate"),
        "bucket_ess": bucket_ess,
        "pause_per_update_flashes": float(np.mean(
            [e["pause_per_update"] for e in per_eng
             if e["updates_applied"]] or [0.0])),
    }


def lag_benchmarks() -> List[Row]:
    rows: List[Row] = []
    payload: Dict = {"config": {
        "steps": STEPS, "batch": BATCH, "n_chips": N_CHIPS,
        "train_chips": TRAIN_CHIPS, "bounds": [b for b in BOUNDS],
        "lag_mode": "token_is",
        "bcast_bytes_per_flash": HW.bcast_bytes_per_flash}}

    # --- 1. lag-bound sweep: broadcast mode x engine count ------------
    sweep: List[Dict] = []
    for mode in ("streamed", "atomic"):
        for n_eng in (1, 2):
            for bound in BOUNDS:
                r = _run(mode, n_eng, bound)
                sweep.append(r)
                tag = "inf" if bound is None else str(bound)
                rows.append((
                    f"lag/{mode}_e{n_eng}_b{tag}", 0.0,
                    f"tok_per_flash={r['tokens_per_flash']:.4f};"
                    f"lag_mean={r['lag_mean']:.2f};"
                    f"lag_max={r['lag_max']};masked={r['masked_tokens']}"))
    payload["bound_sweep"] = sweep

    # the structural claims, as single numbers per (mode, engines) cell:
    # tightening the bound compresses the lag distribution (max <= bound,
    # verified from packed lag fields) and costs throughput
    for mode in ("streamed", "atomic"):
        for n_eng in (1, 2):
            cell = {r["bound"]: r for r in sweep
                    if r["broadcast"] == mode and r["engines"] == n_eng}
            free, locked = cell[None], cell[0]
            slowdown = (free["tokens_per_flash"]
                        / max(locked["tokens_per_flash"], 1e-9))
            rows.append((f"lag/tradeoff_{mode}_e{n_eng}", 0.0,
                         f"free_over_b0_throughput={slowdown:.2f}x;"
                         f"free_lag_max={free['lag_max']};"
                         f"b0_lag_max={locked['lag_max']}"))

    # --- 2. router slice: does smarter admission change the lag profile
    # on a heterogeneous 2x/1x pool at a finite bound? -----------------
    routers: List[Dict] = []
    for router in ("fifo", "shortest_queue", "length_affinity"):
        r = _run("streamed", 2, 2, router=router,
                 engine_speeds=[2.0, 1.0])
        routers.append(r)
        rows.append((f"lag/router_{router}", 0.0,
                     f"tok_per_flash={r['tokens_per_flash']:.4f};"
                     f"lag_mean={r['lag_mean']:.2f};"
                     f"masked={r['masked_tokens']}"))
    payload["router_slice"] = routers

    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("lag/json", 0.0, os.path.abspath(JSON_PATH)))
    return rows


if __name__ == "__main__":
    for row in lag_benchmarks():
        print(",".join(str(c) for c in row))
