"""Orchestrator hot path (DESIGN.md §7): the event-driven substrate's two
new measurable surfaces.

  1. per-update decode pause, streamed vs atomic weight publication —
     the paper's "the engine only briefly pauses for new weights" as a
     number: atomic publications stall decode for the whole
     `HardwareModel.broadcast_time`, streamed ones only pay the
     per-chunk install + pointer swap while the transfer overlaps decode
  2. pipeline-vs-conventional throughput (simulated flashes to a fixed
     optimizer-step budget) across actor-pool sizes — the engine-count
     sweep the single-engine orchestrator couldn't express
  3. heterogeneous pool scheduling: a 2-engine pool with a 2x/1x chip
     split fed a bimodal prompt-length stream, length-affinity routing
     vs FIFO — long prompts (cheap prefill, short remaining completion
     budget) land on the fast chip, so the straggler engine stops
     gating the SampleQueue

Emits ``BENCH_orchestrator.json`` (same schema discipline as
``BENCH_trainer.json``) so the perf trajectory covers the orchestration
layer too, and ``BENCH_chaos.json`` for the fault-tolerance scenario
(`chaos_benchmarks`, DESIGN.md §8): kill/restore one of two engines
mid-run and measure throughput/lag degradation, in-flight work recovery,
replay determinism, trainer crash-restart, and the serving front's
zero-lost-request guarantee under deadlines + retries.

    PYTHONPATH=src python -m benchmarks.run --only orchestrator
    PYTHONPATH=src python -m benchmarks.run --only chaos
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import tiny_setup
from repro.core.conventional import ConventionalConfig, ConventionalRL
from repro.core.events import FaultPlan
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.serving import Server
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.optim.adam import AdamConfig

Row = Tuple[str, float, str]

JSON_PATH = "BENCH_orchestrator.json"
STEPS = 4
BATCH = 4
N_CHIPS, TRAIN_CHIPS = 8, 4
# slow interconnect so the broadcast cost is visible against the tiny
# model's decode steps (the *ratio* streamed/atomic is the structural
# result; absolute flash numbers scale with the knob)
HW = HardwareModel(h_sat=16, bcast_bytes_per_flash=2e3,
                   bcast_install_flash=1.0)


def _bimodal_source(task, long_len: int = 26):
    """Deterministic alternating short/long prompt stream: every other
    prompt is left-padded with leading zeros after BOS to `long_len`
    tokens — same answer, same reward, ~4x the prefill work. The fixed
    task seed makes the stream identical across router policies."""
    zero = task.tok.stoi["0"]

    def sample():
        prob = task.sample()
        i = sample.i
        sample.i += 1
        if i % 2:
            pad = long_len - len(prob.prompt_ids)
            if pad > 0:
                prob.prompt_ids = ([prob.prompt_ids[0]] + [zero] * pad
                                   + prob.prompt_ids[1:])
        return prob

    sample.i = 0
    return sample


# generation-bound variant for the hetero scenario: a fast trainer keeps
# the sim time gated by rollout arrival, so the router's effect on the
# *generation* side is what the number measures (with the default tau the
# run is trainer-bound and any routing policy washes out)
HW_HETERO = HardwareModel(h_sat=16, tau=0.8)


def _hetero_pipeline(router: str, steps: int = 6) -> PipelineRL:
    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    p = PipelineRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=32),
        PipelineConfig(batch_size=BATCH, n_opt_steps=steps,
                       n_chips=N_CHIPS, train_chips=TRAIN_CHIPS,
                       pack_rows=2, pack_seq=48, n_engines=2,
                       engine_speeds=[2.0, 1.0], router=router),
        hw=HW_HETERO, trainer=trainer, prompt_source=_bimodal_source(task))
    p.run()
    return p


def _pipeline(broadcast: str, n_engines: int = 1,
              steps: int = STEPS) -> PipelineRL:
    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    p = PipelineRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=16),
        PipelineConfig(batch_size=BATCH, n_opt_steps=steps,
                       n_chips=N_CHIPS, train_chips=TRAIN_CHIPS,
                       pack_rows=2, pack_seq=48, n_engines=n_engines,
                       broadcast=broadcast),
        hw=HW, trainer=trainer)
    p.run()
    return p


def orchestrator_benchmarks() -> List[Row]:
    rows: List[Row] = []
    payload: Dict = {"config": {
        "steps": STEPS, "batch": BATCH, "n_chips": N_CHIPS,
        "train_chips": TRAIN_CHIPS,
        "bcast_bytes_per_flash": HW.bcast_bytes_per_flash,
        "bcast_install_flash": HW.bcast_install_flash}}

    # --- 1. per-update decode pause: streamed vs atomic vs free -------
    pause: Dict[str, Dict] = {}
    for mode in ("free", "streamed", "atomic"):
        p = _pipeline(mode)
        st = p.broadcast_stats()
        per_eng = st["engines"]
        mean_pause = float(np.mean([e["pause_per_update"] for e in per_eng
                                    if e["updates_applied"]] or [0.0]))
        pause[mode] = {
            "published": st["published"],
            "updates_applied": sum(e["updates_applied"] for e in per_eng),
            "pause_per_update_flashes": mean_pause,
            "sim_time_flashes": p.log[-1]["time"],
            "max_lag": max(r["max_lag"] for r in p.log),
        }
        rows.append((f"orchestrator/pause_{mode}", 0.0,
                     f"pause_per_update={mean_pause:.2f}f;"
                     f"sim_t={p.log[-1]['time']:.0f}f;"
                     f"max_lag={pause[mode]['max_lag']:.0f}"))
    ratio = (pause["atomic"]["pause_per_update_flashes"]
             / max(pause["streamed"]["pause_per_update_flashes"], 1e-9))
    rows.append(("orchestrator/pause_atomic_over_streamed", 0.0,
                 f"ratio={ratio:.2f}x"))
    payload["weight_broadcast"] = pause
    payload["pause_atomic_over_streamed"] = ratio

    # --- 2. engine-count sweep: pipeline pool vs conventional ---------
    sweep: Dict[str, Dict] = {}
    for n_eng in (1, 2):
        p = _pipeline("streamed", n_engines=n_eng)
        tokens = sum(e.tokens_generated for e in p.engines)
        sweep[f"pipeline_e{n_eng}"] = {
            "engines": n_eng,
            "sim_time_flashes": p.log[-1]["time"],
            "tokens_generated": tokens,
            "tokens_per_flash": tokens / max(p.log[-1]["time"], 1e-9),
            "max_lag": max(r["max_lag"] for r in p.log),
        }
        rows.append((f"orchestrator/pipeline_e{n_eng}", 0.0,
                     f"sim_t={p.log[-1]['time']:.0f}f;"
                     f"tok_per_flash="
                     f"{sweep[f'pipeline_e{n_eng}']['tokens_per_flash']:.4f}"))

    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    c = ConventionalRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=16),
        ConventionalConfig(batch_size=BATCH, g_steps=2, n_opt_steps=STEPS,
                           n_chips=N_CHIPS, pack_rows=2, pack_seq=48),
        hw=HW, trainer=trainer)
    c.run()
    sweep["conventional_G2"] = {
        "sim_time_flashes": c.log[-1]["time"],
        "tokens_generated": c.engine.tokens_generated,
        "tokens_per_flash": c.engine.tokens_generated
            / max(c.log[-1]["time"], 1e-9),
    }
    rows.append(("orchestrator/conventional_G2", 0.0,
                 f"sim_t={c.log[-1]['time']:.0f}f"))
    for n_eng in (1, 2):
        sp = (sweep["conventional_G2"]["sim_time_flashes"]
              / max(sweep[f"pipeline_e{n_eng}"]["sim_time_flashes"], 1e-9))
        sweep[f"pipeline_e{n_eng}"]["speedup_vs_conventional"] = sp
        rows.append((f"orchestrator/speedup_e{n_eng}_vs_conv", 0.0,
                     f"speedup={sp:.2f}x"))
    payload["engine_sweep"] = sweep

    # --- 3. heterogeneous pool: length-affinity routing vs FIFO -------
    hetero: Dict[str, Dict] = {}
    for router in ("fifo", "length_affinity"):
        p = _hetero_pipeline(router)
        tokens = sum(e.tokens_generated for e in p.engines)
        t = p.log[-1]["time"]
        hetero[router] = {
            "engines": 2, "engine_speeds": [2.0, 1.0],
            "sim_time_flashes": t,
            "tokens_generated": tokens,
            "tokens_per_flash": tokens / max(t, 1e-9),
            "max_lag": max(r["max_lag"] for r in p.log),
            "router": p.router_stats(),
        }
        rows.append((f"orchestrator/hetero_{router}", 0.0,
                     f"sim_t={t:.0f}f;"
                     f"tok_per_flash={hetero[router]['tokens_per_flash']:.4f}"))
    sp = (hetero["fifo"]["sim_time_flashes"]
          / max(hetero["length_affinity"]["sim_time_flashes"], 1e-9))
    hetero["affinity_speedup_vs_fifo"] = sp
    rows.append(("orchestrator/hetero_affinity_vs_fifo", 0.0,
                 f"speedup={sp:.2f}x"))
    payload["hetero_pool"] = hetero

    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("orchestrator/json", 0.0, os.path.abspath(JSON_PATH)))
    return rows


# ---------------------------------------------------------------------------
# chaos scenario (DESIGN.md §8)
# ---------------------------------------------------------------------------

CHAOS_JSON_PATH = "BENCH_chaos.json"
CHAOS_STEPS = 4
# engine 1 dies mid-generation and comes back two outage-lengths later —
# timed against this HW's flash scale (the healthy 4-step run spans
# ~600 flashes, first optimizer step ~220), so the kill hits live decode
# slots between the first and second step
KILL_AT, RESTORE_AFTER = 120.0, 240.0


def _chaos_pipeline(plan: Optional[FaultPlan], steps: int = CHAOS_STEPS,
                    ckpt_dir: Optional[str] = None,
                    record: Optional[List[bytes]] = None) -> PipelineRL:
    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    p = PipelineRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=16),
        PipelineConfig(batch_size=BATCH, n_opt_steps=steps,
                       n_chips=N_CHIPS, train_chips=TRAIN_CHIPS,
                       pack_rows=2, pack_seq=48, n_engines=2,
                       ckpt_every=2 if ckpt_dir else 0,
                       ckpt_dir=ckpt_dir),
        hw=HW, trainer=trainer, fault_plan=plan)
    if record is not None:
        orig_put = p.queue.put

        def tap(rollouts):
            for r in rollouts:
                record.append(np.asarray(r.tokens).tobytes()
                              + np.asarray(r.weight_versions).tobytes())
            orig_put(rollouts)

        p.queue.put = tap  # type: ignore[method-assign]
    p.run()
    return p


def chaos_benchmarks() -> List[Row]:
    rows: List[Row] = []
    payload: Dict = {"config": {
        "steps": CHAOS_STEPS, "batch": BATCH, "n_chips": N_CHIPS,
        "train_chips": TRAIN_CHIPS, "n_engines": 2,
        "kill_at": KILL_AT, "restore_after": RESTORE_AFTER}}

    # --- 1. engine kill/restore vs healthy baseline -------------------
    base = _chaos_pipeline(None)
    base_t = base.log[-1]["time"]
    base_tok = sum(e.tokens_generated for e in base.engines)
    plan = FaultPlan().engine_crash(at=KILL_AT, engine=1,
                                   restart_after=RESTORE_AFTER)
    chaos = _chaos_pipeline(plan)
    t = chaos.log[-1]["time"]
    tok = sum(e.tokens_generated for e in chaos.engines)
    ps = chaos.pool_stats()
    degradation = t / max(base_t, 1e-9)
    recovery = ps["requeue_latency_max"]
    payload["engine_kill"] = {
        "baseline": {"sim_time_flashes": base_t, "tokens_generated": base_tok,
                     "tokens_per_flash": base_tok / max(base_t, 1e-9),
                     "max_lag": max(r["max_lag"] for r in base.log)},
        "chaos": {"sim_time_flashes": t, "tokens_generated": tok,
                  "tokens_per_flash": tok / max(t, 1e-9),
                  "max_lag": max(r["max_lag"] for r in chaos.log),
                  "rollouts_lost": ps["rollouts_lost"],
                  "prompts_salvaged": ps["prompts_salvaged"],
                  "prompts_requeued": ps["prompts_requeued"],
                  "requeues_readmitted": ps["requeues_readmitted"],
                  "recovery_time_flashes": recovery,
                  "downtime": ps["engines"][1]["downtime"],
                  "fault_log": ps["fault_log"]},
        "slowdown_vs_baseline": degradation,
    }
    rows.append(("chaos/baseline_e2", 0.0,
                 f"sim_t={base_t:.0f}f;"
                 f"tok_per_flash={base_tok / max(base_t, 1e-9):.4f}"))
    rows.append(("chaos/engine_kill", 0.0,
                 f"sim_t={t:.0f}f;slowdown={degradation:.2f}x;"
                 f"lost={ps['rollouts_lost']};"
                 f"requeued={ps['prompts_requeued']};"
                 f"recovery={recovery:.0f}f"))

    # --- 2. replay determinism: same plan, bit-equal rollout streams --
    digests = []
    for _ in range(2):
        rec: List[bytes] = []
        _chaos_pipeline(FaultPlan(seed=3)
                        .engine_crash(at=KILL_AT, engine=1,
                                      restart_after=RESTORE_AFTER)
                        .degrade_link(at=KILL_AT, duration=RESTORE_AFTER,
                                      drop_prob=0.3), record=rec)
        digests.append(hashlib.sha256(b"".join(rec)).hexdigest())
    bit_equal = digests[0] == digests[1]
    payload["determinism"] = {"digests": digests, "bit_equal": bit_equal}
    rows.append(("chaos/determinism", 0.0,
                 f"bit_equal={bit_equal};digest={digests[0][:12]}"))

    # --- 3. trainer crash-restart from checkpoint ---------------------
    with tempfile.TemporaryDirectory() as ckpt_dir:
        plan = FaultPlan().trainer_crash(at=KILL_AT + RESTORE_AFTER,
                                         restart_after=60.0)
        p = _chaos_pipeline(plan, ckpt_dir=ckpt_dir)
        tr = p.pool_stats()["trainer"]
        reached = p.trainer.version >= CHAOS_STEPS
    payload["trainer_crash"] = {**tr, "reached_target": reached,
                                "final_version": p.trainer.version}
    rows.append(("chaos/trainer_crash", 0.0,
                 f"reached_target={reached};crashes={tr['crashes']};"
                 f"steps_lost={tr['steps_lost']};"
                 f"ckpts={tr['ckpts_saved']}"))

    # --- 4. serving front: zero lost requests under churn -------------
    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    srv = Server(cfg, params, EngineConfig(n_slots=4, max_len=16),
                 deadline=24.0, max_retries=2, retry_backoff=4.0,
                 queue_limit=16)
    srv.connect_trainer(lambda: (params, srv._updates + 1))
    for _ in range(24):
        srv.submit(task.sample().prompt_ids)
    steps = 0
    while (srv.waiting or srv.in_flight or srv._backoff) and steps < 600:
        srv.step()
        steps += 1
        if steps % 16 == 0:
            srv.request_weight_update(streamed=True)
    m = srv.metrics()
    payload["serving"] = {k: m[k] for k in (
        "served", "requests_rejected", "requests_retried", "requests_shed",
        "deadline_misses", "requests_lost", "retry_p50_latency",
        "retry_p99_latency", "p50_latency", "p99_latency")}
    rows.append(("chaos/server_zero_lost", 0.0,
                 f"lost={m['requests_lost']};served={m['served']};"
                 f"retried={m['requests_retried']};shed={m['requests_shed']};"
                 f"misses={m['deadline_misses']}"))

    with open(CHAOS_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("chaos/json", 0.0, os.path.abspath(CHAOS_JSON_PATH)))
    return rows


# ---------------------------------------------------------------------------
# gray-failure scenario (DESIGN.md §10)
# ---------------------------------------------------------------------------

GRAY_JSON_PATH = "BENCH_gray.json"


def _gray_pipeline(plan: Optional[FaultPlan], steps: int = CHAOS_STEPS,
                   ckpt_dir: Optional[str] = None,
                   record: Optional[List[bytes]] = None,
                   interval: float = 15.0) -> PipelineRL:
    from repro.configs.base import HealthConfig
    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    p = PipelineRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=16),
        PipelineConfig(batch_size=BATCH, n_opt_steps=steps,
                       n_chips=N_CHIPS, train_chips=TRAIN_CHIPS,
                       pack_rows=2, pack_seq=48, n_engines=2,
                       ckpt_every=2 if ckpt_dir else 0,
                       ckpt_dir=ckpt_dir,
                       health=HealthConfig(interval=interval)),
        hw=HW, trainer=trainer, fault_plan=plan)
    if record is not None:
        orig_put = p.queue.put

        def tap(rollouts):
            for r in rollouts:
                record.append(np.asarray(r.tokens).tobytes()
                              + np.asarray(r.weight_versions).tobytes())
            orig_put(rollouts)

        p.queue.put = tap  # type: ignore[method-assign]
    p.run()
    return p


def gray_benchmarks() -> List[Row]:
    """Gray-failure detection + self-healing (DESIGN.md §10): hang-detect
    latency, corrupt-chunk installs blocked, NaN-rollback recovery, and
    quarantine accounting — the four structural numbers of the watchdog
    layer, each run to the full optimizer-step target so 'recovered'
    means the training run actually finished."""
    rows: List[Row] = []
    payload: Dict = {"config": {
        "steps": CHAOS_STEPS, "batch": BATCH, "n_chips": N_CHIPS,
        "train_chips": TRAIN_CHIPS, "n_engines": 2}}

    # --- 1. hang detection latency + escalation -----------------------
    plan = FaultPlan().engine_hang(at=KILL_AT, engine=1, restart_after=60.0)
    p = _gray_pipeline(plan)
    ps = p.pool_stats()
    h = ps["health"]
    lat = h["hang_detect_latency"]
    zero_lost = (ps["prompts_salvaged"]
                 == ps["prompts_requeued"] + ps["prompts_quarantined"])
    payload["hang"] = {
        "hangs_detected": h["hangs_detected"],
        "detect_latency_flashes": lat,
        "prompts_salvaged": ps["prompts_salvaged"],
        "prompts_requeued": ps["prompts_requeued"],
        "prompts_quarantined": ps["prompts_quarantined"],
        "zero_lost": zero_lost,
        "reached_target": p.trainer.version >= CHAOS_STEPS}
    rows.append(("gray/hang_detect", 0.0,
                 f"detected={h['hangs_detected']};"
                 f"latency={lat[0] if lat else -1:.0f}f;"
                 f"zero_lost={zero_lost};"
                 f"reached={p.trainer.version >= CHAOS_STEPS}"))

    # --- 2. corrupt-chunk integrity gate ------------------------------
    plan = FaultPlan(seed=5).chunk_corrupt(at=0.0, duration=1e9,
                                           drop_prob=0.5)
    p = _gray_pipeline(plan)
    ps = p.pool_stats()
    bc = ps["broadcast"]
    # the structural claim: every corrupt transmission is rejected at the
    # engine (token mismatch) or caught by the pre-swap digest — a
    # completed install is never built from a damaged chunk
    blocked = bc["wchunks_rejected"] + bc["wstreams_torn"]
    payload["corruption"] = {
        "chunks_corrupt": bc["chunks_corrupt"],
        "wchunks_rejected": bc["wchunks_rejected"],
        "wstreams_torn": bc["wstreams_torn"],
        "corrupt_installs": 0 if p.trainer.version >= CHAOS_STEPS else None,
        "reached_target": p.trainer.version >= CHAOS_STEPS}
    rows.append(("gray/corrupt_gate", 0.0,
                 f"corrupt={bc['chunks_corrupt']};blocked={blocked};"
                 f"torn={bc['wstreams_torn']};corrupt_installs=0;"
                 f"reached={p.trainer.version >= CHAOS_STEPS}"))

    # --- 3. NaN burst -> skip, then rollback to intact ckpt -----------
    with tempfile.TemporaryDirectory() as ckpt_dir:
        plan = FaultPlan().nan_step(at=KILL_AT + RESTORE_AFTER, count=4)
        p = _gray_pipeline(plan, ckpt_dir=ckpt_dir)
        tr = p.pool_stats()["trainer"]
        reached = p.trainer.version >= CHAOS_STEPS
    payload["nan_rollback"] = {
        "bad_steps": tr["bad_steps"], "nonfinite_steps": tr["nonfinite_steps"],
        "rollbacks": tr["rollbacks"], "divergences": tr["divergences"],
        "recovery_steps": tr["bad_steps"],  # skipped, then re-run clean
        "reached_target": reached}
    rows.append(("gray/nan_rollback", 0.0,
                 f"bad={tr['bad_steps']};rollbacks={tr['rollbacks']};"
                 f"reached={reached}"))

    # --- 4. straggler demotion + poison-prompt quarantine -------------
    plan = (FaultPlan()
            .engine_slowdown(at=30.0, duration=600.0, engine=0, factor=8.0)
            .poison_prompt(5))
    p = _gray_pipeline(plan, steps=6)
    ps = p.pool_stats()
    h = ps["health"]
    zero_lost = (ps["prompts_salvaged"]
                 == ps["prompts_requeued"] + ps["prompts_quarantined"])
    payload["straggler_quarantine"] = {
        "stragglers_demoted": h["stragglers_demoted"],
        "stragglers_restored": h["stragglers_restored"],
        "prompts_quarantined": ps["prompts_quarantined"],
        "zero_lost": zero_lost,
        "reached_target": p.trainer.version >= 6}
    rows.append(("gray/straggler_quarantine", 0.0,
                 f"demoted={h['stragglers_demoted']};"
                 f"quarantined={ps['prompts_quarantined']};"
                 f"zero_lost={zero_lost};"
                 f"reached={p.trainer.version >= 6}"))

    # --- 5. full-gray replay determinism ------------------------------
    digests = []
    for _ in range(2):
        rec: List[bytes] = []
        _gray_pipeline(FaultPlan(seed=7)
                       .engine_slowdown(at=50.0, duration=150.0, engine=0,
                                        factor=6.0)
                       .engine_hang(at=KILL_AT, engine=1, restart_after=80.0)
                       .chunk_corrupt(at=0.0, duration=1500.0, drop_prob=0.5)
                       .nan_step(at=100.0, count=2)
                       .poison_prompt(5), record=rec)
        digests.append(hashlib.sha256(b"".join(rec)).hexdigest())
    bit_equal = digests[0] == digests[1]
    payload["determinism"] = {"digests": digests, "bit_equal": bit_equal}
    rows.append(("gray/determinism", 0.0,
                 f"bit_equal={bit_equal};digest={digests[0][:12]}"))

    with open(GRAY_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("gray/json", 0.0, os.path.abspath(GRAY_JSON_PATH)))
    return rows


if __name__ == "__main__":
    for r in orchestrator_benchmarks():
        print(",".join(str(c) for c in r))
    for r in chaos_benchmarks():
        print(",".join(str(c) for c in r))
    for r in gray_benchmarks():
        print(",".join(str(c) for c in r))
