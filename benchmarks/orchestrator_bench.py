"""Orchestrator hot path (DESIGN.md §7): the event-driven substrate's two
new measurable surfaces.

  1. per-update decode pause, streamed vs atomic weight publication —
     the paper's "the engine only briefly pauses for new weights" as a
     number: atomic publications stall decode for the whole
     `HardwareModel.broadcast_time`, streamed ones only pay the
     per-chunk install + pointer swap while the transfer overlaps decode
  2. pipeline-vs-conventional throughput (simulated flashes to a fixed
     optimizer-step budget) across actor-pool sizes — the engine-count
     sweep the single-engine orchestrator couldn't express
  3. heterogeneous pool scheduling: a 2-engine pool with a 2x/1x chip
     split fed a bimodal prompt-length stream, length-affinity routing
     vs FIFO — long prompts (cheap prefill, short remaining completion
     budget) land on the fast chip, so the straggler engine stops
     gating the SampleQueue

Emits ``BENCH_orchestrator.json`` (same schema discipline as
``BENCH_trainer.json``) so the perf trajectory covers the orchestration
layer too.

    PYTHONPATH=src python -m benchmarks.run --only orchestrator
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import tiny_setup
from repro.core.conventional import ConventionalConfig, ConventionalRL
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.rollout import EngineConfig
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.optim.adam import AdamConfig

Row = Tuple[str, float, str]

JSON_PATH = "BENCH_orchestrator.json"
STEPS = 4
BATCH = 4
N_CHIPS, TRAIN_CHIPS = 8, 4
# slow interconnect so the broadcast cost is visible against the tiny
# model's decode steps (the *ratio* streamed/atomic is the structural
# result; absolute flash numbers scale with the knob)
HW = HardwareModel(h_sat=16, bcast_bytes_per_flash=2e3,
                   bcast_install_flash=1.0)


def _bimodal_source(task, long_len: int = 26):
    """Deterministic alternating short/long prompt stream: every other
    prompt is left-padded with leading zeros after BOS to `long_len`
    tokens — same answer, same reward, ~4x the prefill work. The fixed
    task seed makes the stream identical across router policies."""
    zero = task.tok.stoi["0"]

    def sample():
        prob = task.sample()
        i = sample.i
        sample.i += 1
        if i % 2:
            pad = long_len - len(prob.prompt_ids)
            if pad > 0:
                prob.prompt_ids = ([prob.prompt_ids[0]] + [zero] * pad
                                   + prob.prompt_ids[1:])
        return prob

    sample.i = 0
    return sample


# generation-bound variant for the hetero scenario: a fast trainer keeps
# the sim time gated by rollout arrival, so the router's effect on the
# *generation* side is what the number measures (with the default tau the
# run is trainer-bound and any routing policy washes out)
HW_HETERO = HardwareModel(h_sat=16, tau=0.8)


def _hetero_pipeline(router: str, steps: int = 6) -> PipelineRL:
    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    p = PipelineRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=32),
        PipelineConfig(batch_size=BATCH, n_opt_steps=steps,
                       n_chips=N_CHIPS, train_chips=TRAIN_CHIPS,
                       pack_rows=2, pack_seq=48, n_engines=2,
                       engine_speeds=[2.0, 1.0], router=router),
        hw=HW_HETERO, trainer=trainer, prompt_source=_bimodal_source(task))
    p.run()
    return p


def _pipeline(broadcast: str, n_engines: int = 1,
              steps: int = STEPS) -> PipelineRL:
    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    p = PipelineRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=16),
        PipelineConfig(batch_size=BATCH, n_opt_steps=steps,
                       n_chips=N_CHIPS, train_chips=TRAIN_CHIPS,
                       pack_rows=2, pack_seq=48, n_engines=n_engines,
                       broadcast=broadcast),
        hw=HW, trainer=trainer)
    p.run()
    return p


def orchestrator_benchmarks() -> List[Row]:
    rows: List[Row] = []
    payload: Dict = {"config": {
        "steps": STEPS, "batch": BATCH, "n_chips": N_CHIPS,
        "train_chips": TRAIN_CHIPS,
        "bcast_bytes_per_flash": HW.bcast_bytes_per_flash,
        "bcast_install_flash": HW.bcast_install_flash}}

    # --- 1. per-update decode pause: streamed vs atomic vs free -------
    pause: Dict[str, Dict] = {}
    for mode in ("free", "streamed", "atomic"):
        p = _pipeline(mode)
        st = p.broadcast_stats()
        per_eng = st["engines"]
        mean_pause = float(np.mean([e["pause_per_update"] for e in per_eng
                                    if e["updates_applied"]] or [0.0]))
        pause[mode] = {
            "published": st["published"],
            "updates_applied": sum(e["updates_applied"] for e in per_eng),
            "pause_per_update_flashes": mean_pause,
            "sim_time_flashes": p.log[-1]["time"],
            "max_lag": max(r["max_lag"] for r in p.log),
        }
        rows.append((f"orchestrator/pause_{mode}", 0.0,
                     f"pause_per_update={mean_pause:.2f}f;"
                     f"sim_t={p.log[-1]['time']:.0f}f;"
                     f"max_lag={pause[mode]['max_lag']:.0f}"))
    ratio = (pause["atomic"]["pause_per_update_flashes"]
             / max(pause["streamed"]["pause_per_update_flashes"], 1e-9))
    rows.append(("orchestrator/pause_atomic_over_streamed", 0.0,
                 f"ratio={ratio:.2f}x"))
    payload["weight_broadcast"] = pause
    payload["pause_atomic_over_streamed"] = ratio

    # --- 2. engine-count sweep: pipeline pool vs conventional ---------
    sweep: Dict[str, Dict] = {}
    for n_eng in (1, 2):
        p = _pipeline("streamed", n_engines=n_eng)
        tokens = sum(e.tokens_generated for e in p.engines)
        sweep[f"pipeline_e{n_eng}"] = {
            "engines": n_eng,
            "sim_time_flashes": p.log[-1]["time"],
            "tokens_generated": tokens,
            "tokens_per_flash": tokens / max(p.log[-1]["time"], 1e-9),
            "max_lag": max(r["max_lag"] for r in p.log),
        }
        rows.append((f"orchestrator/pipeline_e{n_eng}", 0.0,
                     f"sim_t={p.log[-1]['time']:.0f}f;"
                     f"tok_per_flash="
                     f"{sweep[f'pipeline_e{n_eng}']['tokens_per_flash']:.4f}"))

    task, cfg, params = tiny_setup(d_model=64, n_layers=1)
    trainer = Trainer(cfg, params, adam=AdamConfig(lr=1e-3))
    c = ConventionalRL(
        cfg, params, task, EngineConfig(n_slots=8, max_len=16),
        ConventionalConfig(batch_size=BATCH, g_steps=2, n_opt_steps=STEPS,
                           n_chips=N_CHIPS, pack_rows=2, pack_seq=48),
        hw=HW, trainer=trainer)
    c.run()
    sweep["conventional_G2"] = {
        "sim_time_flashes": c.log[-1]["time"],
        "tokens_generated": c.engine.tokens_generated,
        "tokens_per_flash": c.engine.tokens_generated
            / max(c.log[-1]["time"], 1e-9),
    }
    rows.append(("orchestrator/conventional_G2", 0.0,
                 f"sim_t={c.log[-1]['time']:.0f}f"))
    for n_eng in (1, 2):
        sp = (sweep["conventional_G2"]["sim_time_flashes"]
              / max(sweep[f"pipeline_e{n_eng}"]["sim_time_flashes"], 1e-9))
        sweep[f"pipeline_e{n_eng}"]["speedup_vs_conventional"] = sp
        rows.append((f"orchestrator/speedup_e{n_eng}_vs_conv", 0.0,
                     f"speedup={sp:.2f}x"))
    payload["engine_sweep"] = sweep

    # --- 3. heterogeneous pool: length-affinity routing vs FIFO -------
    hetero: Dict[str, Dict] = {}
    for router in ("fifo", "length_affinity"):
        p = _hetero_pipeline(router)
        tokens = sum(e.tokens_generated for e in p.engines)
        t = p.log[-1]["time"]
        hetero[router] = {
            "engines": 2, "engine_speeds": [2.0, 1.0],
            "sim_time_flashes": t,
            "tokens_generated": tokens,
            "tokens_per_flash": tokens / max(t, 1e-9),
            "max_lag": max(r["max_lag"] for r in p.log),
            "router": p.router_stats(),
        }
        rows.append((f"orchestrator/hetero_{router}", 0.0,
                     f"sim_t={t:.0f}f;"
                     f"tok_per_flash={hetero[router]['tokens_per_flash']:.4f}"))
    sp = (hetero["fifo"]["sim_time_flashes"]
          / max(hetero["length_affinity"]["sim_time_flashes"], 1e-9))
    hetero["affinity_speedup_vs_fifo"] = sp
    rows.append(("orchestrator/hetero_affinity_vs_fifo", 0.0,
                 f"speedup={sp:.2f}x"))
    payload["hetero_pool"] = hetero

    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("orchestrator/json", 0.0, os.path.abspath(JSON_PATH)))
    return rows


if __name__ == "__main__":
    for r in orchestrator_benchmarks():
        print(",".join(str(c) for c in r))
