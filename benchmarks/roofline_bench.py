"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun_*.json produced by `python -m repro.launch.dryrun`;
falls back to compiling one cheap combo live if no artifacts exist."""
from __future__ import annotations

import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def roofline_rows() -> List[Row]:
    path = os.path.join(RESULTS, "dryrun_1pod.json")
    if not os.path.exists(path):
        return [("roofline/missing", 0.0,
                 "run: python -m repro.launch.dryrun --all --out "
                 "results/dryrun_1pod.json")]
    rows: List[Row] = []
    with open(path) as f:
        recs = json.load(f)
    for r in recs:
        if not r.get("ok"):
            rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0, "FAILED"))
            continue
        ratio = r.get("useful_flops_ratio")
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"tc={r['t_compute_s']:.2e}s tm={r['t_memory_s']:.2e}s "
            f"tx={r['t_collective_s']:.2e}s dom={r['bottleneck']} "
            f"useful={ratio:.2f}" if ratio else
            f"tc={r['t_compute_s']:.2e}s dom={r['bottleneck']}"))
    return rows
