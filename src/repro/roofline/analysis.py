"""Three-term roofline analysis from a compiled dry-run artifact.

  compute    = HLO_FLOPs   / (peak FLOP/s per chip)
  memory     = HLO bytes   / (HBM bandwidth per chip)
  collective = bytes moved by all-gather/all-reduce/reduce-scatter/
               all-to-all/collective-permute / (ICI link bandwidth)

XLA's cost_analysis() is per-device for SPMD programs; collective bytes are
not in cost_analysis, so they are summed from the (post-SPMD) HLO text.
Hardware constants: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e
PEAK_FLOPS = 197e12   # bf16 FLOP/s per chip
HBM_BW = 819e9        # bytes/s per chip
ICI_BW = 50e9         # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device) from HLO text.
    '-done' ops are skipped so async pairs are not double counted."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if m.group(0).rstrip("(").endswith("-done"):
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    flops: float            # per device
    bytes_accessed: float   # per device
    coll_bytes: float       # per device
    coll_breakdown: Dict[str, int]
    n_devices: int
    model_flops: Optional[float] = None   # 6*N*D (global, useful work)
    bytes_per_device: Optional[float] = None  # peak memory (argument+temp)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if not self.model_flops:
            return None
        return self.model_flops / max(self.flops * self.n_devices, 1.0)

    def row(self) -> Dict:
        return {
            "name": self.name,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_gflops_per_dev": self.flops / 1e9,
            "hlo_gbytes_per_dev": self.bytes_accessed / 1e9,
            "coll_gbytes_per_dev": self.coll_bytes / 1e9,
            "coll_breakdown": {k: v for k, v in self.coll_breakdown.items() if v},
            "useful_flops_ratio": self.useful_flops_ratio,
            "mem_gb_per_dev": (self.bytes_per_device or 0) / 1e9,
        }


def analyze(name: str, compiled, n_devices: int,
            model_flops: Optional[float] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    + getattr(ma, "temp_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        name=name, flops=flops, bytes_accessed=bytes_accessed,
        coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
        n_devices=n_devices, model_flops=model_flops,
        bytes_per_device=mem,
    )


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D for training, 2*N*D for inference forward (N = active params,
    D = tokens processed)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq
