"""Calibrated roofline: correct for XLA cost_analysis counting `lax.scan`
bodies exactly once (trip count is invisible to the static cost analysis —
verified empirically: FLOPs are constant in n_layers for scanned stacks).

Method: compile with the layer scans FULLY UNROLLED at per-group layer
counts 1 and 2 (straight-line HLO, so every op is counted):

    body_g   = f(counts with g=2) - f(counts all 1)
    outside  = f(all 1) - sum_g body_g
    total(L) = outside + sum_g L_g * body_g

Collective bytes and bytes-accessed get the same treatment (the HLO-text
collective parser sees the unrolled collectives). Gradient-accumulation
microbatch loops are calibrated at mb=1 (FLOPs are mb-invariant; HBM bytes
gain (mb-1) weight re-reads, approximated analytically and documented).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeSpec, for_shape
from repro.models.model import layer_groups
from repro.roofline.analysis import Roofline, analyze, model_flops_estimate


def _with_group_counts(cfg: ModelConfig, counts) -> ModelConfig:
    groups = layer_groups(cfg)
    assert len(counts) == len(groups)
    total = sum(counts)
    if cfg.n_experts and cfg.n_dense_layers:
        return dataclasses.replace(cfg, n_layers=total,
                                   n_dense_layers=counts[0])
    return dataclasses.replace(cfg, n_layers=total)


def _measure(cfg: ModelConfig, shape: ShapeSpec, mesh,
             rules=None) -> Dict[str, float]:
    from repro.launch.steps import lower_program
    prog = lower_program(cfg, shape, mesh, microbatch=1, rules=rules)
    compiled = prog.compile()
    r = analyze(prog.name, compiled, mesh.devices.size)
    return {"flops": r.flops, "bytes": r.bytes_accessed, "coll": r.coll_bytes}


def calibrated_roofline(arch_cfg: ModelConfig, shape: ShapeSpec, mesh,
                        microbatch: int = 1,
                        mem_bytes_per_device: float = 0.0,
                        rules=None) -> Roofline:
    cfg = dataclasses.replace(for_shape(arch_cfg, shape), scan_unroll=True)
    groups = layer_groups(cfg)
    n_groups = len(groups)
    real_counts = [c for _, c in groups]

    base = _measure(_with_group_counts(cfg, [1] * n_groups), shape, mesh,
                    rules=rules)
    bodies = []
    for g in range(n_groups):
        counts = [1] * n_groups
        counts[g] = 2
        inc = _measure(_with_group_counts(cfg, counts), shape, mesh,
                       rules=rules)
        bodies.append({k: inc[k] - base[k] for k in base})

    # clamp: XLA may fuse slightly differently between the two compiles;
    # a tiny negative delta is measurement noise, not negative work
    bodies = [{k: max(0.0, v) for k, v in b.items()} for b in bodies]
    outside = {k: max(0.0, base[k] - sum(b[k] for b in bodies))
               for k in base}
    tot = dict(outside)
    for g in range(n_groups):
        for k in tot:
            tot[k] += real_counts[g] * bodies[g][k]

    if microbatch > 1:
        # weight re-reads: each extra microbatch re-streams the (sharded)
        # parameters from HBM for forward+backward (~3 reads of 2 bytes)
        w_bytes_dev = cfg.param_count() * 2 / mesh.devices.size
        tot["bytes"] += (microbatch - 1) * 3.0 * w_bytes_dev
        # FSDP weight all-gathers repeat per microbatch; the per-microbatch
        # activation collectives shrink 1/mb, so total collective bytes are
        # bounded by the measured value times mb for gathers — approximate
        # with the gather share ~= weight bytes gathered over "data"
        tot["coll"] += (microbatch - 1) * w_bytes_dev

    return Roofline(
        name=f"{cfg.name}:{shape.name}:calibrated(mb={microbatch})",
        flops=tot["flops"], bytes_accessed=tot["bytes"],
        coll_bytes=tot["coll"], coll_breakdown={},
        n_devices=mesh.devices.size,
        model_flops=model_flops_estimate(cfg, shape),
        bytes_per_device=mem_bytes_per_device,
    )
