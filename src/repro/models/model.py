"""The composable decoder LM: one definition covering all 10 assigned
architectures (dense GQA, MLA+MoE, pure-SSM, hybrid, VLM/audio prefix).

Layers with identical structure are stacked and scanned (`lax.scan`), which
keeps the HLO size O(1) in depth — essential for compiling 61-layer models
on the 512-device dry-run mesh. Heterogeneous stacks (DeepSeek's 3 dense +
58 MoE layers) become consecutive scan *groups*.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, effective_cache_len
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import ParamDef, build_params, ffn_defs, rms_norm, swiglu
from repro.shardctx import constrain


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

def layer_groups(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """[(ffn_kind, n_layers)] — ffn_kind in {dense, moe, none}."""
    if cfg.n_experts:
        if cfg.n_dense_layers:
            return [("dense", cfg.n_dense_layers),
                    ("moe", cfg.n_layers - cfg.n_dense_layers)]
        return [("moe", cfg.n_layers)]
    if cfg.d_ff == 0:
        return [("none", cfg.n_layers)]
    return [("dense", cfg.n_layers)]


def _group_defs(cfg: ModelConfig, kind: str, count: int) -> Dict[str, Any]:
    d, dt = cfg.d_model, cfg.dtype
    g: Dict[str, Any] = {
        "norm1": ParamDef((count, d), ("layers", "p_embed"), dt, -1.0),
    }
    if cfg.has_attention:
        g["attn"] = attn.attention_defs(cfg, count)
    if cfg.has_ssm:
        g["ssm"] = ssm_mod.ssm_defs(cfg, count)
    if cfg.hybrid_parallel:
        # Hymba: per-branch output norms fused by averaging [arXiv:2411.13676]
        g["hyb_norm_a"] = ParamDef((count, d), ("layers", "p_embed"), dt, -1.0)
        g["hyb_norm_s"] = ParamDef((count, d), ("layers", "p_embed"), dt, -1.0)
    if kind == "dense":
        ff = cfg.dense_d_ff if (cfg.n_experts and cfg.dense_d_ff) else cfg.d_ff
        g["norm2"] = ParamDef((count, d), ("layers", "p_embed"), dt, -1.0)
        g["ffn"] = ffn_defs(d, ff, count, dt)
    elif kind == "moe":
        g["norm2"] = ParamDef((count, d), ("layers", "p_embed"), dt, -1.0)
        g["moe"] = moe_mod.moe_defs(cfg, count)
    return g


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, dt, V = cfg.d_model, cfg.dtype, cfg.vocab_size
    defs: Dict[str, Any] = {
        "embed": ParamDef((V, d), ("p_embed_vocab", "p_embed"), dt),
        "final_norm": ParamDef((d,), ("p_embed",), dt, -1.0),
        "groups": [
            _group_defs(cfg, kind, count) for kind, count in layer_groups(cfg)
        ],
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, V), ("p_embed", "p_vocab"), dt)
    if cfg.use_value_head:
        defs["value_head"] = ParamDef((d, 1), ("p_embed", None), jnp.float32, 0.0)
    if cfg.modality in ("vision", "audio"):
        # learned projector from the (stubbed) frontend embedding space
        defs["mm_proj"] = ParamDef((d, d), ("p_embed", None), dt)
    if cfg.use_mtp:
        defs["mtp"] = {
            "proj": ParamDef((2 * d, d), ("p_embed", "p_embed"), dt),
            "norm_h": ParamDef((d,), ("p_embed",), dt, -1.0),
            "norm_e": ParamDef((d,), ("p_embed",), dt, -1.0),
            "layer": _group_defs(cfg, "dense", 1),
        }
    return defs


def init_params(cfg: ModelConfig, key=None, abstract: bool = False):
    """Annotated param tree (Annotated leaves carry logical axes)."""
    return build_params(param_defs(cfg), key=key, abstract=abstract)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_forward(cfg: ModelConfig, kind: str, h, lp, positions, segment_ids,
                   return_kv: bool):
    """One decoder layer; h: (B,S,d). Returns (h, aux, kv_for_cache)."""
    aux = jnp.zeros((), jnp.float32)
    x = rms_norm(h, lp["norm1"], cfg.norm_eps)
    kv = None
    if cfg.hybrid_parallel:
        a, kv_a = attn.gqa_forward(lp["attn"], x, positions, cfg,
                                   segment_ids, return_kv=True)
        s, st = ssm_mod.ssm_forward(lp["ssm"], x, cfg, return_state=True)
        mix = 0.5 * (rms_norm(a, lp["hyb_norm_a"], cfg.norm_eps)
                     + rms_norm(s, lp["hyb_norm_s"], cfg.norm_eps))
        h = h + mix
        kv = {"k": kv_a[0], "v": kv_a[1], "conv": st[0], "ssd": st[1]}
    elif cfg.arch_type == "ssm":
        s, st = ssm_mod.ssm_forward(lp["ssm"], x, cfg, return_state=True)
        h = h + s
        kv = {"conv": st[0], "ssd": st[1]}
    else:
        fwd = attn.mla_forward if cfg.use_mla else attn.gqa_forward
        a, kv_a = fwd(lp["attn"], x, positions, cfg, segment_ids, return_kv=True)
        h = h + a
        if cfg.use_mla:
            kv = {"c_kv": kv_a[0], "k_rope": kv_a[1]}
        else:
            kv = {"k": kv_a[0], "v": kv_a[1]}
    h = constrain(h, ("batch", "seq", "embed"))

    if kind == "dense":
        x = rms_norm(h, lp["norm2"], cfg.norm_eps)
        f = lp["ffn"]
        h = h + swiglu(x, f["gate"], f["up"], f["down"])
    elif kind == "moe":
        x = rms_norm(h, lp["norm2"], cfg.norm_eps)
        mo, aux = moe_mod.moe_apply(lp["moe"], x, cfg)
        h = h + mo
    h = constrain(h, ("batch", "seq", "embed"))
    return h, aux, (kv if return_kv else None)


def forward(params, tokens, positions, cfg: ModelConfig, *,
            segment_ids=None, prefix_embeds=None, return_cache: bool = False,
            return_hidden: bool = False, loss_targets=None):
    """Full-sequence forward.

    tokens: (B,S) int32; positions: (B,S) int32.
    Returns dict(logits, values?, aux_loss, cache?, hidden?).
    The multimodal prefix (if any) is prepended; its rows are stripped from
    logits/values so downstream shapes match `tokens`.

    loss_targets: optional (B,S) int32 next-token targets (position t holds
    the token logits[t] should score, i.e. tokens[t+1]; the last column is
    a dead pad). With `cfg.fused_loss` set, the head matmul + cross-entropy
    fuse into the blockwise kernel (`kernels.fused_logprob`): no logits are
    materialized and the output carries `token_logprobs` / `lse` /
    `entropy` instead, each (B,S) f32 aligned with `tokens` the way
    `algo.token_logprobs` aligns them (entry t describes the distribution
    that scored token t; entry 0 is a zero pad). The MTP head rides the
    same fused call (per-draft stats `mtp_token_logprobs` / `mtp_lse` /
    `mtp_entropy` instead of `mtp_logits`); value head and the MoE aux
    loss are unchanged.
    """
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    n_prefix = 0
    if prefix_embeds is not None:
        n_prefix = prefix_embeds.shape[1]
        pe = jnp.einsum("bpd,de->bpe", prefix_embeds.astype(cfg.dtype),
                        params["mm_proj"])
        h = jnp.concatenate([pe, h], axis=1)
        positions = jnp.concatenate(
            [jnp.broadcast_to(jnp.arange(n_prefix, dtype=positions.dtype)[None],
                              (B, n_prefix)),
             positions + n_prefix], axis=1)
        if segment_ids is not None:
            segment_ids = jnp.concatenate(
                [jnp.zeros((B, n_prefix), segment_ids.dtype), segment_ids], axis=1)
    h = constrain(h, ("batch", "seq", "embed"))

    total_aux = jnp.zeros((), jnp.float32)
    caches = []
    for gi, (kind, count) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]

        def scan_body(carry, lp, _kind=kind):
            hh, aux_acc = carry
            hh, aux, kv = _layer_forward(cfg, _kind, hh, lp, positions,
                                         segment_ids, return_cache)
            return (hh, aux_acc + aux), kv

        if cfg.remat:
            # activation checkpointing: save only the per-layer residual
            # stream; recompute attention/FFN internals in the backward pass
            scan_body = jax.checkpoint(
                scan_body,
                policy=jax.checkpoint_policies.nothing_saveable)
        (h, total_aux), kvs = jax.lax.scan(scan_body, (h, total_aux), gp,
                                           unroll=True if cfg.scan_unroll else 1)
        if return_cache:
            caches.append(kvs)

    hidden = h
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    out = {"aux_loss": total_aux, "n_prefix": n_prefix}
    fused = cfg.fused_loss and loss_targets is not None
    if fused:
        out.update(_fused_loss_stats(params, cfg, h[:, n_prefix:],
                                     loss_targets))
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", h, head)
        logits = constrain(logits, ("batch", "seq", "vocab"))
        out["logits"] = logits[:, n_prefix:]
    if cfg.use_value_head:
        values = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                            params["value_head"])[..., 0]
        out["values"] = values[:, n_prefix:]
    if cfg.use_mtp:
        if fused:
            out.update(_mtp_fused_stats(params, cfg, hidden, tokens,
                                        positions, n_prefix))
        else:
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
            out["mtp_logits"] = _mtp_forward(params, cfg, hidden, tokens,
                                             positions, n_prefix, head)
    if return_cache:
        out["cache"] = _stack_group_caches(cfg, caches)
    if return_hidden:
        out["hidden"] = hidden[:, n_prefix:]
    return out


def _fused_head_stats(params, cfg: ModelConfig, hs, tgt):
    """Shared fused lm-head routing for the loss and MTP stats: hs (N,D)
    rows against the lm head, targets (N,) int32. Returns (lp, lse, ent).

    Tied embeddings pass `params["embed"]` in its native (V,D) layout
    (`transpose_head`) so no transposed head copy is materialized. When a
    mesh is active (`shardctx.sharding_context`) and the head's vocab
    logical axis maps to a mesh axis, the call routes through
    `fused_logprob_sharded`: each shard runs the ordinary fused path on
    its V/n head slice and the global stats come from three (N,) psums —
    the (N,V)-free property then holds per shard (DESIGN.md §11). The
    sharded wrapper itself falls back to the single-device call when the
    axis is absent, size 1, or does not divide V, so routing here is
    unconditional on mesh presence only."""
    from repro.shardctx import current_mesh, current_rules
    if cfg.tie_embeddings:
        head, transpose_head = params["embed"], True
        logical = "p_embed_vocab"
    else:
        head, transpose_head = params["lm_head"], False
        logical = "p_vocab"
    mesh = current_mesh()
    if mesh is not None:
        from repro.sharding import DEFAULT_RULES
        rules = dict(DEFAULT_RULES, **(current_rules() or {}))
        axis = rules.get(logical)
        if isinstance(axis, str):
            from repro.kernels.fused_logprob import fused_logprob_sharded
            return fused_logprob_sharded(
                hs, head, tgt, mesh=mesh, axis_name=axis,
                transpose_head=transpose_head, use_pallas=cfg.use_pallas,
                interpret=cfg.pallas_interpret)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        return kops.fused_logprob(
            hs, head, tgt, transpose_head=transpose_head,
            interpret=cfg.pallas_interpret)
    from repro.kernels.fused_logprob import fused_logprob_blocked
    return fused_logprob_blocked(hs, head, tgt,
                                 transpose_head=transpose_head)


def _fused_loss_stats(params, cfg: ModelConfig, h, loss_targets):
    """Fused lm-head + cross-entropy (DESIGN.md §6): per-token stats of the
    sampled tokens without materializing (B,S,V) logits.

    h: (B,S,D) post-final-norm hidden states (multimodal prefix already
    stripped); loss_targets: (B,S) with targets[t] = tokens[t+1] (last
    column dead). Returns token_logprobs / lse / entropy, each (B,S) f32
    shifted to the `algo.token_logprobs` alignment: entry t describes the
    distribution that scored token t (entry 0 is a zero pad, masked by
    loss_mask downstream — prompts start at position >= 1).

    The Pallas kernel runs when `use_pallas` is set (interpret plumbed
    like every other kernel); otherwise the compiled blockwise jnp twin
    `fused_logprob_blocked` — same tiling and VJP-recompute math as a
    lax.scan, so the no-materialization property holds on every backend
    (the full-logits oracle lives in kernels/ref.py, tests only). Under an
    active mesh the head call is vocab-sharded — see `_fused_head_stats`.
    """
    B, S, D = h.shape
    hs = h.reshape(B * S, D)
    tgt = loss_targets.reshape(B * S).astype(jnp.int32)
    lp, lse, ent = _fused_head_stats(params, cfg, hs, tgt)

    def shift(x):  # (B,S) stats of position t -> aligned with token t+1
        return jnp.pad(x.reshape(B, S)[:, :-1], ((0, 0), (1, 0)))

    return {"token_logprobs": shift(lp), "lse": shift(lse),
            "entropy": shift(ent)}


def _mtp_hidden(params, cfg, hidden, tokens, positions, n_prefix):
    """DeepSeek-V3 MTP trunk: [norm(h_t); norm(emb_{t+1})] -> proj -> one
    extra layer -> final norm. Returns the pre-head hidden (B, S-1, D);
    row t carries the draft prediction of token t+2."""
    mp = params["mtp"]
    h = hidden[:, n_prefix:]
    h_t = rms_norm(h[:, :-1], mp["norm_h"], cfg.norm_eps)
    e_next = rms_norm(jnp.take(params["embed"], tokens[:, 1:], axis=0),
                      mp["norm_e"], cfg.norm_eps)
    x = jnp.einsum("bse,ed->bsd", jnp.concatenate([h_t, e_next], axis=-1),
                   mp["proj"])
    lp = jax.tree.map(lambda a: a[0], mp["layer"])  # single stacked layer
    x, _, _ = _layer_forward(cfg, "dense", x, lp, positions[:, 1:], None, False)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def _mtp_forward(params, cfg, hidden, tokens, positions, n_prefix, head):
    """MTP logits oracle: (B, S-1, V) for targets t+2. Only used when the
    fused loss is off — the fused path goes through `_mtp_fused_stats`."""
    x = _mtp_hidden(params, cfg, hidden, tokens, positions, n_prefix)
    return jnp.einsum("bsd,dv->bsv", x, head)


def _mtp_fused_stats(params, cfg, hidden, tokens, positions, n_prefix):
    """Fused-loss coverage for the MTP head: per-draft targets (row t of
    the MTP trunk predicts token t+2) through the same fused lm-head call
    as the main loss, so the draft head stops materializing its own
    (B, S-1, V) logits. Returns mtp_token_logprobs / mtp_lse /
    mtp_entropy, each (B, S-1) f32 in MTP row alignment (entry t scores
    token t+2; the last row is a dead pad, like the main loss targets'
    last column)."""
    x = _mtp_hidden(params, cfg, hidden, tokens, positions, n_prefix)
    B, Sm1, D = x.shape
    tgt = jnp.concatenate([tokens[:, 2:], tokens[:, -1:]], axis=1)
    lp, lse, ent = _fused_head_stats(params, cfg, x.reshape(B * Sm1, D),
                                     tgt.reshape(B * Sm1).astype(jnp.int32))
    return {"mtp_token_logprobs": lp.reshape(B, Sm1),
            "mtp_lse": lse.reshape(B, Sm1),
            "mtp_entropy": ent.reshape(B, Sm1)}


def _stack_group_caches(cfg: ModelConfig, caches: List[Dict[str, Any]]):
    """Concat per-group scan outputs into the unified (L, ...) cache tree,
    sharded per CACHE_LOGICAL (without this, a prefill cache whose kv_heads
    don't divide the TP axis is replicated across it — 425 GB/dev for
    musicgen's 32k MHA prefill; see EXPERIMENTS.md §Perf)."""
    from repro.configs.base import CACHE_LOGICAL
    keys = caches[0].keys()
    return {
        k: constrain(jnp.concatenate([c[k] for c in caches], axis=0),
                     CACHE_LOGICAL[k])
        for k in keys
    }


# ---------------------------------------------------------------------------
# decode (one token against the cache)
# ---------------------------------------------------------------------------

def _cached_layer_step(cfg: ModelConfig, kind: str, h, lp, attn_fn, ssm_fn):
    """Shared layer wiring for the cache-carrying paths (decode_step and
    prefill_chunk): norm1 -> attention/SSM branch(es) -> residual -> FFN.

    attn_fn(attn_params, x) / ssm_fn(ssm_params, x) run the path-specific
    primitive and return (branch_out, new_cache_entries)."""
    x = rms_norm(h, lp["norm1"], cfg.norm_eps)
    if cfg.hybrid_parallel:
        a, ncs_a = attn_fn(lp["attn"], x)
        s, ncs_s = ssm_fn(lp["ssm"], x)
        mix = 0.5 * (rms_norm(a, lp["hyb_norm_a"], cfg.norm_eps)
                     + rms_norm(s, lp["hyb_norm_s"], cfg.norm_eps))
        h = h + mix
        ncs = {**ncs_a, **ncs_s}
    elif cfg.arch_type == "ssm":
        s, ncs = ssm_fn(lp["ssm"], x)
        h = h + s
    else:
        a, ncs = attn_fn(lp["attn"], x)
        h = h + a

    if kind == "dense":
        x = rms_norm(h, lp["norm2"], cfg.norm_eps)
        f = lp["ffn"]
        h = h + swiglu(x, f["gate"], f["up"], f["down"])
    elif kind == "moe":
        x = rms_norm(h, lp["norm2"], cfg.norm_eps)
        mo, _ = moe_mod.moe_apply(lp["moe"], x, cfg)
        h = h + mo
    return h, ncs


def decode_step(params, tokens, positions, cache, cache_index,
                cfg: ModelConfig, *, ring: Optional[bool] = None,
                kv_len_hint: Optional[int] = None, block_tables=None,
                paged_kernel: bool = False):
    """tokens: (B,1); cache: stacked (L,...) tree; cache_index: scalar or (B,).
    Returns (logits (B,1,V), values (B,1)?, new_cache).

    kv_len_hint: optional static upper bound on the valid cache length
    across the batch; forwarded to the flash-decode kernel to shrink its
    KV grid (the generation engine derives it from its host-side length
    mirrors). Must satisfy kv_len_hint >= max over the batch of
    min(cache_index+1, CL); None disables the grid-level early exit.

    block_tables: (B,NB) int32 when the attention cache leaves are page
    pools (L,NP,PS,...) instead of slot arrays (DESIGN.md §9); SSM leaves
    keep the slot layout either way. paged_kernel routes GQA decode
    through the scalar-prefetch paged kernel instead of gather-then-
    flash_decode."""
    B = tokens.shape[0]
    if ring is None:
        # ring addressing applies only to attention caches, and is on
        # exactly when the sliding-window variant allocated a ring buffer
        # (effective_cache_len < full sequence); SSM state has no cache.
        has_kv = "k" in cache or "c_kv" in cache
        ring = has_kv and cfg.attention_variant == "sliding_window"
    h = jnp.take(params["embed"], tokens, axis=0)
    h = constrain(h, ("batch", "seq", "embed"))

    offset = 0
    new_cache = {k: [] for k in cache}
    for gi, (kind, count) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        cache_slice = {k: jax.lax.slice_in_dim(v, offset, offset + count, axis=0)
                       for k, v in cache.items()}

        def scan_body(h, inp, _kind=kind):
            lp, cs = inp

            def attn_fn(pa, x):
                if cfg.use_mla:
                    a, (nck, nkr) = attn.mla_decode(
                        pa, x, positions, cs["c_kv"], cs["k_rope"],
                        cache_index, cfg, ring, block_tables=block_tables,
                        paged_kernel=paged_kernel)
                    return a, {"c_kv": nck, "k_rope": nkr}
                a, (nk, nv) = attn.gqa_decode(
                    pa, x, positions, cs["k"], cs["v"], cache_index,
                    cfg, ring, kv_len_hint=kv_len_hint,
                    block_tables=block_tables, paged_kernel=paged_kernel)
                return a, {"k": nk, "v": nv}

            def ssm_fn(ps, x):
                s, (ncv, nss) = ssm_mod.ssm_decode(
                    ps, x, cs["conv"], cs["ssd"], cfg)
                return s, {"conv": ncv, "ssd": nss}

            return _cached_layer_step(cfg, _kind, h, lp, attn_fn, ssm_fn)

        h, kvs = jax.lax.scan(scan_body, h, (gp, cache_slice),
                              unroll=True if cfg.scan_unroll else 1)
        for k in cache:
            new_cache[k].append(kvs[k])
        offset += count

    new_cache = {k: jnp.concatenate(v, axis=0) for k, v in new_cache.items()}
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    out = {"logits": logits, "cache": new_cache}
    if cfg.use_value_head:
        out["values"] = jnp.einsum(
            "bsd,dv->bsv", h.astype(jnp.float32), params["value_head"])[..., 0]
    return out


# ---------------------------------------------------------------------------
# chunked prefill (batched prompt admission against the slot cache)
# ---------------------------------------------------------------------------

def _merge_state(new, old, mask):
    """Keep `old` rows where mask is False. mask: (B,)."""
    m = mask.reshape((-1,) + (1,) * (old.ndim - 1))
    return jnp.where(m, new.astype(old.dtype), old)


def prefill_chunk(params, tokens, prompt_len, offset, admit_mask, cache,
                  cfg: ModelConfig, *, chunk: int,
                  offset_hint: Optional[int] = None, block_tables=None):
    """One fixed-size chunk of chunked-prefill admission (DESIGN.md §2).

    Runs `chunk` prompt tokens (positions [offset, offset+chunk)) of every
    slot through the full layer stack and writes their K/V (MLA latent /
    SSM state) straight into the slot cache via dynamic_update_slice, so
    admitting a prompt of length P costs ceil((P-1)/chunk) batched forwards
    instead of P-1 one-token decode steps. Chunk attention runs through the
    Pallas prefill kernel (`kernels.prefill_attention`) when shapes fit,
    and supports ring-buffer (sliding-window) caches: writes land at
    `position mod CL` and masking follows the ring rule (see
    `attention.chunk_attention`).

    **Equivalence law** (enforced by `tests/test_prefill.py`): chunked
    admission must match the sequential decode loop *bit-for-bit in fp32*
    on the attention caches and `n_cached` — every K/V value written here
    is the same projection of the same token at the same position the
    legacy token-at-a-time loop would have written — and within fp32
    tolerance on SSD state and logits (the chunked scan and the online
    softmax reassociate their reductions). At ~greedy temperature the two
    admission paths must produce identical completions.

    tokens: (B,T) slot token buffer; prompt_len: (B,); offset: scalar chunk
    start — the host guarantees offset + chunk <= T, offset % chunk == 0
    and chunk | CL (ring writes stay contiguous); offset_hint: optional
    *static* upper bound on the valid cache-slot count (>= min(offset,
    CL)), bucketed host-side to the prefill kernel's block size — shrinks
    the Pallas kernel's cache-block grid so early chunks never launch
    blocks past the write frontier; admit_mask: (B,) bool,
    True for slots admitted this refill (other rows participate in compute
    for static shapes but their cache/state is untouched). Attention-cache
    writes are additionally masked to positions < prompt_len-1 per row: a
    full-length cache would merely hold dead garbage beyond that (masked
    by n_cached), but once a ring wraps, garbage at high positions would
    alias into low slots that count-based decode masking treats as valid.
    The SSD recurrence gets the same mask via dt=0 no-ops. No logits are
    computed: the first completion token is sampled by the normal decode
    step at n_cached = prompt_len-1.

    block_tables: (B,NB) int32 when attention leaves are page pools
    (DESIGN.md §9). The engine then additionally guarantees chunk |
    page_size, so every chunk write lands inside one logical block; the
    chunk attends against the gathered per-slot view, which keeps the
    equivalence law above intact bit-for-bit versus the slot cache.

    Returns the updated cache tree.
    """
    B, T = tokens.shape
    offset = jnp.asarray(offset, jnp.int32)
    toks = jax.lax.dynamic_slice_in_dim(tokens, offset, chunk, axis=1)
    positions = jnp.broadcast_to(
        (offset + jnp.arange(chunk, dtype=jnp.int32))[None], (B, chunk))
    # tokens folded into recurrent state: absolute position < prompt_len-1
    pos_valid = positions < (prompt_len[:, None] - 1)             # (B,C)
    tok_mask = pos_valid.astype(jnp.float32)
    # attention-cache writes: admitted rows, valid prompt positions only
    kv_write_mask = admit_mask[:, None] & pos_valid               # (B,C)

    h = jnp.take(params["embed"], toks, axis=0)
    h = constrain(h, ("batch", "seq", "embed"))

    lg = layer_groups(cfg)
    off_layers = 0
    new_cache = {k: [] for k in cache}
    for gi, (kind, count) in enumerate(lg):
        gp = params["groups"][gi]
        cache_slice = {k: jax.lax.slice_in_dim(v, off_layers,
                                               off_layers + count, axis=0)
                       for k, v in cache.items()}

        def scan_body(h, inp, _kind=kind):
            lp, cs = inp

            def attn_fn(pa, x):
                if cfg.use_mla:
                    a, (nck, nkr) = attn.mla_prefill_chunk(
                        pa, x, positions, cs["c_kv"], cs["k_rope"],
                        offset, kv_write_mask, cfg, offset_hint=offset_hint,
                        block_tables=block_tables)
                    return a, {"c_kv": nck, "k_rope": nkr}
                a, (nk, nv) = attn.gqa_prefill_chunk(
                    pa, x, positions, cs["k"], cs["v"], offset,
                    kv_write_mask, cfg, offset_hint=offset_hint,
                    block_tables=block_tables)
                return a, {"k": nk, "v": nv}

            def ssm_fn(ps, x):
                s, (ncv, nss) = ssm_mod.ssm_forward(
                    ps, x, cfg, return_state=True,
                    initial_state=(cs["conv"], cs["ssd"]),
                    token_mask=tok_mask)
                # only admitted rows may advance recurrent state
                return s, {"conv": _merge_state(ncv, cs["conv"], admit_mask),
                           "ssd": _merge_state(nss, cs["ssd"], admit_mask)}

            return _cached_layer_step(cfg, _kind, h, lp, attn_fn, ssm_fn)

        h, kvs = jax.lax.scan(scan_body, h, (gp, cache_slice),
                              unroll=True if cfg.scan_unroll else 1)
        for k in cache:
            new_cache[k].append(kvs[k])
        off_layers += count

    return {k: jnp.concatenate(v, axis=0) for k, v in new_cache.items()}
