"""Mixture-of-Experts with expert parallelism.

Design (TPU-native, see DESIGN.md §4): experts are sharded over the "model"
mesh axis; tokens are sharded over ("pod","data") and *replicated* along
"model", so each model-column computes only its local experts' contribution
and a single psum over "model" combines them — the same collective pattern
as a tensor-parallel FFN (dispatch stays device-local; no all-to-all).
Dispatch is capacity-bounded and sort-free: k sequential top-1 passes keep
the position-in-expert cumsum at O(T*E) and scatter (T,d) rows per pass —
never materializing a (T,E,C) GShard dispatch tensor or a (T*k,d) gather.

Runs inside shard_map when a mesh context is active, or as plain local code
(single-device smoke tests).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

# the replication-check kwarg was renamed check_rep -> check_vma in jax 0.6
import inspect as _inspect

_SM_CHECK_KW = ("check_vma" if "check_vma"
                in _inspect.signature(shard_map).parameters else "check_rep")

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef
from repro.sharding import logical_to_spec
from repro.shardctx import current_mesh, current_rules


def moe_defs(cfg: ModelConfig, n_stack: int) -> Dict[str, ParamDef]:
    d, dt = cfg.d_model, cfg.dtype
    E, F = cfg.n_experts, cfg.moe_d_ff
    L, Ll = (n_stack,), ("layers",)
    out_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    defs = {
        "router": ParamDef(L + (d, E), Ll + ("p_embed", "p_experts"), jnp.float32),
        "gate": ParamDef(L + (E, d, F), Ll + ("p_experts", "p_embed", "p_mlp"), dt),
        "up": ParamDef(L + (E, d, F), Ll + ("p_experts", "p_embed", "p_mlp"), dt),
        "down": ParamDef(L + (E, F, d), Ll + ("p_experts", "p_mlp", "p_embed"), dt, out_scale),
    }
    if cfg.n_shared_experts:
        SF = cfg.moe_d_ff * cfg.n_shared_experts
        defs.update({
            "shared_gate": ParamDef(L + (d, SF), Ll + ("p_embed", "p_mlp"), dt),
            "shared_up": ParamDef(L + (d, SF), Ll + ("p_embed", "p_mlp"), dt),
            "shared_down": ParamDef(L + (SF, d), Ll + ("p_mlp", "p_embed"), dt, out_scale),
        })
    return defs


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(cfg.experts_per_token * n_tokens * cfg.capacity_factor
                      / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _moe_local(p, x, cfg: ModelConfig, n_local: int, offset,
               expert_axis: Optional[str]):
    """x: (T, d) local tokens; expert weights already local (n_local,...).
    Computes the contribution of experts [offset, offset+n_local) and psums
    over expert_axis if given. Returns (out (T,d), aux_loss scalar)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = _capacity(T, cfg)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (T,E)

    # --- load-balance auxiliary loss (Switch-style) ---
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))

    # --- top-k routing as k sequential top-1 passes ---
    masked = probs
    dests, weights = [], []
    counts = jnp.zeros((E,), jnp.int32)
    for _ in range(k):
        w = masked.max(axis=-1)                                  # (T,)
        e = masked.argmax(axis=-1)                               # (T,)
        masked = masked * (1.0 - jax.nn.one_hot(e, E, dtype=jnp.float32))
        onehot = jax.nn.one_hot(e, E, dtype=jnp.int32)
        pos = counts[e] + (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(T), e]
        counts = counts + onehot.sum(axis=0)
        local = (e >= offset) & (e < offset + n_local) & (pos < C)
        dests.append(jnp.where(local, (e - offset) * C + pos, n_local * C))
        weights.append(w)

    # --- dispatch: scatter (T,d) rows per pass into (n_local*C [+ovf], d) ---
    buf = jnp.zeros((n_local * C + 1, d), x.dtype)
    for dest in dests:
        buf = buf.at[dest].add(x, mode="drop")
    eb = buf[:n_local * C].reshape(n_local, C, d)

    # --- expert FFN (SwiGLU), batched over local experts ---
    g = jnp.einsum("ecd,edf->ecf", eb, p["gate"])
    u = jnp.einsum("ecd,edf->ecf", eb, p["up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    eo = jnp.einsum("ecf,efd->ecd", h, p["down"])                # (n_local,C,d)

    # --- combine: gather back per pass, router-weighted ---
    flat = jnp.concatenate([eo.reshape(n_local * C, d),
                            jnp.zeros((1, d), x.dtype)])
    out = jnp.zeros((T, d), x.dtype)
    for dest, w in zip(dests, weights):
        out = out + flat[dest] * w[:, None].astype(x.dtype)
    if expert_axis is not None:
        out = jax.lax.psum(out, expert_axis)
    return out, aux


def moe_apply(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) global. Returns (out, aux_loss)."""
    B, S, d = x.shape
    mesh = current_mesh()
    E = cfg.n_experts
    xf = x.reshape(B * S, d)

    # token sharding falls back to replicated automatically when B*S is not
    # divisible (logical_to_spec drops the axis), so expert-parallel shard_map
    # only requires the expert count to divide the model axis
    use_ep = (mesh is not None and "model" in mesh.axis_names
              and E % mesh.shape["model"] == 0)
    routed = {k: p[k] for k in ("router", "gate", "up", "down")}
    if use_ep:
        rules = current_rules()
        x_spec = logical_to_spec(("batch", "embed"), xf.shape, mesh, rules)
        ep = mesh.shape["model"]
        n_local = E // ep
        w_specs = {
            "router": P(None, None),
            "gate": P("model", None, None),
            "up": P("model", None, None),
            "down": P("model", None, None),
        }

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(w_specs, x_spec),
            out_specs=(x_spec, P()),
            **{_SM_CHECK_KW: False})
        def run(pl, xl):
            idx = jax.lax.axis_index("model")
            out, aux = _moe_local(pl, xl, cfg, n_local, idx * n_local, "model")
            axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            if axes:
                aux = jax.lax.pmean(aux, axes)
            return out, aux

        out, aux = run(routed, xf)
    else:
        out, aux = _moe_local(routed, xf, cfg, E, 0, None)

    out = out.reshape(B, S, d)
    if cfg.n_shared_experts:
        g = jnp.einsum("bsd,df->bsf", x, p["shared_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["shared_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        out = out + jnp.einsum("bsf,fd->bsd", h, p["shared_down"])
    return out, aux
