"""Attention: GQA (+qk-norm, sliding window) and MLA (DeepSeek-V3 latent).

Full-sequence paths use a *blocked* online-softmax implementation (the jnp
twin of the Pallas flash kernel) so the dry-run memory analysis reflects a
flash-attention working set instead of a materialized (S, S) score tensor.
Decode paths read a static-shape ring-buffer KV cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef, apply_rope, rms_norm
from repro.shardctx import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig, n_stack: int) -> Dict[str, ParamDef]:
    d, dt = cfg.d_model, cfg.dtype
    L = (n_stack,)
    Ll = ("layers",)
    out_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    if cfg.use_mla:
        nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return {
            "wq_a": ParamDef(L + (d, cfg.q_lora_rank), Ll + ("p_embed", "p_lora"), dt),
            "q_norm": ParamDef(L + (cfg.q_lora_rank,), Ll + ("p_lora",), dt, -1.0),
            "wq_b": ParamDef(L + (cfg.q_lora_rank, cfg.n_heads, nope + rope),
                             Ll + ("p_lora", "p_heads", "p_head_dim"), dt),
            "wkv_a": ParamDef(L + (d, cfg.kv_lora_rank + rope), Ll + ("p_embed", "p_lora"), dt),
            "kv_norm": ParamDef(L + (cfg.kv_lora_rank,), Ll + ("p_lora",), dt, -1.0),
            "wk_b": ParamDef(L + (cfg.kv_lora_rank, cfg.n_heads, nope),
                             Ll + ("p_lora", "p_heads", "p_head_dim"), dt),
            "wv_b": ParamDef(L + (cfg.kv_lora_rank, cfg.n_heads, vd),
                             Ll + ("p_lora", "p_heads", "p_head_dim"), dt),
            "wo": ParamDef(L + (cfg.n_heads, vd, d),
                           Ll + ("p_heads", "p_head_dim", "p_embed"), dt, out_scale),
        }
    defs = {
        "wq": ParamDef(L + (d, cfg.n_heads, cfg.d_head),
                       Ll + ("p_embed", "p_heads", "p_head_dim"), dt),
        "wk": ParamDef(L + (d, cfg.n_kv_heads, cfg.d_head),
                       Ll + ("p_embed", "p_kv_heads", "p_head_dim"), dt),
        "wv": ParamDef(L + (d, cfg.n_kv_heads, cfg.d_head),
                       Ll + ("p_embed", "p_kv_heads", "p_head_dim"), dt),
        "wo": ParamDef(L + (cfg.n_heads, cfg.d_head, d),
                       Ll + ("p_heads", "p_head_dim", "p_embed"), dt, out_scale),
    }
    if cfg.use_qk_norm:
        defs["qn"] = ParamDef(L + (cfg.d_head,), Ll + ("p_head_dim",), dt, -1.0)
        defs["kn"] = ParamDef(L + (cfg.d_head,), Ll + ("p_head_dim",), dt, -1.0)
    return defs


# ---------------------------------------------------------------------------
# blocked (flash-style) causal attention — jnp reference of the Pallas kernel
# ---------------------------------------------------------------------------

def blocked_causal_attention(
    q, k, v,
    *,
    scale: float,
    segment_ids=None,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 512,
):
    """q: (B,S,H,Dk); k,v: (B,S,KV,Dk/Dv); GQA via H = KV*rep.

    Online-softmax over KV blocks; O(S * block) memory instead of O(S^2).
    `window > 0` adds a sliding-window constraint (j > i - window).
    """
    B, S, H, Dk = q.shape
    KV, Dv = k.shape[2], v.shape[-1]
    rep = H // KV
    if S % q_block or S % kv_block or S <= q_block:
        return _naive_causal_attention(q, k, v, scale=scale,
                                       segment_ids=segment_ids, window=window)
    nq, nk = S // q_block, S // kv_block
    qr = q.reshape(B, nq, q_block, KV, rep, Dk)
    kr = k.reshape(B, nk, kv_block, KV, Dk)
    vr = v.reshape(B, nk, kv_block, KV, Dv)
    seg = None
    if segment_ids is not None:
        seg = segment_ids.reshape(B, nq, q_block)

    q_pos = jnp.arange(S).reshape(nq, q_block)
    k_pos = jnp.arange(S).reshape(nk, kv_block)

    def one_q_block(qi):
        qb = qr[:, qi]  # (B,qb,KV,rep,Dk)
        qp = q_pos[qi]  # (qb,)
        sq = seg[:, qi] if seg is not None else None

        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb = kr[:, ki], vr[:, ki]
            kp = k_pos[ki]
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = qp[:, None] >= kp[None, :]
            if window:
                mask &= qp[:, None] - kp[None, :] < window
            mask = mask[None, None, None]
            if sq is not None:
                sk = segment_ids.reshape(B, nk, kv_block)[:, ki]
                mask = mask & (sq[:, None, :, None] == sk[:, None, None, :])[:, :, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, rep, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, q_block, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B,KV,rep,qb,Dv)

    outs = jax.lax.map(one_q_block, jnp.arange(nq))  # (nq,B,KV,rep,qb,Dv)
    out = jnp.moveaxis(outs, 0, 1)  # (B,nq,KV,rep,qb,Dv)
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5)).reshape(B, S, H, Dv)
    return out.astype(q.dtype)


def _naive_causal_attention(q, k, v, *, scale, segment_ids=None, window=0):
    B, S, H, Dk = q.shape
    KV = k.shape[2]
    rep = H // KV
    qr = q.reshape(B, S, KV, rep, Dk)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qr, k,
                   preferred_element_type=jnp.float32) * scale
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = i >= j
    if window:
        mask &= (i - j) < window
    mask = mask[None, None, None]
    if segment_ids is not None:
        mask = mask & (segment_ids[:, None, None, :, None]
                       == segment_ids[:, None, None, None, :])
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, v.shape[-1]).astype(q.dtype)


def write_cache(cache, new, index):
    """Write `new` (B,1,...) into ring-buffer `cache` (B,CL,...) at
    slot = index % CL. `index` may be a scalar (lockstep decode) or (B,)
    (continuous-batching engine with per-slot positions)."""
    CL = cache.shape[1]
    slot = jnp.mod(index, CL)
    if jnp.ndim(slot) == 0:
        start = (0, slot) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype), start)
    onehot = (jnp.arange(CL)[None] == slot[:, None]).astype(cache.dtype)
    onehot = onehot.reshape(onehot.shape + (1,) * (cache.ndim - 2))
    return cache * (1 - onehot) + new.astype(cache.dtype) * onehot


# ---------------------------------------------------------------------------
# paged cache plumbing (DESIGN.md §9)
#
# Pool leaves are (n_pages, page_size, ...); `block_tables` (B, n_blocks)
# maps each slot's logical ring block to a physical page. The default read
# path gathers the per-slot contiguous view and runs the UNCHANGED
# attention math on it, which makes the paged engine bit-identical to the
# slot engine by construction (the valid region of the view equals the
# slot cache exactly; trash-page garbage only appears at positions every
# mask already excludes). Writes scatter into the pool; the engine's COW
# discipline guarantees the written page has refcount 1, so no scatter
# ever races except on the trash page (never read).
# ---------------------------------------------------------------------------

def paged_gather(pool, block_tables):
    """pool: (NP,PS,...) -> per-slot view (B, NB*PS, ...)."""
    v = jnp.take(pool, block_tables, axis=0)
    return v.reshape((v.shape[0], v.shape[1] * v.shape[2]) + v.shape[3:])


def write_cache_paged(pool, new, index, block_tables):
    """Paged twin of `write_cache`: write `new` (B,1,...) at ring position
    index mod CL of each row. Inactive rows' block-table entries point at
    the trash page, which absorbs their static-shape stale writes."""
    B = new.shape[0]
    PS, NB = pool.shape[1], block_tables.shape[1]
    CL = NB * PS
    pos = jnp.broadcast_to(jnp.mod(index, CL), (B,))
    blk = pos // PS
    off = pos - blk * PS
    pages = jnp.take_along_axis(block_tables, blk[:, None], axis=1)[:, 0]
    cur = jnp.take(pool, pages, axis=0)                       # (B,PS,...)
    oh = (jnp.arange(PS)[None] == off[:, None]).astype(pool.dtype)
    oh = oh.reshape(oh.shape + (1,) * (pool.ndim - 2))
    merged = cur * (1 - oh) + new.astype(pool.dtype) * oh
    return pool.at[pages].set(merged)


def write_cache_chunk_paged(pool, new, offset, write_mask, block_tables):
    """Paged twin of `write_cache_chunk`. The engine keeps chunk size a
    divisor of page_size, so the chunk [offset, offset+C) lies inside ONE
    logical block. Masked rows merge back exactly what they gathered
    (identity write): live rows' pages are untouched and trash-page
    duplicates all write identical bytes."""
    C = new.shape[1]
    PS = pool.shape[1]
    blk = offset // PS
    off = offset - blk * PS
    pages = jnp.take(block_tables, blk[None], axis=1)[:, 0]   # (B,)
    cur = jnp.take(pool, pages, axis=0)                       # (B,PS,...)
    merged = new.astype(pool.dtype)
    if write_mask is not None:
        old = jax.lax.dynamic_slice_in_dim(cur, off, C, axis=1)
        shape = write_mask.shape + (1,) * (pool.ndim - write_mask.ndim)
        merged = jnp.where(write_mask.reshape(shape), merged, old)
    cur = jax.lax.dynamic_update_slice_in_dim(cur, merged, off, axis=1)
    return pool.at[pages].set(cur)


def decode_block_k(cache_len: int) -> int:
    """flash_decode KV block size for a given cache length — shared with
    the engine's kv_len_hint bucketing so the two layers cannot desync."""
    return min(256, cache_len)


def uses_flash_decode(cfg: ModelConfig, cache_len: int) -> bool:
    """True when decode attention takes the Pallas flash-decode kernel
    (GQA only; MLA decodes through the absorbed jnp path)."""
    return cfg.use_pallas and not cfg.use_mla and cache_len % 64 == 0


def decode_attention(q, k_cache, v_cache, cache_index, *, scale, ring: bool):
    """q: (B,H,Dk); caches: (B,CL,KV,D). One-token flash-decode reference.

    ring=True: the cache is a full ring buffer (all slots valid).
    ring=False: slots >= cache_index are masked out. cache_index may be a
    scalar or per-slot (B,).
    """
    B, H, Dk = q.shape
    CL, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    qr = q.reshape(B, KV, rep, Dk)
    s = jnp.einsum("bgrd,bkgd->bgrk", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if not ring:
        idx = jnp.reshape(cache_index, (-1, 1))  # scalar -> (1,1); (B,) -> (B,1)
        valid = jnp.arange(CL)[None] < idx
        s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer (train/prefill + decode)
# ---------------------------------------------------------------------------

def _maybe_qk_norm(cfg, p, q, k):
    if cfg.use_qk_norm:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    return q, k


def _use_flash_kernel(cfg, S, segment_ids, window) -> bool:
    return (cfg.use_pallas and segment_ids is None and window == 0
            and S % 128 == 0)


def gqa_forward(p, x, positions, cfg: ModelConfig, segment_ids=None,
                return_kv: bool = False):
    """Full-sequence causal GQA. x: (B,S,d)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q, k = _maybe_qk_norm(cfg, p, q, k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    window = cfg.sliding_window if cfg.attention_variant == "sliding_window" else 0
    S = x.shape[1]
    if _use_flash_kernel(cfg, S, segment_ids, window):
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), scale=1.0 / np.sqrt(cfg.d_head),
            interpret=cfg.pallas_interpret)
        out = jnp.swapaxes(out, 1, 2)
    else:
        out = blocked_causal_attention(
            q, k, v, scale=1.0 / np.sqrt(cfg.d_head),
            segment_ids=segment_ids, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, (k, v)
    return y


def gqa_decode(p, x, positions, cache_k, cache_v, cache_index, cfg: ModelConfig,
               ring: bool, kv_len_hint=None, block_tables=None,
               paged_kernel: bool = False):
    """One-token decode. x: (B,1,d); caches (B,CL,KV,Dk), or page pools
    (NP,PS,KV,Dk) when `block_tables` (B,NB) is given. Returns y, new caches.

    kv_len_hint: optional static upper bound on the valid cache length
    across the batch (host-mirrored by the engine); shrinks the flash-decode
    KV grid instead of relying on per-block `pl.when` skips alone.

    Paged path: write the token into its page, then either gather the
    per-slot view and run the IDENTICAL attention below (default —
    bit-equal to the slot cache), or, with paged_kernel, hand the block
    table straight to `flash_decode_paged` (scalar-prefetch; no gather)."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q, k = _maybe_qk_norm(cfg, p, q, k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if block_tables is None:
        CL = cache_k.shape[1]
        cache_k = write_cache(cache_k, k, cache_index)
        cache_v = write_cache(cache_v, v, cache_index)
        view_k, view_v = cache_k, cache_v
    else:
        CL = block_tables.shape[1] * cache_k.shape[1]
        cache_k = write_cache_paged(cache_k, k, cache_index, block_tables)
        cache_v = write_cache_paged(cache_v, v, cache_index, block_tables)
        if paged_kernel and uses_flash_decode(cfg, CL):
            from repro.kernels import ops as kops
            lengths = jnp.full((B,), CL, jnp.int32) if ring else \
                jnp.broadcast_to(jnp.minimum(
                    jnp.asarray(cache_index + 1, jnp.int32), CL), (B,))
            y = kops.flash_decode_paged(
                q[:, 0], cache_k, cache_v, block_tables, lengths,
                scale=1.0 / np.sqrt(cfg.d_head), max_len_hint=kv_len_hint,
                interpret=cfg.pallas_interpret)
            y = jnp.einsum("bhk,hkd->bd", y, p["wo"])[:, None]
            return y, (cache_k, cache_v)
        view_k = paged_gather(cache_k, block_tables)
        view_v = paged_gather(cache_v, block_tables)
    if uses_flash_decode(cfg, CL):
        from repro.kernels import ops as kops
        # clamp to CL: once a ring cache has wrapped (cache_index >= CL)
        # every slot is valid, and the clamp keeps the early-exit tight
        lengths = jnp.full((B,), CL, jnp.int32) if ring else \
            jnp.broadcast_to(jnp.minimum(
                jnp.asarray(cache_index + 1, jnp.int32), CL), (B,))
        y = kops.flash_decode(q[:, 0], view_k, view_v, lengths,
                              scale=1.0 / np.sqrt(cfg.d_head),
                              block_k=decode_block_k(CL),
                              max_len_hint=kv_len_hint,
                              interpret=cfg.pallas_interpret)
    else:
        y = decode_attention(q[:, 0], view_k, view_v, cache_index + 1,
                             scale=1.0 / np.sqrt(cfg.d_head), ring=ring)
    y = jnp.einsum("bhk,hkd->bd", y, p["wo"])[:, None]
    return y, (cache_k, cache_v)


# ---------------------------------------------------------------------------
# MLA layer (DeepSeek-V3): naive expansion for train/prefill, absorbed decode
# ---------------------------------------------------------------------------

def mla_forward(p, x, positions, cfg: ModelConfig, segment_ids=None,
                return_kv: bool = False):
    B, S, _ = x.shape
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])  # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # shared 1-head rope

    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, cfg.n_heads, rope))],
        axis=-1)
    q_full = constrain(q_full, ("batch", "seq", "heads", None))
    k_full = constrain(k_full, ("batch", "seq", "heads", None))
    out = blocked_causal_attention(
        q_full, k_full, v, scale=1.0 / np.sqrt(nope + rope),
        segment_ids=segment_ids)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, (c_kv, k_rope)
    return y


def mla_decode(p, x, positions, cache_ckv, cache_krope, cache_index,
               cfg: ModelConfig, ring: bool, block_tables=None,
               paged_kernel: bool = False):
    """Absorbed MLA decode: scores in latent space, cache stays compressed.
    With `block_tables`, the latent caches are page pools (NP,PS,r) —
    write the token's latent into its page, gather the per-slot view, and
    run the identical absorbed attention (bit-equal to the slot cache)."""
    del paged_kernel  # MLA decodes through the absorbed jnp path
    B = x.shape[0]
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])  # (B,1,H,nope+rope)
    q_nope, q_rope = q[:, 0, :, :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)[:, 0]  # (B,H,rope)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., cfg.kv_lora_rank:], positions, cfg.rope_theta)
    if block_tables is None:
        CL = cache_ckv.shape[1]
        cache_ckv = write_cache(cache_ckv, c_kv, cache_index)
        cache_krope = write_cache(cache_krope, k_rope, cache_index)
        view_ckv, view_krope = cache_ckv, cache_krope
    else:
        CL = block_tables.shape[1] * cache_ckv.shape[1]
        cache_ckv = write_cache_paged(cache_ckv, c_kv, cache_index,
                                      block_tables)
        cache_krope = write_cache_paged(cache_krope, k_rope, cache_index,
                                        block_tables)
        view_ckv = paged_gather(cache_ckv, block_tables)
        view_krope = paged_gather(cache_krope, block_tables)

    # absorb W_uk into q: (B,H,nope) x (r,H,nope) -> (B,H,r)
    q_latent = jnp.einsum("bhk,rhk->bhr", q_nope, p["wk_b"])
    s = jnp.einsum("bhr,bkr->bhk", q_latent, view_ckv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bhp,bkp->bhk", q_rope, view_krope,
                    preferred_element_type=jnp.float32)
    s *= 1.0 / np.sqrt(nope + rope)
    if not ring:
        idx = jnp.reshape(cache_index + 1, (-1, 1, 1))
        valid = jnp.arange(CL)[None, None] < idx
        s = jnp.where(valid, s, NEG_INF)
    pw = jax.nn.softmax(s, axis=-1)
    o_latent = jnp.einsum("bhk,bkr->bhr", pw.astype(view_ckv.dtype), view_ckv,
                          preferred_element_type=jnp.float32).astype(x.dtype)
    o = jnp.einsum("bhr,rhk->bhk", o_latent, p["wv_b"])
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
    return y, (cache_ckv, cache_krope)


# ---------------------------------------------------------------------------
# chunked prefill: a C-token query block against the slot cache + itself
# ---------------------------------------------------------------------------

def write_cache_chunk(cache, new, offset, write_mask=None):
    """Write `new` (B,C,...) into `cache` (B,CL,...) at [offset, offset+C).

    write_mask may be (B,) — only admitted rows may be touched (the others
    hold live K/V of in-progress sequences) — or (B,C) to additionally
    restrict which chunk positions are written (ring-buffer caches must
    not write garbage beyond a row's prompt: once the ring wraps, stale
    high-position garbage would alias into low slots that count-based
    decode masking treats as valid). The caller passes `offset` already
    reduced mod CL; chunk size divides CL so the slice never shifts.
    """
    C = new.shape[1]
    merged = new.astype(cache.dtype)
    if write_mask is not None:
        old = jax.lax.dynamic_slice_in_dim(cache, offset, C, axis=1)
        shape = write_mask.shape + (1,) * (cache.ndim - write_mask.ndim)
        merged = jnp.where(write_mask.reshape(shape), merged, old)
    return jax.lax.dynamic_update_slice_in_dim(cache, merged, offset, axis=1)


def chunk_attention(q, k_chunk, v_chunk, k_cache, v_cache, offset, *, scale):
    """Two-source chunked-prefill attention (jnp twin of the Pallas
    `kernels.prefill_attention` kernel — see its docstring for the mask
    derivation). q: (B,C,H,Dk); k_chunk/v_chunk: (B,C,KV,D); caches:
    (B,CL,KV,D) in their PRE-chunk state; offset: scalar absolute position
    of the chunk's first token.

    Query i (absolute position qp = offset+i) attends to (1) cache slots j
    holding absolute position p_j = offset-1 - ((offset-1-j) mod CL) with
    p_j >= 0 and qp - p_j < CL (ring addressing; degenerates to j < offset
    on a full-length cache), and (2) the chunk's own keys causally."""
    B, C, H, Dk = q.shape
    CL, KV = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    rep = H // KV
    qr = q.reshape(B, C, KV, rep, Dk)
    qp = offset + jnp.arange(C)                                   # (C,)
    j = jnp.arange(CL)
    p_j = (offset - 1) - jnp.mod(offset - 1 - j, CL)              # (CL,)
    valid = (p_j[None] >= 0) & (qp[:, None] - p_j[None] < CL)     # (C,CL)
    s_cache = jnp.einsum("bqgrd,bkgd->bgrqk", qr, k_cache,
                         preferred_element_type=jnp.float32) * scale
    s_cache = jnp.where(valid[None, None, None], s_cache, NEG_INF)
    s_chunk = jnp.einsum("bqgrd,bkgd->bgrqk", qr, k_chunk,
                         preferred_element_type=jnp.float32) * scale
    causal = jnp.arange(C)[:, None] >= jnp.arange(C)[None, :]     # (C,C)
    s_chunk = jnp.where(causal[None, None, None], s_chunk, NEG_INF)
    p = jax.nn.softmax(jnp.concatenate([s_cache, s_chunk], axis=-1), axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p[..., :CL].astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    out += jnp.einsum("bgrqk,bkgd->bqgrd", p[..., CL:].astype(v_chunk.dtype),
                      v_chunk, preferred_element_type=jnp.float32)
    return out.reshape(B, C, H, Dv).astype(q.dtype)


def prefill_block_k(cache_len: int) -> int:
    """prefill_attention cache-block size for a given cache length —
    shared with the engine's offset_hint bucketing so the two layers
    cannot desync (mirror of `decode_block_k`)."""
    return min(128, cache_len)


def _use_prefill_kernel(cfg: ModelConfig, C: int, CL: int) -> bool:
    return cfg.use_pallas and C <= CL and CL % prefill_block_k(CL) == 0


def _chunk_attention_any(q, k_chunk, v_chunk, k_cache, v_cache, offset,
                         cfg: ModelConfig, scale: float,
                         offset_hint: Optional[int] = None):
    """Route chunk-vs-cache attention through the Pallas prefill kernel
    when shapes fit, else the jnp twin. offset_hint (static, >=
    min(offset, CL)) shrinks the kernel's cache-block grid — far cache
    blocks are never launched for early chunks."""
    C, CL = q.shape[1], k_cache.shape[1]
    if _use_prefill_kernel(cfg, C, CL):
        from repro.kernels import ops as kops
        return kops.prefill_attention(q, k_chunk, v_chunk, k_cache, v_cache,
                                      offset, scale=scale,
                                      block_k=prefill_block_k(CL),
                                      offset_hint=offset_hint,
                                      interpret=cfg.pallas_interpret)
    return chunk_attention(q, k_chunk, v_chunk, k_cache, v_cache, offset,
                           scale=scale)


def gqa_prefill_chunk(p, x, positions, cache_k, cache_v, offset, write_mask,
                      cfg: ModelConfig, offset_hint: Optional[int] = None,
                      block_tables=None):
    """One GQA layer over a C-token prompt chunk. x: (B,C,d). Attends the
    chunk against the cache prefix plus itself (attend-then-write: on a
    ring cache the chunk's writes evict exactly the slots leaving the
    window), then writes the chunk's K/V at [offset mod CL, ...) masked by
    write_mask (B,) or (B,C). With `block_tables` the caches are page
    pools: attend against the gathered view, write into pages (the engine
    keeps chunk | page_size, so the chunk lands in one block).
    Returns y (B,C,d), (cache_k, cache_v)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q, k = _maybe_qk_norm(cfg, p, q, k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if block_tables is None:
        view_k, view_v = cache_k, cache_v
        CL = cache_k.shape[1]
    else:
        view_k = paged_gather(cache_k, block_tables)
        view_v = paged_gather(cache_v, block_tables)
        CL = view_k.shape[1]
    y = _chunk_attention_any(q, k, v, view_k, view_v, offset, cfg,
                             1.0 / np.sqrt(cfg.d_head),
                             offset_hint=offset_hint)
    off_w = jnp.mod(offset, CL)
    if block_tables is None:
        cache_k = write_cache_chunk(cache_k, k, off_w, write_mask)
        cache_v = write_cache_chunk(cache_v, v, off_w, write_mask)
    else:
        cache_k = write_cache_chunk_paged(cache_k, k, off_w, write_mask,
                                          block_tables)
        cache_v = write_cache_chunk_paged(cache_v, v, off_w, write_mask,
                                          block_tables)
    y = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    return y, (cache_k, cache_v)


def mla_prefill_chunk(p, x, positions, cache_ckv, cache_krope, offset,
                      write_mask, cfg: ModelConfig,
                      offset_hint: Optional[int] = None,
                      block_tables=None):
    """One absorbed-MLA layer over a C-token prompt chunk: scores in latent
    space against the compressed cache (same math as mla_decode, C queries).
    Routed through the shared prefill-attention primitive by treating the
    latent as a single KV head with the rope part concatenated onto the key
    dim (score = q_latent·c_kv + q_rope·k_rope) and the latent itself as
    the value. Returns y (B,C,d), (cache_ckv, cache_krope)."""
    B, C, _ = x.shape
    if block_tables is None:
        CL = cache_ckv.shape[1]
        view_ckv, view_krope = cache_ckv, cache_krope
    else:
        view_ckv = paged_gather(cache_ckv, block_tables)
        view_krope = paged_gather(cache_krope, block_tables)
        CL = view_ckv.shape[1]
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])   # (B,C,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., cfg.kv_lora_rank:], positions, cfg.rope_theta)

    # absorb W_uk into q: (B,C,H,nope) x (r,H,nope) -> (B,C,H,r)
    q_latent = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["wk_b"])
    q_cat = jnp.concatenate([q_latent, q_rope], axis=-1)     # (B,C,H,r+rope)
    kh_cat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None]
    kc_cat = jnp.concatenate([view_ckv, view_krope], axis=-1)[:, :, None]
    o_latent = _chunk_attention_any(
        q_cat, kh_cat, c_kv[:, :, None], kc_cat, view_ckv[:, :, None],
        offset, cfg, 1.0 / np.sqrt(nope + rope),
        offset_hint=offset_hint)                             # (B,C,H,r)

    off_w = jnp.mod(offset, CL)
    if block_tables is None:
        cache_ckv = write_cache_chunk(cache_ckv, c_kv, off_w, write_mask)
        cache_krope = write_cache_chunk(cache_krope, k_rope, off_w, write_mask)
    else:
        cache_ckv = write_cache_chunk_paged(cache_ckv, c_kv, off_w,
                                            write_mask, block_tables)
        cache_krope = write_cache_chunk_paged(cache_krope, k_rope, off_w,
                                              write_mask, block_tables)
    o = jnp.einsum("bqhr,rhk->bqhk", o_latent.astype(x.dtype), p["wv_b"])
    y = jnp.einsum("bqhk,hkd->bqd", o, p["wo"])
    return y, (cache_ckv, cache_krope)
