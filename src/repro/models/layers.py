"""Core layers: parameter definition/initialization, RMSNorm, RoPE, SwiGLU."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import Annotated


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any
    scale: float = 0.02  # stddev for normal init; 0.0 -> zeros; -1.0 -> ones

    def abstract(self) -> Annotated:
        return Annotated(jax.ShapeDtypeStruct(self.shape, self.dtype), self.logical)

    def init(self, key) -> Annotated:
        if self.scale == 0.0:
            v = jnp.zeros(self.shape, self.dtype)
        elif self.scale == -1.0:
            v = jnp.ones(self.shape, self.dtype)
        else:
            v = (jax.random.normal(key, self.shape, jnp.float32) * self.scale).astype(self.dtype)
        return Annotated(v, self.logical)


def build_params(defs, key=None, abstract: bool = False):
    """Nested dict of ParamDef -> nested dict of Annotated."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    if abstract:
        vals = [d.abstract() for d in leaves]
    else:
        keys = jax.random.split(key, len(leaves))
        vals = [d.init(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope_frequencies(d: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d, 2, dtype=np.float32) / d))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, d_head) or (..., seq, d); positions: (..., seq)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta))  # (d/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, d/2)
    if x.ndim == angles.ndim + 1:  # heads dimension present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def ffn_defs(d_model: int, d_ff: int, n_layers_stack: int, dtype,
             prefix_dims: Tuple[int, ...] = (), prefix_logical=()) -> Dict[str, ParamDef]:
    Ld = (n_layers_stack,) + tuple(prefix_dims)
    Ll = ("layers",) + tuple(prefix_logical)
    out_scale = 0.02 / np.sqrt(2 * max(n_layers_stack, 1))
    return {
        "gate": ParamDef(Ld + (d_model, d_ff), Ll + ("p_embed", "p_mlp"), dtype),
        "up": ParamDef(Ld + (d_model, d_ff), Ll + ("p_embed", "p_mlp"), dtype),
        "down": ParamDef(Ld + (d_ff, d_model), Ll + ("p_mlp", "p_embed"), dtype, out_scale),
    }
