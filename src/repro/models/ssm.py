"""Mamba2 / SSD (state-space duality) block. [arXiv:2405.21060]

Chunked SSD for train/prefill (the jnp twin of the Pallas ssd_scan kernel)
and an O(1)-state recurrent step for decode. Used standalone (mamba2) and as
the SSM branch of Hymba hybrid layers.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef, rms_norm
from repro.shardctx import constrain


def ssm_defs(cfg: ModelConfig, n_stack: int) -> Dict[str, ParamDef]:
    d, dt = cfg.d_model, cfg.dtype
    di, G, N, H = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.n_ssm_heads
    conv_ch = di + 2 * G * N
    L, Ll = (n_stack,), ("layers",)
    out_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    return {
        # in_proj emits [z (di), xBC (di + 2GN), dt (H)]
        "in_proj": ParamDef(L + (d, 2 * di + 2 * G * N + H),
                            Ll + ("p_embed", "p_inner"), dt),
        "conv_w": ParamDef(L + (cfg.d_conv, conv_ch), Ll + ("p_conv", "p_inner"), dt),
        "conv_b": ParamDef(L + (conv_ch,), Ll + ("p_inner",), dt, 0.0),
        "A_log": ParamDef(L + (H,), Ll + ("p_none",), jnp.float32, -1.0),
        "D": ParamDef(L + (H,), Ll + ("p_none",), jnp.float32, -1.0),
        "dt_bias": ParamDef(L + (H,), Ll + ("p_none",), jnp.float32, 0.0),
        "gate_norm": ParamDef(L + (di,), Ll + ("p_inner",), dt, -1.0),
        "out_proj": ParamDef(L + (di, d), Ll + ("p_inner", "p_embed"), dt, out_scale),
    }


def _segsum(x):
    """x: (..., Q). Lower-triangular pairwise cumulative sums:
    out[..., i, j] = sum_{k=j+1..i} x[..., k]  (i >= j), -inf above diag."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    i, j = jnp.arange(Q)[:, None], jnp.arange(Q)[None, :]
    return jnp.where(i >= j, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD. x: (b,l,h,p); dt: (b,l,h); A: (h,) (negative);
    B,C: (b,l,g,n). Returns y: (b,l,h,p) and final state (b,h,p,n).
    `initial_state` (b,h,p,n) f32 seeds the inter-chunk recurrence —
    the chunked-prefill path feeds the previous chunk's state here."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if l % chunk:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = x.shape[1]
    nc = L // chunk
    rep = h // g

    xd = (x * dt[..., None]).astype(jnp.float32)        # fold dt into x
    dA = dt * A[None, None, :]                          # (b,L,h)

    def cview(t, trailing):
        return t.reshape((b, nc, chunk) + trailing)

    xc = cview(xd, (h, p))
    dAc = cview(dA, (h,)).transpose(0, 3, 1, 2)         # (b,h,nc,Q)
    Bc = cview(B.astype(jnp.float32), (g, n))
    Cc = cview(C.astype(jnp.float32), (g, n))
    Bh = jnp.repeat(Bc, rep, axis=3)                    # (b,nc,Q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    A_cum = jnp.cumsum(dAc, axis=-1)                    # (b,h,nc,Q)

    # --- intra-chunk (diagonal blocks) ---
    Lmat = jnp.exp(_segsum(dAc))                        # (b,h,nc,Q,Q)
    Y_diag = jnp.einsum("bcqhn,bcshn,bhcqs,bcshp->bcqhp", Ch, Bh, Lmat, xc)

    # --- chunk states ---
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)     # (b,h,nc,Q)
    states = jnp.einsum("bcqhn,bhcq,bcqhp->bchpn", Bh, decay_states, xc)

    # --- inter-chunk recurrence (sequential scan over chunks) ---
    chunk_decay = jnp.exp(A_cum[..., -1])               # (b,h,nc)

    def step(carry, inp):
        st, dec = inp                                   # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                               # emit state *before* chunk

    if initial_state is None:
        st0 = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        st0 = initial_state.astype(jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, st0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    decay_out = jnp.exp(A_cum)                          # (b,h,nc,Q)
    Y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Ch, prev_states, decay_out)

    y = (Y_diag + Y_off).reshape(b, L, h, p)[:, :l]
    return y.astype(x.dtype), final_state


def _causal_conv(xBC, w, bias, left=None):
    """Depthwise causal conv. xBC: (b,l,ch); w: (k,ch). `left` (b,k-1,ch)
    supplies the pre-conv inputs preceding this chunk (zero-padded when
    absent — the start-of-sequence case)."""
    k = w.shape[0]
    if left is None:
        pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([left.astype(xBC.dtype), xBC], axis=1)
    # sum_{i} x[t-k+1+i] * w[i]
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i] for i in range(k))
    return out + bias


def ssm_forward(p, x, cfg: ModelConfig, return_state: bool = False,
                initial_state=None, token_mask=None):
    """Full-sequence Mamba2 block. x: (B,S,d) -> (B,S,d).

    initial_state=(conv_state (B,k-1,ch), ssd_state (B,H,P,N)) resumes the
    recurrence mid-sequence — the chunked-prefill path processes a prompt
    in fixed-size chunks by threading the state between calls.

    token_mask (B,S) marks which chunk positions belong to the sequence
    (must be a contiguous prefix per row). Masked-out tokens contribute
    nothing to the SSD state (their dt is zeroed, so decay=1 and input=0)
    and the returned conv state is gathered at each row's last valid
    position — rows whose prompt ended in an earlier chunk pass through
    with both states unchanged."""
    di, G, N, H = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    conv_left = ssd_init = None
    if initial_state is not None:
        conv_left, ssd_init = initial_state
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    xBC_pre = xBC
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"], left=conv_left)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs, B, C = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = constrain(xs, ("batch", "seq", "mlp"))
    b, S = x.shape[0], x.shape[1]
    xs = xs.reshape(b, S, H, P)
    B = B.reshape(b, S, G, N)
    C = C.reshape(b, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if token_mask is not None:
        # masked tokens: dt=0 => decay exp(0)=1 and input dt*x=0, i.e. a
        # structural no-op on the SSD recurrence
        dt = dt * token_mask[..., None]
    A = -jnp.exp(p["A_log"])
    if (cfg.use_pallas and S % cfg.ssm_chunk == 0 and ssd_init is None
            and token_mask is None):
        from repro.kernels import ops as kops
        y, state = kops.ssd_scan(xs, dt, A, B, C, chunk=cfg.ssm_chunk,
                                 interpret=cfg.pallas_interpret)
        y = y.astype(jnp.float32)
        state = jnp.swapaxes(state, -1, -2)  # kernel emits (b,h,n,p)
    else:
        y, state = ssd_chunked(xs, dt, A, B, C, cfg.ssm_chunk,
                               initial_state=ssd_init)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        # conv state = last (d_conv-1) pre-conv inputs (prepend the carried
        # left context, or zero-pad, so short chunks still have k-1 rows)
        k = cfg.d_conv
        if conv_left is not None:
            pre = jnp.concatenate(
                [conv_left.astype(xBC_pre.dtype), xBC_pre], axis=1)
        else:
            pre = jnp.pad(xBC_pre, ((0, 0), (max(0, k - 1 - S), 0), (0, 0)))
        if token_mask is not None:
            # per-row: gather the k-1 inputs ending at the last valid
            # position. rel = #valid tokens this chunk; indices rel+arange
            # into [left ; chunk] land exactly on the old conv state when
            # rel == 0, so finished rows pass through unchanged. The gather
            # needs exactly k-1 left-context rows: zero-pad when no state
            # was carried (start of sequence).
            if conv_left is None:
                pre = jnp.pad(xBC_pre, ((0, 0), (k - 1, 0), (0, 0)))
            rel = token_mask.sum(axis=1).astype(jnp.int32)         # (B,)
            idx = rel[:, None] + jnp.arange(k - 1, dtype=jnp.int32)[None]
            conv_new = jnp.take_along_axis(pre, idx[:, :, None], axis=1)
            return out, (conv_new, state)
        return out, (pre[:, -(k - 1):], state)
    return out


def ssm_decode(p, x, conv_state, ssd_state, cfg: ModelConfig):
    """One-token recurrent step. x: (B,1,d); conv_state: (B,k-1,ch);
    ssd_state: (B,H,P,N) fp32. Returns y (B,1,d), (conv_state, ssd_state)."""
    di, G, N, H = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    b = x.shape[0]
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    # conv over [state ; new]
    window = jnp.concatenate([conv_state, xBC[:, None]], axis=1)  # (b,k,ch)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_state = window[:, 1:]
    xBC = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs, B, C = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(b, H, P)
    B = B.reshape(b, G, N)
    C = C.reshape(b, G, N)
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)  # (b,H,N)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None])                                   # (b,H)
    dx = (dt[..., None] * xs.astype(jnp.float32))                # (b,H,P)
    ssd_state = ssd_state * dA[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", dx, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", ssd_state, Ch)               # (b,H,P)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None]
    return out, (conv_state, ssd_state)
