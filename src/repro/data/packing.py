"""Online sequence packing (paper §4 'Key optimizations ... online sequence
packing for fast training').

Finished rollouts of ragged length are packed greedily (first-fit) into
fixed (B, S) training rows; `segment_ids` prevent cross-sequence attention,
`positions` restart per segment, and `loss_mask` covers completion tokens
only. Packed batches match the `train` input_specs exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Rollout:
    """One finished sequence from the generation engine."""
    tokens: np.ndarray             # (T,) prompt + completion
    prompt_len: int
    behavior_logprobs: np.ndarray  # (T,) 0 for prompt positions
    reward: float
    weight_versions: np.ndarray    # (T,) trainer version each token was sampled under
    finished_at: float = 0.0       # sim-clock timestamp (lag bookkeeping)
    prompt_key: int = 0            # prompt identity (group-relative baseline)
    ref_logprobs: Optional[np.ndarray] = None   # filled by the Preprocessor
    token_rewards: Optional[np.ndarray] = None  # KL-shaped per-token rewards
    slot: int = -1                 # engine slot that produced this rollout
    truncated: bool = False        # hit max_len without emitting EOS

    @property
    def length(self) -> int:
        return int(self.tokens.shape[0])


def pack(rollouts: List[Rollout], batch: int, seq: int,
         pad_id: int = 0, trainer_version: Optional[int] = None,
         max_lag: Optional[int] = None) -> Dict[str, np.ndarray]:
    """First-fit pack rollouts into (batch, seq) rows. Sequences longer than
    `seq` are truncated; rows that stay empty are fully masked.

    Two phases: a cheap placement pass (first-fit row search over running
    row occupancy — pure python ints), then one batched copy per row per
    field — each row's segments are concatenated and written with a single
    slice assign, instead of 7 separate (T,) scatter assignments per
    rollout (the old inner loop dominated pack() time at engine-scale
    rollout counts).

    When `trainer_version` is given (the version the learner will step
    *from*, i.e. `trainer.version` at batch-assembly time), the batch also
    carries the staleness contract: per-token `lag = trainer_version -
    weight_versions` on completion positions (0 on prompt/pad, clipped at
    0 so a post-rollback batch can't go negative) and a per-segment
    `truncated` flag. With `max_lag` set, completion tokens whose lag
    exceeds the bound are masked out of the loss and counted in
    `packing_stats["lag_masked"]` — the hard half of the periodic-
    asynchrony barrier (the actor-side gate throttles new stale sampling;
    this guarantees no over-bound token is ever trained on)."""
    tokens = np.full((batch, seq), pad_id, np.int32)
    segment_ids = np.zeros((batch, seq), np.int32)
    positions = np.zeros((batch, seq), np.int32)
    loss_mask = np.zeros((batch, seq), np.float32)
    behavior_lp = np.zeros((batch, seq), np.float32)
    rewards = np.zeros((batch, seq), np.float32)   # per-token (broadcast of seq reward)
    versions = np.zeros((batch, seq), np.int32)
    with_lag = trainer_version is not None
    if with_lag:
        lag = np.zeros((batch, seq), np.int32)
        trunc = np.zeros((batch, seq), np.float32)
    used = np.zeros(batch, np.int32)
    dropped = 0

    # ---- placement: first-fit row per rollout --------------------------
    per_row: List[List[Rollout]] = [[] for _ in range(batch)]
    for r in rollouts:
        T = min(r.length, seq)
        row = -1
        for b in range(batch):
            if used[b] + T <= seq:
                row = b
                break
        if row < 0:
            dropped += 1
            continue
        per_row[row].append(r)
        used[row] += T

    # ---- one batched copy per row per field ----------------------------
    for b, rs in enumerate(per_row):
        if not rs:
            continue
        Ts = [min(r.length, seq) for r in rs]
        n = int(np.sum(Ts))
        tokens[b, :n] = np.concatenate([r.tokens[:T] for r, T in zip(rs, Ts)])
        segment_ids[b, :n] = np.repeat(np.arange(1, len(rs) + 1), Ts)
        positions[b, :n] = np.concatenate([np.arange(T) for T in Ts])
        # loss on completion tokens only (prediction targets are shifted in
        # the trainer; the mask marks *sampled* positions)
        loss_mask[b, :n] = np.concatenate(
            [(np.arange(T) >= min(r.prompt_len, T)).astype(np.float32)
             for r, T in zip(rs, Ts)])
        behavior_lp[b, :n] = np.concatenate(
            [r.behavior_logprobs[:T] for r, T in zip(rs, Ts)])
        rewards[b, :n] = np.concatenate(
            [r.token_rewards[:T] if r.token_rewards is not None
             else np.full(T, r.reward, np.float32) for r, T in zip(rs, Ts)])
        versions[b, :n] = np.concatenate(
            [r.weight_versions[:T] for r, T in zip(rs, Ts)])
        if with_lag:
            # lag only on completion positions (prompt stamps are 0 by
            # engine convention, not a real sampling version)
            lag[b, :n] = np.maximum(
                trainer_version - versions[b, :n], 0
            ).astype(np.int32) * (loss_mask[b, :n] > 0)
            trunc[b, :n] = np.concatenate(
                [np.full(T, float(r.truncated), np.float32)
                 for r, T in zip(rs, Ts)])

    lag_masked = 0
    if with_lag and max_lag is not None:
        over = (lag > max_lag) & (loss_mask > 0)
        lag_masked = int(over.sum())
        loss_mask = np.where(over, 0.0, loss_mask).astype(np.float32)

    out = {
        "tokens": tokens,
        "segment_ids": segment_ids,
        "positions": positions,
        "loss_mask": loss_mask,
        "behavior_logprobs": behavior_lp,
        "rewards": rewards,
        "weight_versions": versions,
        "packing_stats": {
            "fill": float(used.sum()) / float(batch * seq),
            "dropped": dropped,
        },
    }
    if with_lag:
        out["lag"] = lag
        out["truncated"] = trunc
        out["packing_stats"]["lag_masked"] = lag_masked
    return out
