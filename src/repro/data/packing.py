"""Online sequence packing (paper §4 'Key optimizations ... online sequence
packing for fast training').

Finished rollouts of ragged length are packed greedily (first-fit) into
fixed (B, S) training rows; `segment_ids` prevent cross-sequence attention,
`positions` restart per segment, and `loss_mask` covers completion tokens
only. Packed batches match the `train` input_specs exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Rollout:
    """One finished sequence from the generation engine."""
    tokens: np.ndarray             # (T,) prompt + completion
    prompt_len: int
    behavior_logprobs: np.ndarray  # (T,) 0 for prompt positions
    reward: float
    weight_versions: np.ndarray    # (T,) trainer version each token was sampled under
    finished_at: float = 0.0       # sim-clock timestamp (lag bookkeeping)
    prompt_key: int = 0            # prompt identity (group-relative baseline)
    ref_logprobs: Optional[np.ndarray] = None   # filled by the Preprocessor
    token_rewards: Optional[np.ndarray] = None  # KL-shaped per-token rewards
    slot: int = -1                 # engine slot that produced this rollout

    @property
    def length(self) -> int:
        return int(self.tokens.shape[0])


def pack(rollouts: List[Rollout], batch: int, seq: int,
         pad_id: int = 0) -> Dict[str, np.ndarray]:
    """First-fit pack rollouts into (batch, seq) rows. Sequences longer than
    `seq` are truncated; rows that stay empty are fully masked."""
    tokens = np.full((batch, seq), pad_id, np.int32)
    segment_ids = np.zeros((batch, seq), np.int32)
    positions = np.zeros((batch, seq), np.int32)
    loss_mask = np.zeros((batch, seq), np.float32)
    behavior_lp = np.zeros((batch, seq), np.float32)
    rewards = np.zeros((batch, seq), np.float32)   # per-token (broadcast of seq reward)
    versions = np.zeros((batch, seq), np.int32)
    used = np.zeros(batch, np.int32)
    n_seg = np.zeros(batch, np.int32)
    dropped = 0

    for r in rollouts:
        T = min(r.length, seq)
        row = -1
        for b in range(batch):
            if used[b] + T <= seq:
                row = b
                break
        if row < 0:
            dropped += 1
            continue
        o = used[row]
        tokens[row, o:o + T] = r.tokens[:T]
        n_seg[row] += 1
        segment_ids[row, o:o + T] = n_seg[row]
        positions[row, o:o + T] = np.arange(T)
        # loss on completion tokens only (prediction targets are shifted in
        # the trainer; the mask marks *sampled* positions)
        lm_start = min(r.prompt_len, T)
        loss_mask[row, o + lm_start:o + T] = 1.0
        behavior_lp[row, o:o + T] = r.behavior_logprobs[:T]
        if r.token_rewards is not None:
            rewards[row, o:o + T] = r.token_rewards[:T]
        else:
            rewards[row, o:o + T] = r.reward
        versions[row, o:o + T] = r.weight_versions[:T]
        used[row] += T

    return {
        "tokens": tokens,
        "segment_ids": segment_ids,
        "positions": positions,
        "loss_mask": loss_mask,
        "behavior_logprobs": behavior_lp,
        "rewards": rewards,
        "weight_versions": versions,
        "packing_stats": {
            "fill": float(used.sum()) / float(batch * seq),
            "dropped": dropped,
        },
    }
