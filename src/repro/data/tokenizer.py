"""Character-level tokenizer for the synthetic math RL task."""
from __future__ import annotations

from typing import List

_CHARS = "0123456789+-*=() "


class CharTokenizer:
    PAD = 0
    BOS = 1
    EOS = 2

    def __init__(self):
        self.itos = {self.PAD: "<pad>", self.BOS: "<bos>", self.EOS: "<eos>"}
        self.stoi = {}
        for i, ch in enumerate(_CHARS):
            tid = 3 + i
            self.itos[tid] = ch
            self.stoi[ch] = tid
        self.vocab_size = 3 + len(_CHARS)

    def encode(self, text: str, bos: bool = False) -> List[int]:
        ids = [self.stoi[c] for c in text]
        return ([self.BOS] if bos else []) + ids

    def decode(self, ids) -> str:
        return "".join(self.itos.get(int(i), "?") for i in ids
                       if int(i) not in (self.PAD, self.BOS, self.EOS))
