"""Synthetic math reasoning task (stand-in for OpenReasoner-Zero's 17K
problems): arithmetic expressions the policy must answer after '='.

Reward follows the paper: 1 for a correct answer, 0 otherwise, plus a soft
penalty as the generation approaches the maximum sequence length.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.data.tokenizer import CharTokenizer


@dataclasses.dataclass
class Problem:
    prompt_ids: List[int]
    answer: int


class MathTask:
    def __init__(self, max_operand: int = 20, ops: str = "+-", seed: int = 0,
                 partial_credit: bool = False):
        """partial_credit=True adds dense shaping for the CPU testbed (a
        well-formed short numeric answer earns 0.25 even when wrong) —
        exact-match-only reward is too sparse for a char-level model trained
        from scratch in a few hundred steps."""
        self.tok = CharTokenizer()
        self.max_operand = max_operand
        self.ops = ops
        self.partial_credit = partial_credit
        self.rng = np.random.RandomState(seed)

    def sample(self) -> Problem:
        a = int(self.rng.randint(0, self.max_operand))
        b = int(self.rng.randint(0, self.max_operand))
        op = self.ops[int(self.rng.randint(len(self.ops)))]
        ans = a + b if op == "+" else (a - b if op == "-" else a * b)
        text = f"{a}{op}{b}="
        return Problem(self.tok.encode(text, bos=True), ans)

    def sample_batch(self, n: int) -> List[Problem]:
        return [self.sample() for _ in range(n)]

    def reward(self, problem: Problem, completion_ids: Sequence[int],
               max_new_tokens: int, soft_penalty_margin: int = 4) -> float:
        """1.0 if the completion spells the correct integer (then EOS),
        0.0 otherwise; soft penalty near the length limit (paper §5)."""
        text = self.tok.decode(completion_ids).strip()
        # cut at first non-digit/non-sign character
        body = ""
        for i, ch in enumerate(text):
            if ch.isdigit() or (ch == "-" and i == 0):
                body += ch
            else:
                break
        correct = False
        well_formed = False
        if body not in ("", "-"):
            try:
                correct = int(body) == problem.answer
                well_formed = body == text  # nothing but the number
            except ValueError:
                correct = False
        r = 1.0 if correct else 0.0
        if not correct and self.partial_credit and well_formed \
                and len(completion_ids) <= 4:
            r = 0.25  # dense shaping: short, purely-numeric answer
        overrun = len(completion_ids) - (max_new_tokens - soft_penalty_margin)
        if overrun > 0:
            r -= 0.1 * overrun  # soft length penalty
        return float(r)
