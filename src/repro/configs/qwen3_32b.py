"""Qwen3-32B: dense, GQA (64H/8KV), qk-norm. [hf:Qwen/Qwen3-8B family card]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        arch_type="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,  # Qwen3 uses decoupled head_dim=128 (n_heads*d_head != d_model)
        d_ff=25600,
        vocab_size=151936,
        use_qk_norm=True,
        rope_theta=1e6,
        source="hf:Qwen/Qwen3-8B (family); Qwen3 technical report",
    )
