"""Architecture registry: ``get_config("<arch-id>")`` and the shape table."""
from __future__ import annotations

from typing import Dict

from repro.configs import (
    deepseek_v3_671b,
    granite_3_2b,
    granite_moe_1b,
    hymba_1_5b,
    llama3_8b,
    mamba2_2_7b,
    musicgen_medium,
    phi3_mini_3_8b,
    phi3_vision_4_2b,
    qwen3_32b,
)
from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeSpec,
    for_shape,
    input_specs,
    kv_cache_specs,
    smoke_config,
)

_MODULES = {
    "qwen3-32b": qwen3_32b,
    "hymba-1.5b": hymba_1_5b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "phi-3-vision-4.2b": phi3_vision_4_2b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "llama3-8b": llama3_8b,
    "granite-3-2b": granite_3_2b,
    "musicgen-medium": musicgen_medium,
    "deepseek-v3-671b": deepseek_v3_671b,
    "mamba2-2.7b": mamba2_2_7b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    return _MODULES[arch].config()


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "SHAPES", "ModelConfig", "ShapeSpec", "all_configs",
    "for_shape", "get_config", "input_specs", "kv_cache_specs", "smoke_config",
]
