"""Phi-3-mini-3.8B: dense, RoPE, SwiGLU, MHA (kv=32 == heads). [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        arch_type="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_head=96,
        d_ff=8192,
        vocab_size=32064,
        rope_theta=1e4,
        source="arXiv:2404.14219 (Phi-3 technical report)",
    )
