"""Granite-3.0-1B-A400M: MoE, 32 experts top-8, GQA.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        arch_type="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_head=64,
        d_ff=512,       # per-expert ffn dim
        moe_d_ff=512,
        vocab_size=49155,
        n_experts=32,
        experts_per_token=8,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
