"""MusicGen-medium: decoder-only transformer over EnCodec tokens; the
EnCodec/conditioning frontend is stubbed (input_specs provides precomputed
conditioning-frame embeddings as a prefix). [arXiv:2306.05284]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        arch_type="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        d_head=64,
        d_ff=6144,
        vocab_size=2048,  # EnCodec codebook size
        modality="audio",
        n_prefix_tokens=64,  # stubbed T5/conditioning frames
        source="arXiv:2306.05284 (MusicGen: simple and controllable music generation)",
    )
