"""Granite-3.0-2B: dense, GQA (32H/8KV). [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        arch_type="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_head=64,
        d_ff=8192,
        vocab_size=49155,
        source="hf:ibm-granite/granite-3.0-2b-base",
    )
