"""Tiny dense config for the CPU RL experiments (learning-curve studies,
examples, tests). Same family as the paper's Qwen-2.5-7B runs (dense GQA
decoder), scaled to run hundreds of optimizer steps on one CPU."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def config(vocab_size: int = 32, d_model: int = 128, n_layers: int = 2,
           use_value_head: bool = True) -> ModelConfig:
    return ModelConfig(
        name="tiny-rl",
        arch_type="dense",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        d_ff=4 * d_model,
        vocab_size=vocab_size,
        dtype=jnp.float32,
        use_value_head=use_value_head,
        tie_embeddings=True,
        source="repro-internal (CPU-scale RL testbed)",
    )
