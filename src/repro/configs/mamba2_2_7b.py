"""Mamba2-2.7B: attention-free SSM with SSD (state-space duality) layers.
[arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        arch_type="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        d_head=0,
        d_ff=0,           # attention-free, no separate MLP (Mamba2 block only)
        vocab_size=50280,
        ssm_state=128,
        ssm_n_groups=1,
        ssm_head_dim=64,
        expand=2,
        ssm_chunk=64,
        source="arXiv:2405.21060 (Transformers are SSMs / Mamba-2)",
    )
