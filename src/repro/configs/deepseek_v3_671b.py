"""DeepSeek-V3-671B: MLA attention (compressed latent KV cache), MoE with
1 shared + 256 routed experts (top-8), multi-token prediction head.
[arXiv:2412.19437]

Assigned spec: 61L, d_model=7168, 128H, d_ff=2048 (per routed expert),
vocab=129280. Per the paper, the first 3 layers are dense with d_ff=18432.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,  # MLA: all heads read the shared compressed latent
        d_head=128,
        d_ff=2048,
        moe_d_ff=2048,
        vocab_size=129280,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        n_experts=256,
        experts_per_token=8,
        n_shared_experts=1,
        n_dense_layers=3,
        dense_d_ff=18432,
        use_mtp=True,
        mtp_depth=1,
        source="arXiv:2412.19437 (DeepSeek-V3 technical report)",
    )
