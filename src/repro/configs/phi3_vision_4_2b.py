"""Phi-3-vision-4.2B: phi3-mini backbone + CLIP ViT frontend (stubbed —
input_specs provides precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        arch_type="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_head=96,
        d_ff=8192,
        vocab_size=32064,
        rope_theta=1e4,
        modality="vision",
        n_prefix_tokens=576,  # CLIP ViT-L/14 @336: (336/14)^2 = 576 patches
        source="hf:microsoft/Phi-3-vision-128k-instruct",
    )
