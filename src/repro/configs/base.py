"""Config system: model configs, input shapes, and ShapeDtypeStruct specs.

Every assigned architecture is a `ModelConfig` instance in its own module
(`repro.configs.<arch>`), citing its source. `input_specs()` builds the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # attention options
    use_qk_norm: bool = False
    rope_theta: float = 10000.0
    attention_variant: str = "full"  # full | sliding_window (decode ring buffer)
    sliding_window: int = 8192
    # MLA (DeepSeek-V3 style multi-head latent attention)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0  # leading dense layers (DeepSeek-V3 uses 3)
    dense_d_ff: int = 0  # d_ff of those leading dense layers
    capacity_factor: float = 2.0
    router_aux_coef: float = 0.001
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_n_groups: int = 1
    ssm_chunk: int = 64
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2
    # hybrid (Hymba): parallel attention + SSM heads in every layer
    hybrid_parallel: bool = False
    # multimodal prefix (stubbed frontend provides embeddings)
    modality: str = "text"  # text | vision | audio
    n_prefix_tokens: int = 0
    # DeepSeek multi-token prediction head
    use_mtp: bool = False
    mtp_depth: int = 1
    # numerics
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # value head for RL (paper Eq. 4 baseline)
    use_value_head: bool = True
    # activation checkpointing over the layer scan (training memory)
    remat: bool = False
    # fully unroll layer scans (roofline calibration: XLA cost_analysis
    # counts a scan body once, so calibration compiles unroll at L=1,2)
    scan_unroll: bool = False
    # route attention/SSD through the Pallas TPU kernels (interpret mode on
    # CPU); falls back to the jnp path when a shape doesn't fit the kernel
    use_pallas: bool = False
    # fused linear-cross-entropy trainer loss (DESIGN.md §6): when the
    # trainer passes loss targets, `forward` skips the (B,S,V) logits
    # materialization and returns per-token logprob/lse/entropy from the
    # blockwise Pallas kernel (jnp twin when use_pallas is off). Inference
    # paths (decode/prefill) are unaffected.
    fused_loss: bool = False
    # Pallas interpret mode: None = auto (interpret off-TPU, compiled on
    # TPU); True/False forces it. Plumbed into every kernel call so TPU
    # runs never hit an interpret-mode kernel by accident.
    pallas_interpret: Optional[bool] = None
    source: str = ""

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def supports_long_decode(self) -> bool:
        """Every arch supports long_500k: SSM/hybrid natively (O(1) state);
        attention archs via the sliding-window ring-buffer cache."""
        return True

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.n_layers
        n = 0
        n += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d  # lm head
        for li in range(L):
            n += 2 * d  # 2 norms
            # --- attention ---
            if self.has_attention:
                if self.use_mla:
                    n += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.qk_rope_dim)
                    n += d * (self.kv_lora_rank + self.qk_rope_dim)
                    n += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    n += self.n_heads * self.v_head_dim * d
                else:
                    n += d * self.n_heads * self.d_head  # q
                    n += 2 * d * self.n_kv_heads * self.d_head  # k,v
                    n += self.n_heads * self.d_head * d  # o
            # --- ssm branch ---
            if self.has_ssm:
                di = self.d_inner
                n += d * (2 * di + 2 * self.ssm_n_groups * self.ssm_state + self.n_ssm_heads)
                n += self.d_conv * (di + 2 * self.ssm_n_groups * self.ssm_state)
                n += 2 * self.n_ssm_heads  # A_log, D
                n += di * d  # out proj
            # --- ffn ---
            moe_layer = self.n_experts > 0 and li >= self.n_dense_layers
            if moe_layer:
                e_ff = self.moe_d_ff
                per_expert = 3 * d * e_ff
                n += d * self.n_experts  # router
                if active_only:
                    n += self.experts_per_token * per_expert
                else:
                    n += self.n_experts * per_expert
                n += self.n_shared_experts * per_expert
            elif self.d_ff > 0:
                ff = self.dense_d_ff if (self.n_experts > 0 and self.dense_d_ff) else self.d_ff
                n += 3 * d * ff  # SwiGLU gate/up/down
        return n


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Gray-failure self-healing knobs (DESIGN.md §10): the HealthMonitor
    watchdog over the actor pool plus the trainer's numerical-robustness
    policy. Defaults are conservative enough that a healthy run with the
    monitor enabled is bit-identical to one without it — detection only
    *observes* until a threshold trips."""
    enabled: bool = True
    # watchdog sweep cadence (flashes of simulated time)
    interval: float = 20.0
    # hang detection: heartbeat deadline = max(hang_grace,
    # hang_factor * EWMA inter-tick gap) per engine
    hang_grace: float = 120.0
    hang_factor: float = 8.0
    # straggler detection: speed-normalized EWMA tick cost vs the pool
    # minimum; must exceed the factor for `patience` consecutive sweeps
    straggler_factor: float = 2.5
    straggler_patience: int = 2
    # poison-prompt circuit breaker: a prompt salvaged from this many
    # failed/hung engines is quarantined instead of requeued
    quarantine_after: int = 3
    # a detected hang is escalated to fail/salvage/requeue; unless the
    # fault plan carries its own restart_after, the wedged engine is
    # restarted this long after detection (None = leave it down)
    hang_restart_after: Optional[float] = 60.0
    # trainer robustness: auto-rollback to the newest intact checkpoint
    # after this many consecutive guarded-bad steps (0 = never)
    bad_step_rollback: int = 3
    # EWMA loss-spike divergence detector: |loss| > factor * EWMA(|loss|)
    # marks the step bad (0.0 = disabled; it is off by default because a
    # young policy's loss is legitimately spiky)
    loss_spike_factor: float = 0.0
    # rotated trainer_step_*.npz checkpoints kept for rollback targets
    ckpt_keep: int = 3


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def effective_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer cache length actually allocated for a decode shape."""
    if not cfg.has_attention:
        return 0
    if cfg.use_mla:
        return seq_len  # compressed latent cache is cheap; keep full length
    if cfg.attention_variant == "sliding_window" or seq_len > 65536:
        # long-context decode uses the sliding-window ring buffer
        return min(seq_len, cfg.sliding_window)
    return seq_len


def for_shape(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Specialize a config for an input shape (attention variant for 500k)."""
    if shape.name == "long_500k" and cfg.has_attention and not cfg.use_mla:
        return dataclasses.replace(cfg, attention_variant="sliding_window")
    return cfg


def kv_cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Dict[str, Any]:
    """ShapeDtypeStructs for the decode-state pytree (stacked over layers)."""
    L = cfg.n_layers
    s: Dict[str, Any] = {}
    if cfg.has_attention:
        cl = effective_cache_len(cfg, cache_len)
        if cfg.use_mla:
            s["c_kv"] = jax.ShapeDtypeStruct((L, batch, cl, cfg.kv_lora_rank), cfg.dtype)
            s["k_rope"] = jax.ShapeDtypeStruct((L, batch, cl, cfg.qk_rope_dim), cfg.dtype)
        else:
            s["k"] = jax.ShapeDtypeStruct((L, batch, cl, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
            s["v"] = jax.ShapeDtypeStruct((L, batch, cl, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
    if cfg.has_ssm:
        s["conv"] = jax.ShapeDtypeStruct(
            (L, batch, cfg.d_conv - 1,
             cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state), cfg.dtype)
        s["ssd"] = jax.ShapeDtypeStruct(
            (L, batch, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    return s


def paged_layout(cfg: ModelConfig, cache_len: int,
                 page_size: int) -> tuple:
    """(page_size, n_blocks) for a paged attention cache of logical length
    `cache_len`. page_size is reduced until it divides the cache length so
    every logical ring position maps to exactly one (block, offset)."""
    cl = effective_cache_len(cfg, cache_len)
    if cl == 0:
        return 0, 0
    ps = max(1, min(int(page_size), cl))
    while cl % ps:
        ps -= 1
    return ps, cl // ps


def paged_cache_specs(cfg: ModelConfig, batch: int, cache_len: int,
                      n_pages: int, page_size: int) -> Dict[str, Any]:
    """ShapeDtypeStructs for the paged decode-state pytree (DESIGN.md §9).

    Attention leaves become page *pools* shaped (L, n_pages, page_size,
    ...): one physical page spans all layers of all attention leaves, so a
    single host-side integer per logical block addresses every leaf. SSM
    leaves are O(1) per slot — no paging win — and keep the slot layout
    from `kv_cache_specs`. The (batch, n_blocks) block table itself lives
    host-side (numpy) and rides into jit as an ordinary traced arg.
    """
    L = cfg.n_layers
    s: Dict[str, Any] = {}
    if cfg.has_attention:
        ps, _ = paged_layout(cfg, cache_len, page_size)
        if cfg.use_mla:
            s["c_kv"] = jax.ShapeDtypeStruct((L, n_pages, ps, cfg.kv_lora_rank), cfg.dtype)
            s["k_rope"] = jax.ShapeDtypeStruct((L, n_pages, ps, cfg.qk_rope_dim), cfg.dtype)
        else:
            s["k"] = jax.ShapeDtypeStruct((L, n_pages, ps, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
            s["v"] = jax.ShapeDtypeStruct((L, n_pages, ps, cfg.n_kv_heads, cfg.d_head), cfg.dtype)
    if cfg.has_ssm:
        ssm = kv_cache_specs(dataclasses.replace(cfg, arch_type="ssm"),
                             batch, cache_len)
        s.update(ssm)
    return s


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a step function.

    train  -> RL train batch (tokens, mask, behavior logprobs, rewards, ...)
    prefill-> prompt tokens
    decode -> one-token step against a KV cache of shape.seq_len
    """
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    sd = jax.ShapeDtypeStruct
    specs: Dict[str, Any]
    if shape.kind == "train":
        # matches repro.data.packing.pack output (online sequence packing)
        specs = {
            "tokens": sd((B, S), i32),
            "loss_mask": sd((B, S), f32),
            "behavior_logprobs": sd((B, S), f32),
            "rewards": sd((B, S), f32),  # per-token broadcast of sequence reward
            "positions": sd((B, S), i32),
            "segment_ids": sd((B, S), i32),
        }
    elif shape.kind == "prefill":
        specs = {
            "tokens": sd((B, S), i32),
            "positions": sd((B, S), i32),
        }
    else:  # decode: one new token, cache of length seq_len
        specs = {
            "tokens": sd((B, 1), i32),
            "positions": sd((B, 1), i32),
            "cache": kv_cache_specs(cfg, B, S),
            "cache_index": sd((), i32),
        }
    if cfg.modality in ("vision", "audio") and cfg.n_prefix_tokens:
        # stubbed frontend: precomputed patch/frame embeddings
        specs["prefix_embeds"] = sd((B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)
    return specs


CACHE_LOGICAL = {
    "k": ("layers", "batch", "cache_seq", "kv_heads", None),
    "v": ("layers", "batch", "cache_seq", "kv_heads", None),
    "c_kv": ("layers", "batch", "cache_seq", None),
    "k_rope": ("layers", "batch", "cache_seq", None),
    "conv": ("layers", "batch", None, "mlp"),
    "ssd": ("layers", "batch", "heads", None, None),
}


def input_logical(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Logical axis names for every input spec (same keys as input_specs)."""
    two = ("batch", "seq")
    if shape.kind == "train":
        log: Dict[str, Any] = {k: two for k in (
            "tokens", "loss_mask", "behavior_logprobs", "rewards",
            "positions", "segment_ids")}
    elif shape.kind == "prefill":
        log = {"tokens": two, "positions": two}
    else:
        log = {
            "tokens": ("batch", None),
            "positions": ("batch", None),
            "cache": {k: CACHE_LOGICAL[k]
                      for k in kv_cache_specs(cfg, 1, 8)},
            "cache_index": (),
        }
    if cfg.modality in ("vision", "audio") and cfg.n_prefix_tokens:
        log["prefix_embeds"] = ("batch", None, None)
    return log


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    if n_heads % n_kv:
        n_kv = 1
    repl: Dict[str, Any] = dict(
        n_layers=2,
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=32,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=64,
        ssm_head_dim=32 if cfg.has_ssm else cfg.ssm_head_dim,
        ssm_state=min(cfg.ssm_state, 16) if cfg.has_ssm else 0,
        ssm_chunk=16 if cfg.has_ssm else cfg.ssm_chunk,
        dtype=jnp.float32,
    )
    if cfg.use_mla:
        repl.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                    qk_rope_dim=16, v_head_dim=32)
    if cfg.n_experts:
        repl.update(n_experts=min(cfg.n_experts, 4),
                    experts_per_token=min(cfg.experts_per_token, 2),
                    moe_d_ff=64, n_dense_layers=min(cfg.n_dense_layers, 1),
                    dense_d_ff=128 if cfg.dense_d_ff else 0)
    if cfg.n_prefix_tokens:
        repl.update(n_prefix_tokens=8)
    return dataclasses.replace(cfg, **repl)
