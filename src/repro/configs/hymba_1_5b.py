"""Hymba-1.5B: hybrid-head — parallel attention + Mamba(SSM) heads in every
layer, outputs fused. [arXiv:2411.13676]

Note: the paper also uses learnable meta tokens and cross-layer KV sharing;
we implement the core parallel-head fusion (the architectural signature) and
note the omission in DESIGN.md.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        arch_type="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_head=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_head_dim=64,
        expand=2,
        hybrid_parallel=True,
        source="arXiv:2411.13676 (Hymba)",
    )
