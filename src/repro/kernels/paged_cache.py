"""Paged KV cache: block allocator, block tables, and the paged
flash-decode kernel (DESIGN.md §9).

The slot-array cache (DESIGN.md §1) reserves one contiguous `max_len`
stripe per slot. The paged cache replaces the stripe with a *block table*:
each slot maps logical cache block j (positions [j*PS, (j+1)*PS) of its
ring layout) to a physical page in a shared pool, flashinfer-style. Pool
leaves are shaped (L, n_pages, page_size, ...) — one allocation spans all
layers of a leaf, so the host allocator hands out one integer per logical
block regardless of depth.

Host side (this module, pure numpy — no jax):

  - `PageAllocator`: ref-counted free-list allocator. Page 0 is the
    reserved TRASH page: the jitted engine step has static shapes, so
    *inactive* slots still execute their cache write every step at a
    stale position — their table rows point every block at page 0, which
    absorbs those writes and is never read (positions are masked by
    per-slot lengths). Frees are LIFO and the free list is seeded in
    ascending order, so allocation order is deterministic given the call
    sequence — chaos replay stays bit-equal.
  - `BlockTables`: the (H, n_blocks) int32 table plus the copy-on-write
    discipline. `fork_row` shares a prefix by bumping refcounts (GRPO
    prefix sharing: prefill once, fork G rollouts); `ensure_writable`
    enforces the COW invariant — a page with refcount > 1 is *never*
    written: the writer first gets a fresh page and the device copies the
    old page's contents (lazy COW at the divergence block).

Device side:

  - `gather_pages`: block-table gather producing the contiguous per-slot
    view (B, CL, ...) — the default paged read path. Running the
    *unchanged* decode/prefill attention (Pallas or jnp) on the gathered
    view makes the paged engine bit-identical to the slot engine by
    construction: the valid region of the view equals the slot cache
    exactly, and invalid positions are NEG_INF-masked before they touch
    the softmax in either engine.
  - `flash_decode_paged`: the true paged kernel (opt-in,
    `EngineConfig.paged_attention="kernel"`): the block table is a
    scalar-prefetch operand and the KV BlockSpec index maps read it, so
    pages stream HBM->VMEM directly — no gathered copy. Its online
    softmax blocks are page-sized, so it matches the slot kernel
    bitwise only when page_size == decode_block_k(CL); otherwise the
    reductions reassociate and equality is fp32-tolerance (the parity
    tests pin both cases).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu
import numpy as np

from repro.kernels.common import default_interpret

NEG_INF = -1e30

TRASH_PAGE = 0


class OutOfPages(RuntimeError):
    """The pool has no free page. The engine reacts by deferring
    admission or preempting a sequence — never by corrupting a page."""


class PageAllocator:
    """Ref-counted page pool. Page 0 (TRASH_PAGE) is reserved forever.

    Deterministic: the free list is seeded ascending and reused LIFO, so
    the page sequence depends only on the alloc/free call order.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (1 is the trash page), "
                             f"got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.refcount = np.zeros(n_pages, np.int32)
        # pop() yields 1, 2, 3, ... on a fresh pool
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        # counters (page-costed admission + telemetry)
        self.total_allocs = 0
        self.cow_copies = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        """Pages currently referenced by at least one block-table entry."""
        return self.n_pages - 1 - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise OutOfPages(f"all {self.n_pages - 1} pages live")
        p = self._free.pop()
        assert self.refcount[p] == 0, f"free-list page {p} has refs"
        self.refcount[p] = 1
        self.total_allocs += 1
        return p

    def share(self, p: int) -> None:
        """One more block-table entry references page p (COW fork)."""
        if p == TRASH_PAGE:
            raise ValueError("cannot share the trash page")
        if self.refcount[p] <= 0:
            raise ValueError(f"share of dead page {p}")
        self.refcount[p] += 1

    def release(self, p: int) -> None:
        """Drop one reference; the page returns to the pool at zero."""
        if p == TRASH_PAGE:
            raise ValueError("cannot release the trash page")
        if self.refcount[p] <= 0:
            raise ValueError(f"double free of page {p}")
        self.refcount[p] -= 1
        if self.refcount[p] == 0:
            self._free.append(p)

    def check(self) -> None:
        """Conservation invariants (exercised by the property suite)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page in free list"
        assert TRASH_PAGE not in free, "trash page leaked into free list"
        assert self.refcount[TRASH_PAGE] == 0
        for p in range(1, self.n_pages):
            if p in free:
                assert self.refcount[p] == 0, f"free page {p} has refs"
            else:
                assert self.refcount[p] > 0, f"page {p} leaked (0 refs, " \
                    f"not free)"
        assert self.free_pages + self.live_pages == self.n_pages - 1


class BlockTables:
    """(H, n_blocks) block table + the copy-on-write write discipline.

    Entry 0 means "unallocated": reads of such blocks are always masked
    by per-slot lengths, and writes from inactive slots land on the
    trash page by construction.
    """

    def __init__(self, n_slots: int, n_blocks: int, alloc: PageAllocator):
        self.alloc = alloc
        self.n_blocks = int(n_blocks)
        self.table = np.zeros((n_slots, n_blocks), np.int32)

    # ---- queries -------------------------------------------------------
    def blocks_for(self, n_positions: int) -> int:
        """Blocks needed to cover ring positions [0, n_positions)."""
        if n_positions <= 0:
            return 0
        ps = self.alloc.page_size
        return -(-min(n_positions, self.n_blocks * ps) // ps)

    def owned_pages(self, s: int) -> List[int]:
        return [int(p) for p in self.table[s] if p != TRASH_PAGE]

    # ---- mutation (all invariant-preserving) ---------------------------
    def alloc_prefix(self, s: int, n_blocks: int) -> int:
        """Allocate fresh pages for blocks [0, n_blocks) of row s (prompt
        admission). Rolls back on pool exhaustion. Returns pages taken."""
        taken: List[Tuple[int, int]] = []
        try:
            for j in range(n_blocks):
                assert self.table[s, j] == TRASH_PAGE, (s, j)
                p = self.alloc.alloc()
                self.table[s, j] = p
                taken.append((j, p))
        except OutOfPages:
            for j, p in taken:
                self.alloc.release(p)
                self.table[s, j] = TRASH_PAGE
            raise
        return len(taken)

    def fork_row(self, dst: int, src: int) -> int:
        """dst shares every allocated block of src (refcount bump, no
        copy) — GRPO prefix sharing. Returns #blocks shared."""
        n = 0
        for j in range(self.n_blocks):
            p = int(self.table[src, j])
            if p == TRASH_PAGE:
                continue
            self.alloc.share(p)
            self.table[dst, j] = p
            n += 1
        return n

    def ensure_writable(self, s: int, j: int) -> Optional[Tuple[int, int]]:
        """Make block j of row s safe to write: allocate if unallocated,
        COW if shared. Returns (src_page, dst_page) when the caller must
        copy page contents on device (COW), else None. The invariant this
        enforces: no write ever lands on a page with refcount > 1."""
        p = int(self.table[s, j])
        if p == TRASH_PAGE:
            self.table[s, j] = self.alloc.alloc()
            return None
        if self.alloc.refcount[p] > 1:
            q = self.alloc.alloc()       # may raise OutOfPages: no state
            #                              was mutated yet, caller retries
            self.alloc.refcount[p] -= 1  # >1 before, so never hits 0
            self.table[s, j] = q
            self.alloc.cow_copies += 1
            return (p, q)
        return None

    def release_row(self, s: int) -> int:
        """Free every allocated block of row s (rollout finished, slot
        preempted, or engine killed). Returns #refs dropped."""
        n = 0
        for j in range(self.n_blocks):
            p = int(self.table[s, j])
            if p == TRASH_PAGE:
                continue
            self.alloc.release(p)
            self.table[s, j] = TRASH_PAGE
            n += 1
        return n

    def check(self) -> None:
        """Cross-check table refcounts against the allocator (property
        suite): every page's refcount equals the number of table entries
        referencing it."""
        refs = np.zeros(self.alloc.n_pages, np.int64)
        vals, counts = np.unique(self.table, return_counts=True)
        refs[vals] = counts
        refs[TRASH_PAGE] = 0
        np.testing.assert_array_equal(refs, self.alloc.refcount)
        self.alloc.check()


# ---------------------------------------------------------------------------
# device side: block-table gather (default read path)
# ---------------------------------------------------------------------------

def gather_pages(pool, block_table):
    """pool: (NP, PS, ...); block_table: (B, NB) int32. Returns the
    contiguous per-slot view (B, NB*PS, ...) — logical ring position p of
    row b lives at view[b, p]. Unallocated blocks gather the trash page;
    every consumer masks those positions by per-slot length before the
    softmax, so their contents never reach an output."""
    v = jnp.take(pool, block_table, axis=0)          # (B, NB, PS, ...)
    return v.reshape((v.shape[0], v.shape[1] * v.shape[2]) + v.shape[3:])


# ---------------------------------------------------------------------------
# paged flash-decode kernel (scalar-prefetch block table)
# ---------------------------------------------------------------------------

def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         page_size: int, n_blocks: int):
    b, ki = pl.program_id(0), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip pages entirely past this row's valid length (the index map
    # already fetched the trash page for unallocated blocks; this avoids
    # paying their dots too)
    @pl.when(ki * page_size < len_ref[b])
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (rep, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (ps, d)
        v = v_ref[0, 0].astype(jnp.float32)                 # (ps, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        valid = (ki * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], page_size), 1)) < len_ref[b]
        s = jnp.where(valid, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_paged(q, k_pool, v_pool, block_tables, lengths, *,
                       scale: float, max_len_hint: int | None = None,
                       interpret: bool | None = None):
    """One-token GQA decode directly against the page pool.

    q: (B,H,Dk); pools: (NP,PS,KV,D); block_tables: (B,NB) int32;
    lengths: (B,) valid logical cache length per row. Returns (B,H,Dv).

    The block table and lengths ride in as *scalar-prefetch* operands
    (`pltpu.PrefetchScalarGridSpec`): the KV BlockSpec index maps read
    `bt_ref[b, ki]`, so each grid step DMAs exactly the physical page
    backing logical block ki of row b — the pool is never gathered into a
    contiguous copy. Online softmax runs page-by-page, i.e. with
    block_k = page_size; bitwise equal to `flash_decode` on the gathered
    view only when page_size == its block_k (same reduction order),
    fp32-close otherwise.

    max_len_hint (static, >= max(lengths)) shrinks the trailing grid axis
    to ceil(hint/PS) pages, mirroring `flash_decode`'s grid-level early
    exit.
    """
    interpret = default_interpret(interpret)
    B, H, Dk = q.shape
    NP, PS, KV, D = k_pool.shape
    Dv = v_pool.shape[-1]
    NB = block_tables.shape[1]
    rep = H // KV
    nb = NB
    if max_len_hint is not None:
        nb = max(1, min(nb, -(-int(max_len_hint) // PS)))

    qr = q.reshape(B, KV, rep, Dk)
    kr = jnp.swapaxes(k_pool, 1, 2)                    # (NP,KV,PS,D)
    vr = jnp.swapaxes(v_pool, 1, 2)
    bt = jnp.asarray(block_tables, jnp.int32)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               page_size=PS, n_blocks=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, rep, Dk),
                         lambda b, h, ki, bt_ref, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, PS, D),
                         lambda b, h, ki, bt_ref, len_ref:
                         (bt_ref[b, ki], h, 0, 0)),
            pl.BlockSpec((1, 1, PS, Dv),
                         lambda b, h, ki, bt_ref, len_ref:
                         (bt_ref[b, ki], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, rep, Dv),
            lambda b, h, ki, bt_ref, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, Dv), q.dtype),
        interpret=interpret,
    )(bt, lengths, qr, kr, vr)
    return out.reshape(B, H, Dv)
