"""Pallas TPU flash-decode: one-token GQA attention against a (possibly
ring-buffer) KV cache — the generation engine's hot loop.

TPU adaptation of vLLM's paged-attention CUDA kernel: instead of gather-
paged KV blocks, the cache is a contiguous per-slot ring buffer (static
shapes, see DESIGN.md §1) and the kernel streams KV *blocks* HBM->VMEM
along the sequential trailing grid axis with online-softmax accumulation in
VMEM scratch. Invalid slots (>= cache length) are masked, so one kernel
serves both the growing-cache and the full-ring cases.

The kernel is *length-aware* at two levels:

- **grid-level** — a static `max_len_hint` (the host-mirrored
  `max(lengths)` over the batch, rounded up to `block_k`) shrinks the
  trailing grid axis itself, so blocks beyond the hint are never fetched
  from HBM at all (the `pl.when` variant still paid the DMA);
- **block-level** — the per-slot valid length lives in SMEM and KV blocks
  entirely beyond it skip the QK^T / PV dots via `pl.when` — in a
  continuous-batching engine most slots are far from the cache capacity,
  so the common case touches only `ceil(len/block_k)` blocks' worth of
  MXU work instead of `CL/block_k`.

grid = (batch, kv_heads, n_kv_blocks); all `rep` q-heads of a kv head are
processed together as a (rep, d) tile — MXU-friendly and it amortizes the
KV block fetch exactly like GQA intends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import MEMSPACE as _MEMSPACE, default_interpret

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int, n_kv_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # length-aware skip: blocks whose first slot is already past this
    # sequence's valid length contribute nothing — don't issue the dots
    @pl.when(ki * block_k < len_ref[0])
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (rep, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                 # (bk, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        valid = (ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], block_k), 1)) < len_ref[0]
        s = jnp.where(valid, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, lengths, *, scale: float,
                 block_k: int = 256, max_len_hint: int | None = None,
                 interpret: bool | None = None):
    """q: (B,H,Dk); caches: (B,CL,KV,D); lengths: (B,) valid cache length
    per slot (pass CL for a full ring buffer). Returns (B,H,Dv).

    max_len_hint: optional *static* upper bound on max(lengths) — the grid's
    trailing KV axis shrinks to ceil(hint/block_k) blocks, so cache blocks
    beyond the hint are never even fetched. The caller must guarantee
    hint >= max(lengths) (the generation engine derives it from its host
    length mirrors, rounded up to block_k so jit sees few distinct values);
    a violation silently truncates attention. None keeps the full grid.

    interpret=None resolves to interpret mode off-TPU and compiled mode on
    TPU (callers may force either; see kernels.ops for the jitted wrapper).
    """
    interpret = default_interpret(interpret)
    B, H, Dk = q.shape
    CL, KV = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    rep = H // KV
    block_k = min(block_k, CL)
    assert CL % block_k == 0, (CL, block_k)
    nk = CL // block_k
    if max_len_hint is not None:
        nk = max(1, min(nk, -(-int(max_len_hint) // block_k)))

    qr = q.reshape(B, KV, rep, Dk)
    kr = jnp.swapaxes(k_cache, 1, 2)                    # (B,KV,CL,D)
    vr = jnp.swapaxes(v_cache, 1, 2)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,),
                         memory_space=_MEMSPACE.SMEM),
            pl.BlockSpec((1, 1, rep, Dk), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, Dk), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dv), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, Dv), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qr, kr, vr)
    return out.reshape(B, H, Dv)
