"""Pallas TPU flash attention (prefill/train) with GQA and causal masking.

TPU adaptation of the paper's vLLM/CUDA attention path: online-softmax over
KV blocks with the running (m, l, acc) statistics held in VMEM scratch that
persists across the sequential trailing grid axis. Block shapes are
MXU-aligned (128) and sized so the working set (q tile + k tile + v tile +
acc) fits v5e VMEM (~128 KiB * 128 lanes).

grid = (batch, q_heads, n_q_blocks, n_kv_blocks); the kv axis is innermost
(sequential on TPU), so scratch carries the accumulation; the causal upper
triangle is skipped with pl.when (real savings on TPU, structural no-op in
interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import default_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, block_q: int, block_k: int,
                  n_kv_blocks: int, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked blocks above the diagonal
        pl.when(qi * block_q + block_q - 1 >= ki * block_k)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B,H,S,Dk); k,v: (B,KV,S,Dk/Dv) — GQA folded via h // rep.
    Returns (B,H,S,Dv). interpret=None: interpret off-TPU, compiled on TPU."""
    interpret = default_interpret(interpret)
    B, H, S, Dk = q.shape
    KV, Dv = k.shape[1], v.shape[-1]
    rep = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=nk, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dk), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, Dk), lambda b, h, qi, ki, _r=rep: (b, h // _r, ki, 0)),
            pl.BlockSpec((1, 1, block_k, Dv), lambda b, h, qi, ki, _r=rep: (b, h // _r, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, Dv), jnp.float32),  # running numerator
        ],
        interpret=interpret,
    )(q, k, v)
