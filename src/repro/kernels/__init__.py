"""Pallas TPU kernels for the generation hot paths (DESIGN.md §5):

- `flash_attention`   — full-sequence causal GQA (train / whole-prompt)
- `flash_decode`      — one-token decode vs the (ring) slot cache
- `prefill_attention` — chunked-prefill: a prompt chunk vs cache + itself
- `ssd_scan`          — Mamba2 SSD chunked scan
- `fused_logprob`     — trainer lm-head + cross-entropy, logits-free

Call through the jit'd wrappers in `kernels.ops`; pure-jnp oracles live in
`kernels.ref`. Off-TPU the kernels run in interpret mode (see
`kernels.common.default_interpret`).
"""
