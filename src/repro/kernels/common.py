"""Shared helpers for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.experimental.pallas.tpu as pltpu

# MemorySpace was named TPUMemorySpace before jax 0.5
MEMSPACE = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace


def default_interpret(interpret: bool | None) -> bool:
    """Resolve the interpret flag: None = interpret mode off-TPU (kernel
    bodies execute in Python for correctness validation), compiled on TPU."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
