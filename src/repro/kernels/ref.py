"""Pure-jnp oracles for every Pallas kernel (the blocked/naive model-zoo
implementations double as references; re-exported here with the kernels'
calling conventions)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import (
    _naive_causal_attention,
    chunk_attention as _chunk_ref,
    decode_attention as _decode_ref,
)
from repro.models.ssm import ssd_chunked


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True):
    """q: (B,H,S,Dk); k,v: (B,KV,S,D). Matches kernels.flash_attention."""
    qb = jnp.swapaxes(q, 1, 2)      # (B,S,H,D)
    kb = jnp.swapaxes(k, 1, 2)
    vb = jnp.swapaxes(v, 1, 2)
    if not causal:
        raise NotImplementedError("reference is causal-only")
    out = _naive_causal_attention(qb, kb, vb, scale=scale)
    return jnp.swapaxes(out, 1, 2)


def flash_decode_ref(q, k_cache, v_cache, lengths, *, scale: float):
    """Matches kernels.flash_decode (lengths == CL means full ring)."""
    return _decode_ref(q, k_cache, v_cache, jnp.asarray(lengths),
                       scale=scale, ring=False)


def prefill_attention_ref(q, k_chunk, v_chunk, k_cache, v_cache, offset, *,
                          scale: float):
    """Matches kernels.prefill_attention (two-source chunk-vs-cache
    attention with ring addressing; caches in their pre-chunk state)."""
    return _chunk_ref(q, k_chunk, v_chunk, k_cache, v_cache,
                      jnp.asarray(offset, jnp.int32), scale=scale)


def fused_logprob_ref(hidden, head, targets, *, transpose_head: bool = False):
    """Matches kernels.fused_logprob (blockwise linear-cross-entropy), via
    the straightforward full-logits computation — the equivalence oracle
    for value *and* gradient, and the model layer's jnp fallback when the
    Pallas path is off. hidden: (N,D); head: (D,V) or (V,D) with
    transpose_head; targets: (N,) int32. Returns (logprob, lse, entropy),
    each (N,) f32. f32 accumulation like the kernel (the unfused model
    path materializes logits in *model dtype*, so bf16 runs agree with
    this twin more tightly than with that path)."""
    import jax

    eq = "nd,vd->nv" if transpose_head else "nd,dv->nv"
    logits = jnp.einsum(eq, hidden, head,
                        preferred_element_type=jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt_l = jnp.take_along_axis(logits, targets[:, None].astype(jnp.int32),
                                axis=-1)[:, 0]
    p = jnp.exp(logits - lse[:, None])
    entropy = lse - jnp.sum(p * logits, axis=-1)
    return tgt_l - lse, lse, entropy


def ssd_scan_ref(x, dt, A, B, C, *, chunk: int = 64):
    """Matches kernels.ssd_scan: returns (y, final_state (b,h,n,p))."""
    y, state = ssd_chunked(x, dt, A, B, C, chunk)
    return y, jnp.swapaxes(state, -1, -2)
