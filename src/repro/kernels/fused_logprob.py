"""Pallas TPU fused linear-cross-entropy: the trainer's lm-head hot path.

The textbook LM loss materializes the full ``(B, S, V)`` logits tensor in
model dtype, then a *second* f32 copy for ``log_softmax`` — at llama3-8B /
128k-vocab scale those two tensors dominate trainer activation memory and
the logits *gradient* (a third ``(B, S, V)`` tensor) dominates backward
HBM traffic. This kernel fuses the lm-head matmul with the cross-entropy
reduction: hidden states ``(N, D)`` and the head matrix stream through the
MXU in vocab *blocks* with an online-logsumexp recurrence, producing only
per-token scalars — the sampled token's logprob, the logsumexp, and the
full-distribution entropy. No logits tensor ever exists.

Forward recurrence per ``(row-block, vocab-block)`` grid step (all f32 in
VMEM scratch, persisting across the sequential trailing vocab axis):

    l       = h @ W[:, v0:v0+bv]                  # (bn, bv) block logits
    m'      = max(m, max_v l)                     # running max
    corr    = exp(m - m')
    s       = s * corr + sum_v exp(l - m')        # running sumexp
    a       = a * corr + sum_v exp(l - m') * l    # entropy numerator
    t      += sum_v 1[v == target] * l            # target logit gather

and at the last vocab block ``lse = m + log s``, ``logprob = t - lse``,
``entropy = lse - a / s`` (since ``H = lse - E_p[l]``).

The custom VJP never materializes the logits gradient either: with row
coefficients ``c0 = g_lse - g_lp + g_ent * (lse - H)`` the per-block
gradient is

    dl = g_lp * 1[v == target] + p * (c0 - g_ent * l),   p = exp(l - lse)

recomputed on the fly from the saved ``lse`` (softmax recompute — the same
trick flash-attention backward uses). Two passes: ``dhidden`` accumulates
``dl @ W_blk^T`` over vocab blocks (vocab trailing/sequential), ``dhead``
accumulates ``h^T @ dl`` over row blocks (rows trailing/sequential), so
each output tile owns exactly one sequential reduction axis. Gradients
flow to both the hidden states and the head weights; f32 accumulation
throughout.

``transpose_head=True`` reads the head as ``(V, D)`` — the tied-embedding
layout — so tied models pass ``params["embed"]`` directly and no
transposed ``(D, V)`` copy is ever materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import default_interpret

NEG_INF = -1e30


def _dot(a, b, dims):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=jnp.float32)


def _block_logits(h_ref, w_ref, transpose_head: bool):
    """(bn, bv) f32 logits of this vocab block."""
    h = h_ref[...]
    w = w_ref[...]
    if transpose_head:                       # w: (bv, D)
        return _dot(h, w, ((1,), (1,)))
    return _dot(h, w, ((1,), (0,)))          # w: (D, bv)


def _col_ids(vi, block_n: int, block_v: int):
    return vi * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, block_v), 1)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(tgt_ref, h_ref, w_ref, lp_ref, lse_ref, ent_ref,
                m_ref, s_ref, a_ref, t_ref, *, block_n: int, block_v: int,
                n_v_blocks: int, vocab: int, transpose_head: bool):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        a_ref[...] = jnp.zeros_like(a_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    l = _block_logits(h_ref, w_ref, transpose_head)
    col = _col_ids(vi, block_n, block_v)
    l = jnp.where(col < vocab, l, NEG_INF)   # pad columns never contribute

    m_prev, s_prev = m_ref[...], s_ref[...]
    m_new = jnp.maximum(m_prev, l.max(axis=-1, keepdims=True))
    p = jnp.exp(l - m_new)
    corr = jnp.exp(m_prev - m_new)
    s_ref[...] = s_prev * corr + p.sum(axis=-1, keepdims=True)
    # entropy numerator: sum exp(l - m) * l; masked cols give exp -> 0
    a_ref[...] = a_ref[...] * corr + (p * l).sum(axis=-1, keepdims=True)
    onehot = col == tgt_ref[...]             # tgt: (bn, 1) broadcasts
    t_ref[...] += jnp.where(onehot, l, 0.0).sum(axis=-1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(vi == n_v_blocks - 1)
    def _finalize():
        s = jnp.maximum(s_ref[...], 1e-30)
        lse = m_ref[...] + jnp.log(s)
        lse_ref[...] = lse
        lp_ref[...] = t_ref[...] - lse
        ent_ref[...] = lse - a_ref[...] / s


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _block_dlogits(tgt_ref, lse_ref, c0_ref, glp_ref, gent_ref, h_ref, w_ref,
                   vi, *, block_n: int, block_v: int, vocab: int,
                   transpose_head: bool):
    """Recompute the (bn, bv) logits-gradient block from saved row stats."""
    l = _block_logits(h_ref, w_ref, transpose_head)
    col = _col_ids(vi, block_n, block_v)
    p = jnp.exp(l - lse_ref[...])
    onehot = col == tgt_ref[...]
    dl = glp_ref[...] * onehot.astype(jnp.float32) \
        + p * (c0_ref[...] - gent_ref[...] * l)
    return jnp.where(col < vocab, dl, 0.0)


def _bwd_dh_kernel(tgt_ref, lse_ref, c0_ref, glp_ref, gent_ref, h_ref, w_ref,
                   dh_ref, acc_ref, *, block_n: int, block_v: int,
                   n_v_blocks: int, vocab: int, transpose_head: bool):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dl = _block_dlogits(tgt_ref, lse_ref, c0_ref, glp_ref, gent_ref, h_ref,
                        w_ref, vi, block_n=block_n, block_v=block_v,
                        vocab=vocab, transpose_head=transpose_head)
    w = w_ref[...]
    if transpose_head:                       # (bn, bv) @ (bv, D)
        acc_ref[...] += _dot(dl, w, ((1,), (0,)))
    else:                                    # (bn, bv) x (D, bv) -> (bn, D)
        acc_ref[...] += _dot(dl, w, ((1,), (1,)))

    @pl.when(vi == n_v_blocks - 1)
    def _finalize():
        dh_ref[...] = acc_ref[...].astype(dh_ref.dtype)


def _bwd_dw_kernel(tgt_ref, lse_ref, c0_ref, glp_ref, gent_ref, h_ref, w_ref,
                   dw_ref, acc_ref, *, block_n: int, block_v: int,
                   n_n_blocks: int, vocab: int, transpose_head: bool):
    vi, ni = pl.program_id(0), pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dl = _block_dlogits(tgt_ref, lse_ref, c0_ref, glp_ref, gent_ref, h_ref,
                        w_ref, vi, block_n=block_n, block_v=block_v,
                        vocab=vocab, transpose_head=transpose_head)
    h = h_ref[...]
    if transpose_head:                       # dl^T @ h -> (bv, D)
        acc_ref[...] += _dot(dl, h, ((0,), (0,)))
    else:                                    # h^T @ dl -> (D, bv)
        acc_ref[...] += _dot(h, dl, ((0,), (0,)))

    @pl.when(ni == n_n_blocks - 1)
    def _finalize():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def _bwd_dw_chunk_kernel(tgt_ref, lse_ref, c0_ref, glp_ref, gent_ref, h_ref,
                         w_ref, dw_ref, acc_ref, *, block_n: int, block_v: int,
                         n_n_blocks: int, rows_per_chunk: int, vocab: int,
                         transpose_head: bool):
    """Two-level dhead reduction (level 1): the sequential rows axis is cut
    into chunks of `rows_per_chunk` row blocks; the VMEM accumulator resets
    at each chunk boundary and flushes a per-chunk f32 partial to its own
    slice of the (n_chunks, ...) output. Level 2 — summing the partials —
    happens outside the kernel as an ordinary tree reduction, so at very
    large N the hidden re-read per vocab block stops being one monolithic
    length-n_n_blocks dependency chain."""
    vi, ni = pl.program_id(0), pl.program_id(1)

    @pl.when(ni % rows_per_chunk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dl = _block_dlogits(tgt_ref, lse_ref, c0_ref, glp_ref, gent_ref, h_ref,
                        w_ref, vi, block_n=block_n, block_v=block_v,
                        vocab=vocab, transpose_head=transpose_head)
    h = h_ref[...]
    if transpose_head:                       # dl^T @ h -> (bv, D)
        acc_ref[...] += _dot(dl, h, ((0,), (0,)))
    else:                                    # h^T @ dl -> (D, bv)
        acc_ref[...] += _dot(h, dl, ((0,), (0,)))

    last_of_chunk = (ni % rows_per_chunk) == rows_per_chunk - 1

    @pl.when((ni == n_n_blocks - 1) | last_of_chunk)
    def _flush():
        dw_ref[...] = acc_ref[...][None]


# ---------------------------------------------------------------------------
# pallas_call plumbing
# ---------------------------------------------------------------------------

def _pad_axis(x, axis: int, to: int):
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _geometry(N: int, D: int, V: int, block_n: int, block_v: int):
    """Static launch geometry. block_n is shrunk to divide the (padded) row
    count; the vocab axis is padded up to a block multiple and masked by
    the true V in-kernel (odd V % block remainders)."""
    bn = max(1, min(block_n, N))
    while N % bn:
        bn -= 1
    bv = max(1, min(block_v, V))
    Vp = -(-V // bv) * bv
    return bn, bv, N // bn, Vp // bv, Vp


def _row_specs(bn):
    """BlockSpecs for the per-row (N, 1) scalar inputs."""
    return pl.BlockSpec((bn, 1), lambda ni, vi: (ni, 0))


def _w_spec(bv, D, transpose_head, flip=False):
    """Head-matrix BlockSpec; flip swaps the (ni, vi) grid-arg order for
    the dhead kernel whose grid is (vocab, rows)."""
    if transpose_head:
        if flip:
            return pl.BlockSpec((bv, D), lambda vi, ni: (vi, 0))
        return pl.BlockSpec((bv, D), lambda ni, vi: (vi, 0))
    if flip:
        return pl.BlockSpec((D, bv), lambda vi, ni: (0, vi))
    return pl.BlockSpec((D, bv), lambda ni, vi: (0, vi))


def _fused_fwd_call(hidden, head, targets, block_n, block_v, transpose_head,
                    interpret):
    N, D = hidden.shape
    V = head.shape[0] if transpose_head else head.shape[1]
    bn, bv, n_n, n_v, Vp = _geometry(N, D, V, block_n, block_v)
    head = _pad_axis(head, 0 if transpose_head else 1, Vp)
    tgt = targets.reshape(N, 1).astype(jnp.int32)

    kernel = functools.partial(
        _fwd_kernel, block_n=bn, block_v=bv, n_v_blocks=n_v, vocab=V,
        transpose_head=transpose_head)
    out = pl.pallas_call(
        kernel,
        grid=(n_n, n_v),
        in_specs=[
            _row_specs(bn),
            pl.BlockSpec((bn, D), lambda ni, vi: (ni, 0)),
            _w_spec(bv, D, transpose_head),
        ],
        out_specs=[_row_specs(bn)] * 3,
        out_shape=[jax.ShapeDtypeStruct((N, 1), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.float32)] * 4,
        interpret=interpret,
    )(tgt, hidden, head)
    lp, lse, ent = (o[:, 0] for o in out)
    return lp, lse, ent


def _fused_bwd_call(hidden, head, targets, lse, c0, g_lp, g_ent,
                    block_n, block_v, transpose_head, interpret,
                    dw_chunks=1):
    N, D = hidden.shape
    V = head.shape[0] if transpose_head else head.shape[1]
    bn, bv, n_n, n_v, Vp = _geometry(N, D, V, block_n, block_v)
    head_p = _pad_axis(head, 0 if transpose_head else 1, Vp)
    rows = [targets.reshape(N, 1).astype(jnp.int32),
            lse.reshape(N, 1), c0.reshape(N, 1),
            g_lp.reshape(N, 1), g_ent.reshape(N, 1)]

    dh = pl.pallas_call(
        functools.partial(_bwd_dh_kernel, block_n=bn, block_v=bv,
                          n_v_blocks=n_v, vocab=V,
                          transpose_head=transpose_head),
        grid=(n_n, n_v),
        in_specs=[_row_specs(bn)] * 5 + [
            pl.BlockSpec((bn, D), lambda ni, vi: (ni, 0)),
            _w_spec(bv, D, transpose_head),
        ],
        out_specs=pl.BlockSpec((bn, D), lambda ni, vi: (ni, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), hidden.dtype),
        scratch_shapes=[pltpu.VMEM((bn, D), jnp.float32)],
        interpret=interpret,
    )(*rows, hidden, head_p)

    dw_shape = (Vp, D) if transpose_head else (D, Vp)
    dw_block = (bv, D) if transpose_head else (D, bv)
    if dw_chunks > 1 and n_n > 1:
        # two-level reduction: per-row-chunk f32 partials + tree sum
        rpc = -(-n_n // dw_chunks)           # row blocks per chunk
        n_chunks = -(-n_n // rpc)
        if transpose_head:
            out_spec = pl.BlockSpec((1,) + dw_block,
                                    lambda vi, ni: (ni // rpc, vi, 0))
        else:
            out_spec = pl.BlockSpec((1,) + dw_block,
                                    lambda vi, ni: (ni // rpc, 0, vi))
        dw_part = pl.pallas_call(
            functools.partial(_bwd_dw_chunk_kernel, block_n=bn, block_v=bv,
                              n_n_blocks=n_n, rows_per_chunk=rpc, vocab=V,
                              transpose_head=transpose_head),
            grid=(n_v, n_n),                 # rows trailing: dw accumulates
            in_specs=[pl.BlockSpec((bn, 1), lambda vi, ni: (ni, 0))] * 5 + [
                pl.BlockSpec((bn, D), lambda vi, ni: (ni, 0)),
                _w_spec(bv, D, transpose_head, flip=True),
            ],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((n_chunks,) + dw_shape,
                                           jnp.float32),
            scratch_shapes=[pltpu.VMEM(dw_block, jnp.float32)],
            interpret=interpret,
        )(*rows, hidden, head_p)
        dw = dw_part.sum(axis=0).astype(head.dtype)
    else:
        dw = pl.pallas_call(
            functools.partial(_bwd_dw_kernel, block_n=bn, block_v=bv,
                              n_n_blocks=n_n, vocab=V,
                              transpose_head=transpose_head),
            grid=(n_v, n_n),                 # rows trailing: dw accumulates
            in_specs=[pl.BlockSpec((bn, 1), lambda vi, ni: (ni, 0))] * 5 + [
                pl.BlockSpec((bn, D), lambda vi, ni: (ni, 0)),
                _w_spec(bv, D, transpose_head, flip=True),
            ],
            out_specs=pl.BlockSpec(
                dw_block, (lambda vi, ni: (vi, 0)) if transpose_head
                else (lambda vi, ni: (0, vi))),
            out_shape=jax.ShapeDtypeStruct(dw_shape, head.dtype),
            scratch_shapes=[pltpu.VMEM(dw_block, jnp.float32)],
            interpret=interpret,
        )(*rows, hidden, head_p)
    if Vp != V:
        dw = dw[:V] if transpose_head else dw[:, :V]
    return dh, dw


# ---------------------------------------------------------------------------
# custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused(static, hidden, head, targets):
    block_n, block_v, transpose_head, interpret, _ = static
    return _fused_fwd_call(hidden, head, targets, block_n, block_v,
                           transpose_head, interpret)


def _fused_fwd(static, hidden, head, targets):
    block_n, block_v, transpose_head, interpret, _ = static
    out = _fused_fwd_call(hidden, head, targets, block_n, block_v,
                          transpose_head, interpret)
    lp, lse, ent = out
    return out, (hidden, head, targets, lse, ent)


def _fused_bwd(static, res, cts):
    block_n, block_v, transpose_head, interpret, dw_chunks = static
    hidden, head, targets, lse, ent = res
    g_lp, g_lse, g_ent = (g.astype(jnp.float32) for g in cts)
    # dl = g_lp * 1[v==t] + p * (c0 - g_ent * l), c0 = g_lse - g_lp
    #    + g_ent * (lse - H)  — see module docstring for the derivation
    c0 = g_lse - g_lp + g_ent * (lse - ent)
    dh, dw = _fused_bwd_call(hidden, head, targets, lse, c0, g_lp, g_ent,
                             block_n, block_v, transpose_head, interpret,
                             dw_chunks=dw_chunks)
    d_tgt = np.zeros(targets.shape, jax.dtypes.float0)
    return dh, dw, d_tgt


_fused.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# blocked jnp twin (compiled fallback — the `blocked_causal_attention` of
# the fused loss): same vocab tiling, online-LSE recurrence and custom-VJP
# recompute as the Pallas kernel, expressed as a lax.scan so XLA compiles
# it on any backend. The model layer uses it when `use_pallas` is off —
# unlike the full-logits oracle in kernels/ref.py it, too, never
# materializes the (N, V) logits or their gradient.
# ---------------------------------------------------------------------------

def _blocked_logits(hidden, head_p, i, bv, transpose_head):
    if transpose_head:
        wb = jax.lax.dynamic_slice_in_dim(head_p, i * bv, bv, axis=0)
        return wb, _dot(hidden, wb, ((1,), (1,)))
    wb = jax.lax.dynamic_slice_in_dim(head_p, i * bv, bv, axis=1)
    return wb, _dot(hidden, wb, ((1,), (0,)))


def _blocked_geometry(head, block_v, transpose_head):
    V = head.shape[0] if transpose_head else head.shape[1]
    bv = max(1, min(block_v, V))
    Vp = -(-V // bv) * bv
    head_p = _pad_axis(head, 0 if transpose_head else 1, Vp)
    return V, bv, Vp // bv, head_p


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _blocked(static, hidden, head, targets):
    block_v, transpose_head = static
    N = hidden.shape[0]
    V, bv, nv, head_p = _blocked_geometry(head, block_v, transpose_head)
    tgt = targets.astype(jnp.int32)

    def body(carry, i):
        m, s, a, tl = carry
        _, l = _blocked_logits(hidden, head_p, i, bv, transpose_head)
        col = i * bv + jnp.arange(bv)
        l = jnp.where(col[None] < V, l, NEG_INF)
        m2 = jnp.maximum(m, l.max(axis=-1))
        p = jnp.exp(l - m2[:, None])
        corr = jnp.exp(m - m2)
        s = s * corr + p.sum(axis=-1)
        a = a * corr + (p * l).sum(axis=-1)
        tl = tl + jnp.where(col[None] == tgt[:, None], l, 0.0).sum(axis=-1)
        return (m2, s, a, tl), None

    init = (jnp.full((N,), NEG_INF, jnp.float32), jnp.zeros((N,)),
            jnp.zeros((N,)), jnp.zeros((N,)))
    (m, s, a, tl), _ = jax.lax.scan(body, init, jnp.arange(nv))
    s = jnp.maximum(s, 1e-30)
    lse = m + jnp.log(s)
    return tl - lse, lse, lse - a / s


def _blocked_fwd(static, hidden, head, targets):
    out = _blocked(static, hidden, head, targets)
    lp, lse, ent = out
    return out, (hidden, head, targets, lse, ent)


def _blocked_bwd(static, res, cts):
    block_v, transpose_head = static
    hidden, head, targets, lse, ent = res
    g_lp, g_lse, g_ent = (g.astype(jnp.float32) for g in cts)
    c0 = g_lse - g_lp + g_ent * (lse - ent)
    N, D = hidden.shape
    V, bv, nv, head_p = _blocked_geometry(head, block_v, transpose_head)
    tgt = targets.astype(jnp.int32)

    def body(dh, i):
        wb, l = _blocked_logits(hidden, head_p, i, bv, transpose_head)
        col = i * bv + jnp.arange(bv)
        p = jnp.exp(l - lse[:, None])
        dl = g_lp[:, None] * (col[None] == tgt[:, None]).astype(jnp.float32) \
            + p * (c0[:, None] - g_ent[:, None] * l)
        dl = jnp.where(col[None] < V, dl, 0.0)
        if transpose_head:           # wb: (bv, D); dw block: (bv, D)
            dwb = _dot(dl, hidden, ((0,), (0,)))
            dh = dh + _dot(dl, wb, ((1,), (0,)))
        else:                        # wb: (D, bv); dw block: (D, bv)
            dwb = _dot(hidden, dl, ((0,), (0,)))
            dh = dh + _dot(dl, wb, ((1,), (1,)))
        return dh, dwb

    dh, dwbs = jax.lax.scan(body, jnp.zeros((N, D)), jnp.arange(nv))
    if transpose_head:               # (nv, bv, D) -> (Vp, D)
        dw = dwbs.reshape(nv * bv, D)[:V]
    else:                            # (nv, D, bv) -> (D, Vp)
        dw = jnp.moveaxis(dwbs, 0, 1).reshape(D, nv * bv)[:, :V]
    d_tgt = np.zeros(targets.shape, jax.dtypes.float0)
    return dh.astype(hidden.dtype), dw.astype(head.dtype), d_tgt


_blocked.defvjp(_blocked_fwd, _blocked_bwd)


def fused_logprob_blocked(hidden, head, targets, *,
                          transpose_head: bool = False, block_v: int = 512):
    """Compiled blockwise linear-cross-entropy — the jnp twin of
    `fused_logprob` (same tiling, online-LSE and VJP-recompute math,
    expressed as a lax.scan). Used by the model layer when the Pallas path
    is off; also never materializes the logits or their gradient."""
    assert hidden.ndim == 2 and head.ndim == 2 and targets.ndim == 1
    return _blocked((int(block_v), bool(transpose_head)),
                    hidden, head, targets)


def fused_logprob(hidden, head, targets, *, transpose_head: bool = False,
                  block_n: int = 128, block_v: int = 512,
                  interpret: bool | None = None, dw_chunks: int = 1):
    """Blockwise linear-cross-entropy over the lm head.

    hidden: (N, D) final hidden states (post final-norm); head: (D, V), or
    (V, D) with ``transpose_head=True`` (tied-embedding layout — pass the
    embedding matrix directly, no transposed copy); targets: (N,) int32
    sampled-token ids. Returns ``(logprob, lse, entropy)``, each (N,) f32:
    the target token's logprob, the logsumexp, and the full-distribution
    entropy per row. Differentiable w.r.t. hidden and head via a custom
    VJP that re-derives each vocab block's softmax from the saved ``lse``
    — neither the logits nor their gradient are ever materialized.

    dw_chunks > 1 splits the backward dhead reduction over the rows axis
    into that many per-chunk f32 partials summed outside the kernel (a
    two-level tree reduction): at very large N the single sequential
    accumulation chain per vocab tile stops gating the re-read of hidden.
    The default (1) keeps the original single-pass accumulator.

    Memory: activations are O(N) scalars + one (bn, D) tile per grid step,
    vs O(N·V) logits (twice: model dtype + f32) for the unfused path.
    """
    interpret = default_interpret(interpret)
    assert hidden.ndim == 2 and head.ndim == 2 and targets.ndim == 1
    return _fused((int(block_n), int(block_v), bool(transpose_head),
                   bool(interpret), int(dw_chunks)), hidden, head, targets)


# ---------------------------------------------------------------------------
# vocab-sharded wrapper (the p_vocab -> "model" mesh axis, DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# Each shard owns a contiguous V/n slice of the head and runs the ordinary
# fused kernel on it, producing *local* (lp_i, lse_i, ent_i). The global
# stats are exact functions of those three scalars per row:
#
#     lse = m + log sum_i exp(lse_i - m),      m = max_i lse_i
#     sum_{v in shard i} exp(l_v) * l_v = exp(lse_i) * (lse_i - ent_i)
#       => entropy = lse - sum_i exp(lse_i - lse) * (lse_i - ent_i)
#     target logit = sum_i owned_i * (lp_i + lse_i)   (one owner per row)
#       => logprob = target logit - lse
#
# so one psum over "model" of three (N,)-vectors replaces any (N, V)
# traffic — the no-materialization property now holds *per shard*, and the
# combine is plain differentiable jnp, so the custom VJP of the local call
# stays intact and grads flow to the local head slice only.

def vocab_shard_count(mesh, axis_name: str, vocab: int) -> int:
    """Usable vocab shards: the size of `axis_name` on `mesh` when it
    exists and divides `vocab`, else 1 (caller falls back to the
    replicated path — the same divisibility-drop contract as
    `sharding.logical_to_spec`)."""
    if mesh is None or axis_name not in mesh.shape:
        return 1
    n = int(mesh.shape[axis_name])
    return n if n > 1 and vocab % n == 0 else 1


def fused_logprob_sharded(hidden, head, targets, *, mesh=None,
                          axis_name: str = "model",
                          transpose_head: bool = False,
                          use_pallas: bool = True,
                          block_n: int | None = None,
                          block_v: int | None = None,
                          interpret: bool | None = None,
                          dw_chunks: int = 1):
    """`fused_logprob` sharded over the vocab axis of `mesh`.

    hidden (N, D) and targets (N,) enter replicated; the head enters split
    along its vocab dimension over `axis_name` (rows when transpose_head,
    columns otherwise — exactly how `sharding.DEFAULT_RULES` places
    `p_vocab`/`p_embed_vocab`). Each shard runs the single-device fused
    path (Pallas kernel or the blocked jnp twin per `use_pallas`) on its
    V/n slice with targets clipped into the slice; the cross-shard combine
    is three psums over (N,) vectors (see header comment). Falls back to
    the unsharded call when `mesh` is None, the axis is absent/size-1, or
    V does not divide — so callers can route unconditionally.

    Value and grads match the single-device path to fp32 tolerance (the
    shard cut only reassociates the vocab reduction, like a different
    block_v would)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    interpret = default_interpret(interpret)
    if block_n is None:
        block_n = 256 if interpret else 128
    if block_v is None:
        block_v = 2048 if interpret else 512
    assert hidden.ndim == 2 and head.ndim == 2 and targets.ndim == 1
    V = head.shape[0] if transpose_head else head.shape[1]
    n = vocab_shard_count(mesh, axis_name, V)
    if n <= 1:
        if use_pallas:
            return fused_logprob(hidden, head, targets,
                                 transpose_head=transpose_head,
                                 block_n=block_n, block_v=block_v,
                                 interpret=interpret, dw_chunks=dw_chunks)
        return fused_logprob_blocked(hidden, head, targets,
                                     transpose_head=transpose_head,
                                     block_v=block_v)

    v_local = V // n

    def shard_fn(h, w, t):
        off = jax.lax.axis_index(axis_name).astype(jnp.int32) * v_local
        t_local = jnp.clip(t.astype(jnp.int32) - off, 0, v_local - 1)
        if use_pallas:
            lp_i, lse_i, ent_i = _fused(
                (int(block_n), int(block_v), bool(transpose_head),
                 bool(interpret), int(dw_chunks)), h, w, t_local)
        else:
            lp_i, lse_i, ent_i = _blocked(
                (int(block_v), bool(transpose_head)), h, w, t_local)
        owned = (t >= off) & (t < off + v_local)
        t_logit = lp_i + lse_i               # local logit of the clipped id
        # stable max of the shard lse's; pmax has no autodiff rule, so the
        # max rides an all_gather of the stopped values (m is a constant —
        # any shared offset gives the same lse, see the log-sum-exp form)
        m = jax.lax.all_gather(
            jax.lax.stop_gradient(lse_i), axis_name).max(axis=0)
        lse = m + jnp.log(jax.lax.psum(jnp.exp(lse_i - m), axis_name))
        ent = lse - jax.lax.psum(
            jnp.exp(lse_i - lse) * (lse_i - ent_i), axis_name)
        # non-owner rows gathered a clipped (wrong) id: the where() both
        # drops their contribution and zeroes their cotangent into lp_i
        tgt = jax.lax.psum(jnp.where(owned, t_logit, 0.0), axis_name)
        return tgt - lse, lse, ent

    w_spec = P(axis_name, None) if transpose_head else P(None, axis_name)
    return shard_map(shard_fn, mesh=mesh,
                     in_specs=(P(), w_spec, P()),
                     out_specs=(P(), P(), P()),
                     check_rep=False)(hidden, head, targets)
