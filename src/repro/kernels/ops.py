"""jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True off-TPU (kernel bodies execute in Python on
CPU for correctness validation) and False on real TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.decode_attention import flash_decode as _flash_decode
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan




@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    interpret = default_interpret(interpret)
    return _flash_attention(q, k, v, scale=scale, causal=causal,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, scale: float,
                 block_k: int = 256, interpret: bool | None = None):
    interpret = default_interpret(interpret)
    return _flash_decode(q, k_cache, v_cache, lengths, scale=scale,
                         block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 64,
             interpret: bool | None = None):
    interpret = default_interpret(interpret)
    return _ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
