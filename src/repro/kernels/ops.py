"""jit'd public wrappers for the Pallas kernels — the only entry points the
model layer calls (DESIGN.md §5 "Kernel catalog" documents each kernel's
grid/block layout, masking rules, and early-exit behavior).

`interpret` defaults to True off-TPU (kernel bodies execute in Python on
CPU for correctness validation) and False on real TPU backends; the model
threads `ModelConfig.pallas_interpret` (set from `EngineConfig.interpret`
by the generation engine) into every call so TPU runs never hit an
interpret-mode kernel by accident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.decode_attention import flash_decode as _flash_decode
from repro.kernels.paged_cache import flash_decode_paged as _flash_decode_paged
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.fused_logprob import fused_logprob as _fused_logprob
from repro.kernels.prefill_attention import (
    prefill_attention as _prefill_attention,
)
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


@functools.partial(jax.jit, static_argnames=("scale", "causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Full-sequence (train / whole-prompt prefill) flash attention.

    q: (B,H,S,Dk); k,v: (B,KV,S,Dk/Dv) with GQA folded via H = KV*rep.
    Returns (B,H,S,Dv). Online-softmax over KV blocks; causal=True skips
    fully-masked blocks above the diagonal. S must divide by both block
    sizes (the model layer falls back to the jnp blocked path otherwise).
    """
    interpret = default_interpret(interpret)
    return _flash_attention(q, k, v, scale=scale, causal=causal,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "block_k",
                                             "max_len_hint", "interpret"))
def flash_decode(q, k_cache, v_cache, lengths, *, scale: float,
                 block_k: int = 256, max_len_hint: int | None = None,
                 interpret: bool | None = None):
    """One-token decode attention against the (possibly ring-buffer) slot
    cache — the generation engine's per-step hot loop.

    q: (B,H,Dk); caches: (B,CL,KV,D); lengths: (B,) count of valid cache
    slots per sequence (CL for a warm ring buffer). Slots >= lengths[b]
    are masked, so the positional-validity invariant of DESIGN.md §1 holds
    without ever zeroing retired slots. max_len_hint (static, must be
    >= max(lengths)) shrinks the KV grid axis itself — blocks beyond the
    hint are never fetched; per-slot `pl.when` skips handle the rest.
    """
    interpret = default_interpret(interpret)
    return _flash_decode(q, k_cache, v_cache, lengths, scale=scale,
                         block_k=block_k, max_len_hint=max_len_hint,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "max_len_hint",
                                             "interpret"))
def flash_decode_paged(q, k_pool, v_pool, block_tables, lengths, *,
                       scale: float, max_len_hint: int | None = None,
                       interpret: bool | None = None):
    """One-token decode attention straight against the paged KV pool
    (DESIGN.md §9) — no gathered per-slot copy.

    q: (B,H,Dk); pools: (NP,PS,KV,D); block_tables: (B,NB) int32 mapping
    each slot's logical ring block to its physical page (trash page 0 for
    unallocated blocks); lengths: (B,) valid logical length per slot.
    The block table and lengths are scalar-prefetch operands: the KV
    BlockSpec index maps dereference `bt[b, ki]`, so each grid step DMAs
    exactly the page backing logical block ki of row b. The online
    softmax runs page-by-page (block_k = page_size); it matches
    `flash_decode` on the gathered view bitwise only when page_size
    equals that call's block_k, fp32-close otherwise. max_len_hint
    (static, >= max(lengths)) shrinks the page grid axis like
    `flash_decode`'s early exit.
    """
    interpret = default_interpret(interpret)
    return _flash_decode_paged(q, k_pool, v_pool, block_tables, lengths,
                               scale=scale, max_len_hint=max_len_hint,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "block_k",
                                             "offset_hint", "interpret"))
def prefill_attention(q, k_chunk, v_chunk, k_cache, v_cache, offset, *,
                      scale: float, block_k: int = 128,
                      offset_hint: int | None = None,
                      interpret: bool | None = None):
    """Chunked-prefill attention: a C-token prompt chunk (Q) against the
    slot cache prefix plus the chunk's own K/V — the admission hot path.

    q: (B,C,H,Dk); k_chunk/v_chunk: (B,C,KV,D); caches: (B,CL,KV,D) in
    their PRE-chunk state (attend-then-write); offset: scalar absolute
    position of the chunk's first token. Cache slots are masked by the
    ring rule p_j = offset-1 - ((offset-1-j) mod CL), valid iff p_j >= 0
    and qp - p_j < CL — which degenerates to j < offset on a full-length
    cache; intra-chunk attention is causal. MLA absorbed prefill reuses
    the kernel with KV=1 and latent+rope dims concatenated.

    offset_hint (static, >= min(offset, CL)) shrinks the cache-block grid
    axis itself — like `flash_decode`'s `max_len_hint` — so blocks past
    the write frontier are never fetched; the engine buckets the host-side
    chunk offset to block_k so jit sees few distinct values.

    Part of the chunked-prefill equivalence law (DESIGN.md §2): admission
    through this kernel must match the sequential decode loop bit-for-bit
    in fp32 on the resulting cache, and within fp32 tolerance on logits.
    """
    interpret = default_interpret(interpret)
    return _prefill_attention(q, k_chunk, v_chunk, k_cache, v_cache, offset,
                              scale=scale, block_k=block_k,
                              offset_hint=offset_hint, interpret=interpret)


def fused_logprob(hidden, head, targets, *, transpose_head: bool = False,
                  block_n: int | None = None, block_v: int | None = None,
                  interpret: bool | None = None):
    """Fused linear-cross-entropy over the lm head — the trainer's loss
    hot path (DESIGN.md §5-6).

    hidden: (N,D) post-final-norm hidden states; head: (D,V), or (V,D)
    with transpose_head=True (tied-embedding layout, no transposed copy);
    targets: (N,) int32. Returns (logprob, lse, entropy), each (N,) f32.
    Tiles the vocab axis with an online-logsumexp reduction so the
    (N,V) logits are never materialized, and carries a custom VJP that
    recomputes per-block softmax from the saved lse so the logits
    *gradient* is never materialized either (grads reach both hidden and
    head). Unlike the other wrappers this one is not jit-wrapped: it is
    always called from inside the already-jitted `train_step` loss, and
    an extra jit boundary here would only add a dispatch layer.

    block_n/block_v default to MXU-friendly (128, 512) tiles on compiled
    TPU; interpret mode (the CPU validation/co-sim path) defaults to
    coarser (256, 2048) blocks — the interpreter pays per-grid-step python
    dispatch, so fewer/bigger blocks make CPU trainer steps measurably
    faster with identical masking and numerics. Explicit values win.
    """
    interpret = default_interpret(interpret)
    if block_n is None:
        block_n = 256 if interpret else 128
    if block_v is None:
        block_v = 2048 if interpret else 512
    return _fused_logprob(hidden, head, targets,
                          transpose_head=transpose_head, block_n=block_n,
                          block_v=block_v, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 64,
             interpret: bool | None = None):
    """Mamba2 SSD chunked scan: intra-chunk attention-form + inter-chunk
    state recurrence. x: (b,l,h,p); dt: (b,l,h); A: (h,); B,C: (b,l,g,n).
    Returns (y (b,l,h,p), final_state (b,h,p,n) fp32). The recurrence is
    reassociated across chunks, so results match the sequential scan to
    fp32 tolerance (not bitwise) — the equivalence tests account for this.
    """
    interpret = default_interpret(interpret)
    return _ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
