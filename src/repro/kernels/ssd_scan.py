"""Pallas TPU kernel for the Mamba2 SSD chunked scan. [arXiv:2405.21060]

TPU adaptation: the chunk dimension is the sequential trailing grid axis;
the (P, N) recurrent state lives in VMEM scratch and is carried across
chunks — the HBM traffic is exactly one pass over x/dt/B/C plus the y
writeback, and all three chunk-local contractions (C@B^T, score@x, C@state)
are MXU matmuls. Chunk length Q and head dim P should be multiples of 8/128
for lane alignment (Q=64..128 fits VMEM comfortably at N=128).

grid = (batch, heads, n_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import MEMSPACE as _MEMSPACE, default_interpret


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, fstate_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)            # (Q,P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    b = b_ref[0, :, 0].astype(jnp.float32)            # (Q,N)
    c = c_ref[0, :, 0].astype(jnp.float32)            # (Q,N)
    A = a_ref[0]                                      # scalar (negative)

    dA = dt * A                                       # (Q,)
    A_cum = jnp.cumsum(dA)                            # (Q,)
    xd = x * dt[:, None]                              # (Q,P)

    # intra-chunk: L[i,j] = exp(A_cum[i] - A_cum[j]) for i >= j
    seg = A_cum[:, None] - A_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * Lmat
    y = jax.lax.dot_general(scores, xd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                            # (N,P)
    y += jnp.exp(A_cum)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: state' = exp(sum dA) * state + B^T @ (decay * xd)
    decay = jnp.exp(A_cum[-1] - A_cum)                # (Q,)
    state_ref[...] = jnp.exp(A_cum[-1]) * state + jax.lax.dot_general(
        b, xd * decay[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        fstate_ref[0, 0] = state_ref[...]


def ssd_scan(x, dt, A, B, C, *, chunk: int = 64,
             interpret: bool | None = None):
    """x: (b,l,h,p); dt: (b,l,h) (softplus'd); A: (h,) negative;
    B,C: (b,l,g,n). Returns (y (b,l,h,p), final_state (b,h,n,p))
    (no D skip / gating — see ops.py). interpret=None: auto by backend."""
    interpret = default_interpret(interpret)
    bsz, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,),
                         memory_space=_MEMSPACE.SMEM),
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c_, _r=rep: (b_, c_, h_ // _r, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c_, _r=rep: (b_, c_, h_ // _r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt, B, C)
