"""Pallas TPU flash chunked-prefill: a C-token prompt chunk attending to
the slot cache plus itself — the generation engine's admission hot path.

Chunked-prefill attention has two key sources with different masking:

1. **Cache prefix** — K/V written by *previous* chunks of this prompt,
   read from the (possibly ring-buffer) slot cache. Cache slot ``j``
   holds absolute position ``p_j = offset-1 - ((offset-1-j) mod CL)``
   (the most recent position congruent to ``j`` that precedes the chunk);
   it is valid for a query at absolute position ``qp`` iff ``p_j >= 0``
   (the slot was ever written) and ``qp - p_j < CL`` (inside the sliding
   window — for a full-length cache ``CL`` equals the sequence budget so
   this clips nothing). With ``CL = max_len`` the rule degenerates to the
   familiar ``j < offset``.
2. **The chunk itself** — fresh K/V of this chunk's tokens, causal within
   the chunk (``kp <= qp``; the window constraint is vacuous because the
   host guarantees ``C <= CL``).

Attention therefore runs against the cache *before* the chunk is written
into it: on a ring buffer the chunk's writes overwrite exactly the slots
that fall out of the window, so attend-then-write is what makes chunked
admission equal the sequential decode loop (DESIGN.md §2 equivalence law).

grid = (batch, kv_heads, n_cache_blocks + 1); the trailing axis is
sequential on TPU and streams cache KV blocks HBM->VMEM with online-
softmax state in VMEM scratch, exactly like ``flash_decode``; the final
grid step processes the chunk's own K/V tile and writes the output. All
``rep`` q-heads of a kv head are folded with the chunk axis into one
``(C*rep, d)`` MXU tile. Cache blocks entirely beyond the write frontier
(``ki*block_k >= offset``) skip their dots via ``pl.when`` — the first
chunks of a prompt touch almost none of the cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.common import MEMSPACE as _MEMSPACE, default_interpret

NEG_INF = -1e30


def _prefill_kernel(off_ref, q_ref, kc_ref, vc_ref, kh_ref, vh_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, scale: float, block_k: int,
                    n_cache_blocks: int, chunk: int, rep: int, cache_len: int):
    ki = pl.program_id(2)
    off = off_ref[0]
    rows = chunk * rep  # row = ci * rep + r  ->  query chunk index ci = row//rep

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _online_update(s, v):
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # ---- cache-prefix blocks: skip blocks entirely past the write frontier
    @pl.when((ki < n_cache_blocks) & (ki * block_k < off))
    def _cache_block():
        q = q_ref[0, 0].astype(jnp.float32)                  # (rows, d)
        k = kc_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        v = vc_ref[0, 0].astype(jnp.float32)                 # (bk, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = off + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 0) // rep            # abs query pos
        j = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 1)                   # cache slot
        # absolute position held at slot j (ring addressing); for a
        # full-length cache this reduces to p_j = j valid iff j < offset
        p_j = (off - 1) - jnp.remainder(off - 1 - j, cache_len)
        valid = (p_j >= 0) & (qp - p_j < cache_len)
        _online_update(jnp.where(valid, s, NEG_INF), v)

    # ---- the chunk's own K/V: causal within the chunk, then finalize
    @pl.when(ki == n_cache_blocks)
    def _chunk_block():
        q = q_ref[0, 0].astype(jnp.float32)                  # (rows, d)
        k = kh_ref[0, 0].astype(jnp.float32)                 # (C, d)
        v = vh_ref[0, 0].astype(jnp.float32)                 # (C, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = jax.lax.broadcasted_iota(jnp.int32, (rows, chunk), 0) // rep
        ci = jax.lax.broadcasted_iota(jnp.int32, (rows, chunk), 1)
        _online_update(jnp.where(ci <= qi, s, NEG_INF), v)
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def prefill_attention(q, k_chunk, v_chunk, k_cache, v_cache, offset, *,
                      scale: float, block_k: int = 128,
                      offset_hint: int | None = None,
                      interpret: bool | None = None):
    """q: (B,C,H,Dk); k_chunk/v_chunk: (B,C,KV,Dk/Dv); caches:
    (B,CL,KV,Dk/Dv); offset: scalar int32 absolute position of the chunk's
    first token. Returns (B,C,H,Dv).

    The caches must be in their pre-chunk state (attend-then-write, see
    module docstring). Requires C <= CL and CL % block_k == 0. MLA absorbed
    prefill reuses this kernel with KV=1, Dk = kv_lora_rank + qk_rope_dim
    (concatenated latent+rope queries/keys) and Dv = kv_lora_rank.

    offset_hint: optional *static* upper bound on the number of valid
    cache slots, i.e. >= min(offset, CL) — the cache-block grid axis
    shrinks to ceil(hint/block_k) blocks, so blocks past the write
    frontier are never even fetched (the `pl.when` skip alone still paid
    the DMA). The generation engine derives it from the host-side chunk
    offset, rounded up to block_k so jit sees few distinct values; a
    violation silently truncates attention. None keeps the full grid.

    interpret=None resolves to interpret mode off-TPU and compiled mode on
    TPU (callers may force either; see kernels.ops for the jitted wrapper).
    """
    interpret = default_interpret(interpret)
    B, C, H, Dk = q.shape
    CL, KV = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    rep = H // KV
    block_k = min(block_k, CL)
    assert CL % block_k == 0, (CL, block_k)
    assert C <= CL, (C, CL)
    nkb = CL // block_k
    if offset_hint is not None:
        # a first chunk (offset 0) touches no cache blocks at all
        nkb = min(nkb, -(-min(int(offset_hint), CL) // block_k))
    rows = C * rep

    qr = q.reshape(B, C, KV, rep, Dk).transpose(0, 2, 1, 3, 4)
    qr = qr.reshape(B, KV, rows, Dk)
    kc = jnp.swapaxes(k_cache, 1, 2)                    # (B,KV,CL,Dk)
    vc = jnp.swapaxes(v_cache, 1, 2)
    kh = jnp.swapaxes(k_chunk, 1, 2)                    # (B,KV,C,Dk)
    vh = jnp.swapaxes(v_chunk, 1, 2)
    off = jnp.reshape(jnp.asarray(offset, jnp.int32), (1,))

    kernel = functools.partial(
        _prefill_kernel, scale=scale, block_k=block_k, n_cache_blocks=nkb,
        chunk=C, rep=rep, cache_len=CL)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nkb + 1),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (0,),
                         memory_space=_MEMSPACE.SMEM),
            pl.BlockSpec((1, 1, rows, Dk), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, Dk),
                         lambda b, h, ki, _n=max(nkb - 1, 0):
                             (b, h, jnp.minimum(ki, _n), 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, ki, _n=max(nkb - 1, 0):
                             (b, h, jnp.minimum(ki, _n), 0)),
            pl.BlockSpec((1, 1, C, Dk), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, C, Dv), lambda b, h, ki: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, Dv), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, rows, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, 1), jnp.float32),
            pltpu.VMEM((rows, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(off, qr, kc, vc, kh, vh)
    out = out.reshape(B, KV, C, rep, Dv).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, C, H, Dv)
