"""Ambient sharding context.

Model code annotates activations with *logical* axes via `constrain`;
outside a mesh context this is the identity, inside it becomes
`with_sharding_constraint` using the rules engine. This keeps the model
definitions mesh-agnostic (smoke tests on 1 CPU device, dry-run on 512).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding import logical_to_spec

_CTX: contextvars.ContextVar = contextvars.ContextVar("shard_ctx", default=None)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules=None):
    tok = _CTX.set((mesh, rules))
    try:
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else contextlib.nullcontext():
            yield
    finally:
        _CTX.reset(tok)


def current_mesh() -> Optional[Mesh]:
    ctx = _CTX.get()
    return ctx[0] if ctx else None


def current_rules():
    ctx = _CTX.get()
    return ctx[1] if ctx else None


def constrain(x, logical: Sequence[Optional[str]]):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
