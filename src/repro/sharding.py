"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every parameter and activation in the model zoo is annotated with *logical*
axis names. A rules table maps logical axes to mesh axes; `logical_to_spec`
drops a mesh axis whenever the dimension is not divisible by the mesh axis
size (GQA kv_heads=8 on a model axis of 16, batch=1 on data=16, ...), so one
rule set covers all 10 architectures and all 4 input shapes.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

# Default rule set: FSDP over "data" (+"pod"), tensor parallel over "model".
# Tuple values mean the dimension is sharded over multiple mesh axes.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    # KV-cache length: flash-decode style sequence parallelism. Falls back
    # onto whichever axis the batch didn't consume; without this, archs
    # whose kv_heads don't divide the model axis replicate the whole cache
    # across it (16x memory + traffic; see EXPERIMENTS.md §Perf-2)
    "cache_seq": ("data", "model"),
    "embed": None,             # activation d_model stays replicated across TP
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",        # expert parallelism
    "expert_capacity": None,
    "prefix": None,
    # parameters: FSDP shards the non-TP dim over data, TP over model
    "p_embed": "data",
    "p_vocab": "model",
    "p_embed_vocab": "model",  # embedding table's vocab dim (gather operand)
    "p_heads": "model",
    "p_kv_heads": "model",
    "p_head_dim": None,
    "p_mlp": "model",
    "p_experts": "model",
    "p_lora": None,
    "p_inner": "model",        # SSM d_inner
    "p_conv": None,
    "p_state": None,
    "p_none": None,
}


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_spec(
    logical: Sequence[Optional[str]],
    dims: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict[str, MeshAxes]] = None,
) -> P:
    """Map logical axis names (+ concrete dims) to a PartitionSpec.

    A mesh axis is used only if (a) it exists in the mesh, (b) the dim is
    divisible by its size (after stacking with earlier axes of the same
    dim), and (c) it has not been consumed by an earlier dimension.
    """
    rules = dict(DEFAULT_RULES, **(rules or {}))
    sizes = _axis_sizes(mesh)
    used: set = set()
    out = []
    for name, dim in zip(logical, dims):
        entry: MeshAxes = rules.get(name) if name else None
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        picked = []
        shard = 1
        for ax in axes:
            if ax not in sizes or ax in used:
                continue
            if dim % (shard * sizes[ax]) != 0:
                continue
            picked.append(ax)
            shard *= sizes[ax]
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


class Annotated:
    """A ShapeDtypeStruct (or array) tagged with logical axis names."""

    __slots__ = ("value", "logical")

    def __init__(self, value, logical: Sequence[Optional[str]]):
        if len(logical) != len(value.shape):
            raise ValueError(
                f"logical axes {logical} do not match shape {value.shape}")
        self.value = value
        self.logical = tuple(logical)


def spec_for(ann: Annotated, mesh: Mesh, rules=None) -> P:
    return logical_to_spec(ann.logical, ann.value.shape, mesh, rules)


def tree_specs(tree, mesh: Mesh, rules=None):
    """Pytree of Annotated -> pytree of PartitionSpec (same structure)."""
    return jax.tree.map(
        lambda a: spec_for(a, mesh, rules),
        tree,
        is_leaf=lambda x: isinstance(x, Annotated),
    )


def tree_values(tree):
    return jax.tree.map(
        lambda a: a.value, tree, is_leaf=lambda x: isinstance(x, Annotated))


def tree_shardings(tree, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda a: NamedSharding(mesh, spec_for(a, mesh, rules)),
        tree,
        is_leaf=lambda x: isinstance(x, Annotated),
    )
