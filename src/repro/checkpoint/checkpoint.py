"""Minimal tree checkpointing: flatten the pytree with '/'-joined key paths
into an .npz. Enough for the RL driver's periodic checkpoints and the §5.1
consecutive-checkpoint KL study."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, like) -> Any:
    """Restore into the structure of `like` (shapes/dtypes preserved)."""
    with np.load(path) as data:
        flat = dict(data)
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    vals = []
    for path_, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        arr = flat[key]
        vals.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), vals)
