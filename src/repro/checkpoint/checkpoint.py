"""Minimal tree checkpointing: flatten the pytree with '/'-joined key paths
into an .npz. Enough for the RL driver's periodic checkpoints, the §5.1
consecutive-checkpoint KL study, and the trainer's crash-restart path
(DESIGN.md §8) — which is why `save` is atomic: a crash mid-save must
never corrupt the previous checkpoint (the restart would then have
nothing to restore from)."""
from __future__ import annotations

import os
import zipfile
from typing import Any, Dict

import jax
import numpy as np


class CheckpointError(ValueError):
    """Checkpoint file unusable: corrupt archive, missing/unexpected keys,
    or shape mismatch against the restore target."""


def _norm(path: str) -> str:
    """`np.savez` appends '.npz' to bare paths; normalize so
    `save(p)`/`load(p)` round-trip with the same `p` either way."""
    return path if path.endswith(".npz") else path + ".npz"


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree) -> None:
    """Atomic save: write to a sibling temp file, fsync, then
    `os.replace` — a crash at any point leaves either the old complete
    checkpoint or the new complete one, never a truncated archive."""
    path = _norm(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    try:
        # a file object keeps savez from appending another suffix to tmp
        with open(tmp, "wb") as f:
            np.savez(f, **_flatten(tree))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load(path: str, like) -> Any:
    """Restore into the structure of `like` (shapes/dtypes preserved).
    Raises CheckpointError naming the missing/unexpected keys or the
    mismatched shapes instead of surfacing a bare KeyError deep in the
    tree walk."""
    path = _norm(path)
    try:
        with np.load(path) as data:
            flat = dict(data)
    except FileNotFoundError:
        raise
    except (zipfile.BadZipFile, ValueError, OSError, EOFError) as e:
        raise CheckpointError(
            f"corrupt or unreadable checkpoint {path!r}: "
            f"{type(e).__name__}: {e}") from e
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    want = {}
    for path_, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        want[key] = leaf
    missing = sorted(set(want) - set(flat))
    unexpected = sorted(set(flat) - set(want))
    if missing or unexpected:
        raise CheckpointError(
            f"checkpoint {path!r} does not match the restore target: "
            f"missing keys {missing}, unexpected keys {unexpected}")
    bad_shapes = [
        f"{k}: checkpoint {flat[k].shape} vs target {tuple(leaf.shape)}"
        for k, leaf in want.items()
        if hasattr(leaf, "shape") and tuple(flat[k].shape) != tuple(leaf.shape)]
    if bad_shapes:
        raise CheckpointError(
            f"checkpoint {path!r} shape mismatch: " + "; ".join(bad_shapes))
    vals = []
    for path_, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        arr = flat[key]
        vals.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), vals)
