"""Minimal tree checkpointing: flatten the pytree with '/'-joined key paths
into an .npz. Enough for the RL driver's periodic checkpoints, the §5.1
consecutive-checkpoint KL study, and the trainer's crash-restart path
(DESIGN.md §8) — which is why `save` is atomic: a crash mid-save must
never corrupt the previous checkpoint (the restart would then have
nothing to restore from)."""
from __future__ import annotations

import os
import zipfile
import zlib
from typing import Any, Dict

import jax
import numpy as np


class CheckpointError(ValueError):
    """Checkpoint file unusable: corrupt archive, missing/unexpected keys,
    shape mismatch against the restore target, or content-checksum
    mismatch (bit rot / torn write that still unzips)."""


# reserved key holding the crc32 content checksum of every other entry;
# absent in pre-§10 checkpoints, which therefore still load (unverified)
_CRC_KEY = "__content_crc32__"


def _content_crc(flat: Dict[str, np.ndarray]) -> int:
    """crc32 over (key, dtype, shape, bytes) of every entry in sorted key
    order — any flipped bit, truncated array, or renamed key changes it."""
    crc = 0
    for key in sorted(k for k in flat if k != _CRC_KEY):
        arr = np.ascontiguousarray(flat[key])
        head = f"{key}|{arr.dtype.str}|{arr.shape}".encode()
        crc = zlib.crc32(arr.tobytes(), zlib.crc32(head, crc))
    return crc


def _norm(path: str) -> str:
    """`np.savez` appends '.npz' to bare paths; normalize so
    `save(p)`/`load(p)` round-trip with the same `p` either way."""
    return path if path.endswith(".npz") else path + ".npz"


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree) -> None:
    """Atomic save: write to a sibling temp file, fsync, then
    `os.replace` — a crash at any point leaves either the old complete
    checkpoint or the new complete one, never a truncated archive. A
    content checksum over every entry rides along so `load`/`verify` can
    reject damage the zip layer doesn't catch."""
    path = _norm(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    flat[_CRC_KEY] = np.asarray(_content_crc(flat), np.int64)
    tmp = path + ".tmp"
    try:
        # a file object keeps savez from appending another suffix to tmp
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load(path: str, like) -> Any:
    """Restore into the structure of `like` (shapes/dtypes preserved).
    Raises CheckpointError naming the missing/unexpected keys or the
    mismatched shapes instead of surfacing a bare KeyError deep in the
    tree walk."""
    path = _norm(path)
    try:
        with np.load(path) as data:
            flat = dict(data)
    except FileNotFoundError:
        raise
    except (zipfile.BadZipFile, ValueError, OSError, EOFError,
            KeyError) as e:
        raise CheckpointError(
            f"corrupt or unreadable checkpoint {path!r}: "
            f"{type(e).__name__}: {e}") from e
    stored_crc = flat.pop(_CRC_KEY, None)
    if stored_crc is not None and int(stored_crc) != _content_crc(flat):
        raise CheckpointError(
            f"checkpoint {path!r} failed content-checksum verification "
            f"(bit rot or torn write)")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    want = {}
    for path_, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        want[key] = leaf
    missing = sorted(set(want) - set(flat))
    unexpected = sorted(set(flat) - set(want))
    if missing or unexpected:
        raise CheckpointError(
            f"checkpoint {path!r} does not match the restore target: "
            f"missing keys {missing}, unexpected keys {unexpected}")
    bad_shapes = [
        f"{k}: checkpoint {flat[k].shape} vs target {tuple(leaf.shape)}"
        for k, leaf in want.items()
        if hasattr(leaf, "shape") and tuple(flat[k].shape) != tuple(leaf.shape)]
    if bad_shapes:
        raise CheckpointError(
            f"checkpoint {path!r} shape mismatch: " + "; ".join(bad_shapes))
    vals = []
    for path_, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_)
        arr = flat[key]
        vals.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(jax.tree.structure(like), vals)


def verify(path: str) -> bool:
    """True iff `path` is a readable checkpoint whose content checksum
    (when present) matches. Cheap intact-ness probe for rotation and the
    newest-intact-fallback restore path (DESIGN.md §10)."""
    try:
        with np.load(_norm(path)) as data:
            flat = dict(data)
    except (FileNotFoundError, zipfile.BadZipFile, ValueError, OSError,
            EOFError, KeyError):
        return False
    stored = flat.pop(_CRC_KEY, None)
    return stored is None or int(stored) == _content_crc(flat)
