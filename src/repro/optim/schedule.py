"""Learning-rate schedules (pure jnp, traceable inside train_step)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def constant(lr: float):
    def fn(step):
        return jnp.full((), lr, jnp.float32)
    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  final_ratio: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = lr * step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = lr * (final_ratio + (1 - final_ratio)
                    * 0.5 * (1 + jnp.cos(np.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def warmup_constant(lr: float, warmup_steps: int):
    def fn(step):
        step = step.astype(jnp.float32)
        return lr * jnp.minimum(1.0, step / max(warmup_steps, 1))
    return fn
