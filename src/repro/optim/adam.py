"""Adam (Kingma, 2014) in pure JAX with fp32 moments over a bf16/f32 param
tree. Moments inherit the parameter sharding (ZeRO-style: the FSDP rules
already shard every large parameter, so optimizer state is sharded the same
way for free)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-6  # paper: Adam, lr 1e-6
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0


def adam_init(params) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree.map(zeros, params),
                     v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adam_update(params, grads, state: AdamState, cfg: AdamConfig, lr=None):
    """Returns (new_params, new_state, grad_norm). `lr` (traced scalar from
    a schedule) overrides cfg.lr when given."""
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), gnorm
