"""RL algorithm layer: truncated-importance-sampling REINFORCE with a
learned value baseline (paper Eq. 4-5), the ESS on-policyness metric
(Eq. 6, Kong 1992), and lag-aware staleness corrections that consume the
per-token `weight_versions` provenance the engine stamps (DESIGN.md §12):

  lag_mode="off"       — the paper's objective, bit-identical to the
                         pre-lag code path (lag fields dropped before jit)
  lag_mode="token_is"  — per-token lag-conditional clamp: stale tokens get
                         a tighter IS ceiling (clamp decays geometrically
                         in lag), so one global clamp stops being the only
                         defense against off-policy drift
  lag_mode="truncated" — Truncated-PPO-style staleness horizon: tokens
                         sampled more than `lag_horizon` versions ago are
                         masked out of the objective, and max_len-truncated
                         rollouts can be downweighted (`truncated_weight`)

All modes are Python-trace-time branches — a mode never pays for the
others' math, and "off" compiles to exactly the historical jaxpr."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RLConfig:
    is_clamp: float = 5.0          # paper: "clamp the importance weights to 5"
    value_coef: float = 0.5
    aux_coef: float = 0.001        # MoE load-balance
    entropy_coef: float = 0.0
    temperature: float = 1.0
    # ---- lag-aware objectives (DESIGN.md §12) --------------------------
    lag_mode: str = "off"          # "off" | "token_is" | "truncated"
    lag_clamp_decay: float = 0.5   # token_is: clamp *= decay**lag
    lag_clamp_min: float = 1.0     # token_is: clamp floor (>=1 keeps the
                                   # on-policy ratio un-truncated)
    lag_horizon: int = 4           # truncated: mask tokens with lag > this
    truncated_weight: float = 1.0  # truncated: weight for max_len-truncated
                                   # rollouts (1.0 = no downweighting)
    lag_buckets: Tuple[int, ...] = (0, 1, 2, 4, 8)  # per-bucket ESS/clamp


def token_logprobs(logits, tokens):
    """logits: (B,S,V) predicting token t from context < t (i.e. logits[t]
    scores tokens[t+1]); returns per-token logprob of the *sampled* token,
    aligned with `tokens` (position 0 gets 0)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lp_next = jnp.take_along_axis(lp[:, :-1], tokens[:, 1:, None], axis=-1)[..., 0]
    return jnp.pad(lp_next, ((0, 0), (1, 0)))


def token_stats_from_logits(logits, tokens):
    """Per-token loss statistics from raw logits — the unfused twin of the
    `kernels.fused_logprob` model output. Returns a dict with
    `token_logprobs`, `lse` and `entropy`, each (B,S) f32 aligned with
    `tokens` like `token_logprobs` (entry t describes the distribution
    that scored token t; entry 0 is a zero pad)."""
    l32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(l32, axis=-1)              # (B,S)
    tgt_l = jnp.take_along_axis(l32[:, :-1], tokens[:, 1:, None],
                                axis=-1)[..., 0]
    p = jnp.exp(l32 - lse[..., None])   # softmax from the lse already paid
    ent = lse - jnp.sum(p * l32, axis=-1)

    def shift(x):
        return jnp.pad(x[:, :-1], ((0, 0), (1, 0)))

    return {"token_logprobs": jnp.pad(tgt_l - lse[:, :-1], ((0, 0), (1, 0))),
            "lse": shift(lse), "entropy": shift(ent)}


def ess(weights, mask) -> jax.Array:
    """Normalized effective sample size (Eq. 6) over masked tokens.

    Explicitly 0 for an empty mask (salvage/requeue can assemble
    completion-free batches) instead of leaning on the 1e-30 epsilon —
    bit-identical to the epsilon path on every non-degenerate batch
    (`where(True, x, 0)` selects x bitwise)."""
    w = weights * mask
    n = jnp.maximum(mask.sum(), 1.0)
    s1 = w.sum()
    s2 = jnp.square(w).sum()
    return jnp.where(s2 > 0,
                     jnp.square(s1) / jnp.maximum(n * s2, 1e-30), 0.0)


def reinforce_loss(
    outputs, values, batch: Dict[str, jax.Array], cfg: RLConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Truncated-IS REINFORCE (Eq. 5) + value MSE.

    outputs: either raw (B,S,V) logits, or a per-token stats dict with
    `token_logprobs` and `entropy` as produced by the fused-loss model
    path (`M.forward(..., loss_targets=...)` under `cfg.fused_loss`) or by
    `token_stats_from_logits` — the loss never needs the full logits, only
    the sampled token's logprob and (for the metric/bonus) the
    distribution entropy, which is what makes the fused kernel a drop-in.
    batch: packed train batch (tokens, loss_mask, behavior_logprobs,
    rewards (per-token broadcast), and — when a lag mode is armed —
    per-token `lag` and per-segment `truncated` from `pack(...,
    trainer_version=...)`). `values` may be None.

    Lag handling is a Python-trace-time branch on `cfg.lag_mode` (never a
    `jnp.where` over modes): "off" compiles to exactly the historical
    jaxpr, and the armed modes are bit-identical to it whenever every lag
    is 0 (`decay**0 == 1.0`, `mask * 1.0`, `where(True, x, _)` are all
    bitwise-exact identities).
    """
    tokens, mask = batch["tokens"], batch["loss_mask"]
    if isinstance(outputs, dict):
        stats = outputs
    else:
        stats = token_stats_from_logits(outputs, tokens)
    cur_lp = stats["token_logprobs"]                    # (B,S) f32
    beh_lp = batch["behavior_logprobs"]
    rewards = batch["rewards"]

    lag_f = None
    if cfg.lag_mode != "off":
        # legacy callers pack no lag field: fall back to all-fresh
        lag = batch.get("lag")
        lag_f = (jnp.asarray(lag).astype(jnp.float32) if lag is not None
                 else jnp.zeros_like(mask))

    if cfg.lag_mode == "truncated":
        # staleness horizon: tokens sampled more than `lag_horizon`
        # versions ago leave the objective entirely (Truncated PPO);
        # max_len-truncated rollouts optionally downweighted
        keep = jnp.where(lag_f <= float(cfg.lag_horizon), 1.0, 0.0)
        if cfg.truncated_weight != 1.0:
            tr = batch.get("truncated")
            tr = (jnp.asarray(tr).astype(jnp.float32) if tr is not None
                  else jnp.zeros_like(mask))
            keep = keep * (1.0 - (1.0 - cfg.truncated_weight) * tr)
        mask = mask * keep

    log_ratio = jnp.where(mask > 0, cur_lp - beh_lp, 0.0)
    ratio = jnp.exp(log_ratio)
    if cfg.lag_mode == "token_is":
        # lag-conditional clamp: the IS ceiling decays geometrically in
        # staleness, flooring at lag_clamp_min (>=1 keeps fresh tokens
        # un-truncated). lag==0 gives clamp == is_clamp exactly.
        clamp_tok = jnp.maximum(
            cfg.is_clamp * jnp.power(cfg.lag_clamp_decay, lag_f),
            cfg.lag_clamp_min)
    else:
        clamp_tok = cfg.is_clamp
    clamped = jnp.minimum(ratio, clamp_tok)

    if values is not None:
        baseline = values
        value_loss = jnp.sum(jnp.square(rewards - values) * mask) \
            / jnp.maximum(mask.sum(), 1.0)
    else:
        baseline = jnp.zeros_like(rewards)
        value_loss = jnp.zeros((), jnp.float32)
    adv = jax.lax.stop_gradient(rewards - baseline)

    pg = -jnp.sum(jax.lax.stop_gradient(clamped) * adv * cur_lp * mask) \
        / jnp.maximum(mask.sum(), 1.0)

    loss = pg + cfg.value_coef * value_loss
    # entropy bonus: sampled-token surrogate (-p log p of the taken action
    # only) — identical between the fused and unfused paths since it needs
    # only cur_lp. The full-distribution entropy is reported as a metric.
    ent = -jnp.sum(jnp.exp(cur_lp) * cur_lp * mask) / jnp.maximum(mask.sum(), 1.0)
    if cfg.entropy_coef:
        loss = loss - cfg.entropy_coef * ent

    # degenerate-batch guard (salvage/requeue or a hard lag bound can
    # assemble an all-masked batch): explicit zero-loss no-op, counted via
    # the `empty_batch` metric. `where(True, loss, 0)` is `loss` bitwise,
    # so non-degenerate batches are untouched.
    n_tok = mask.sum()
    loss = jnp.where(n_tok > 0, loss, 0.0)

    metrics = {
        "entropy": jnp.sum(stats["entropy"] * mask)
            / jnp.maximum(mask.sum(), 1.0),
        "pg_loss": pg,
        "value_loss": value_loss,
        "ess": ess(ratio, mask),
        "mean_is_weight": jnp.sum(ratio * mask) / jnp.maximum(mask.sum(), 1.0),
        "clip_frac": jnp.sum((ratio > clamp_tok) * mask)
            / jnp.maximum(mask.sum(), 1.0),
        "token_kl": jnp.sum((beh_lp - cur_lp) * mask) / jnp.maximum(mask.sum(), 1.0),
        "mean_reward_tok": jnp.sum(rewards * mask) / jnp.maximum(mask.sum(), 1.0),
        "empty_batch": (n_tok == 0).astype(jnp.float32),
    }
    if cfg.lag_mode != "off":
        # per-lag-bucket ESS and clamp rate: bucket i covers
        # [lag_buckets[i], lag_buckets[i+1]) (last bucket open-ended)
        buckets = tuple(cfg.lag_buckets)
        for i, lo in enumerate(buckets):
            hi = buckets[i + 1] if i + 1 < len(buckets) else None
            sel = (lag_f >= lo) if hi is None else \
                ((lag_f >= lo) & (lag_f < hi))
            bmask = mask * sel
            metrics[f"ess_lag{lo}"] = ess(ratio, bmask)
            metrics[f"clamp_lag{lo}"] = jnp.sum((ratio > clamp_tok) * bmask) \
                / jnp.maximum(bmask.sum(), 1.0)
    return loss, metrics
