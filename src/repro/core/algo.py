"""RL algorithm layer: truncated-importance-sampling REINFORCE with a
learned value baseline (paper Eq. 4-5) and the ESS on-policyness metric
(Eq. 6, Kong 1992)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RLConfig:
    is_clamp: float = 5.0          # paper: "clamp the importance weights to 5"
    value_coef: float = 0.5
    aux_coef: float = 0.001        # MoE load-balance
    entropy_coef: float = 0.0
    temperature: float = 1.0


def token_logprobs(logits, tokens):
    """logits: (B,S,V) predicting token t from context < t (i.e. logits[t]
    scores tokens[t+1]); returns per-token logprob of the *sampled* token,
    aligned with `tokens` (position 0 gets 0)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lp_next = jnp.take_along_axis(lp[:, :-1], tokens[:, 1:, None], axis=-1)[..., 0]
    return jnp.pad(lp_next, ((0, 0), (1, 0)))


def token_stats_from_logits(logits, tokens):
    """Per-token loss statistics from raw logits — the unfused twin of the
    `kernels.fused_logprob` model output. Returns a dict with
    `token_logprobs`, `lse` and `entropy`, each (B,S) f32 aligned with
    `tokens` like `token_logprobs` (entry t describes the distribution
    that scored token t; entry 0 is a zero pad)."""
    l32 = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(l32, axis=-1)              # (B,S)
    tgt_l = jnp.take_along_axis(l32[:, :-1], tokens[:, 1:, None],
                                axis=-1)[..., 0]
    p = jnp.exp(l32 - lse[..., None])   # softmax from the lse already paid
    ent = lse - jnp.sum(p * l32, axis=-1)

    def shift(x):
        return jnp.pad(x[:, :-1], ((0, 0), (1, 0)))

    return {"token_logprobs": jnp.pad(tgt_l - lse[:, :-1], ((0, 0), (1, 0))),
            "lse": shift(lse), "entropy": shift(ent)}


def ess(weights, mask) -> jax.Array:
    """Normalized effective sample size (Eq. 6) over masked tokens."""
    w = weights * mask
    n = jnp.maximum(mask.sum(), 1.0)
    s1 = w.sum()
    s2 = jnp.square(w).sum()
    return jnp.square(s1) / jnp.maximum(n * s2, 1e-30)


def reinforce_loss(
    outputs, values, batch: Dict[str, jax.Array], cfg: RLConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Truncated-IS REINFORCE (Eq. 5) + value MSE.

    outputs: either raw (B,S,V) logits, or a per-token stats dict with
    `token_logprobs` and `entropy` as produced by the fused-loss model
    path (`M.forward(..., loss_targets=...)` under `cfg.fused_loss`) or by
    `token_stats_from_logits` — the loss never needs the full logits, only
    the sampled token's logprob and (for the metric/bonus) the
    distribution entropy, which is what makes the fused kernel a drop-in.
    batch: packed train batch (tokens, loss_mask, behavior_logprobs,
    rewards (per-token broadcast), ...). `values` may be None.
    """
    tokens, mask = batch["tokens"], batch["loss_mask"]
    if isinstance(outputs, dict):
        stats = outputs
    else:
        stats = token_stats_from_logits(outputs, tokens)
    cur_lp = stats["token_logprobs"]                    # (B,S) f32
    beh_lp = batch["behavior_logprobs"]
    rewards = batch["rewards"]

    log_ratio = jnp.where(mask > 0, cur_lp - beh_lp, 0.0)
    ratio = jnp.exp(log_ratio)
    clamped = jnp.minimum(ratio, cfg.is_clamp)

    if values is not None:
        baseline = values
        value_loss = jnp.sum(jnp.square(rewards - values) * mask) \
            / jnp.maximum(mask.sum(), 1.0)
    else:
        baseline = jnp.zeros_like(rewards)
        value_loss = jnp.zeros((), jnp.float32)
    adv = jax.lax.stop_gradient(rewards - baseline)

    pg = -jnp.sum(jax.lax.stop_gradient(clamped) * adv * cur_lp * mask) \
        / jnp.maximum(mask.sum(), 1.0)

    loss = pg + cfg.value_coef * value_loss
    # entropy bonus: sampled-token surrogate (-p log p of the taken action
    # only) — identical between the fused and unfused paths since it needs
    # only cur_lp. The full-distribution entropy is reported as a metric.
    ent = -jnp.sum(jnp.exp(cur_lp) * cur_lp * mask) / jnp.maximum(mask.sum(), 1.0)
    if cfg.entropy_coef:
        loss = loss - cfg.entropy_coef * ent

    metrics = {
        "entropy": jnp.sum(stats["entropy"] * mask)
            / jnp.maximum(mask.sum(), 1.0),
        "pg_loss": pg,
        "value_loss": value_loss,
        "ess": ess(ratio, mask),
        "mean_is_weight": jnp.sum(ratio * mask) / jnp.maximum(mask.sum(), 1.0),
        "clip_frac": jnp.sum((ratio > cfg.is_clamp) * mask)
            / jnp.maximum(mask.sum(), 1.0),
        "token_kl": jnp.sum((beh_lp - cur_lp) * mask) / jnp.maximum(mask.sum(), 1.0),
        "mean_reward_tok": jnp.sum(rewards * mask) / jnp.maximum(mask.sum(), 1.0),
    }
    return loss, metrics
