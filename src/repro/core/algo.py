"""RL algorithm layer: truncated-importance-sampling REINFORCE with a
learned value baseline (paper Eq. 4-5) and the ESS on-policyness metric
(Eq. 6, Kong 1992)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RLConfig:
    is_clamp: float = 5.0          # paper: "clamp the importance weights to 5"
    value_coef: float = 0.5
    aux_coef: float = 0.001        # MoE load-balance
    entropy_coef: float = 0.0
    temperature: float = 1.0


def token_logprobs(logits, tokens):
    """logits: (B,S,V) predicting token t from context < t (i.e. logits[t]
    scores tokens[t+1]); returns per-token logprob of the *sampled* token,
    aligned with `tokens` (position 0 gets 0)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lp_next = jnp.take_along_axis(lp[:, :-1], tokens[:, 1:, None], axis=-1)[..., 0]
    return jnp.pad(lp_next, ((0, 0), (1, 0)))


def ess(weights, mask) -> jax.Array:
    """Normalized effective sample size (Eq. 6) over masked tokens."""
    w = weights * mask
    n = jnp.maximum(mask.sum(), 1.0)
    s1 = w.sum()
    s2 = jnp.square(w).sum()
    return jnp.square(s1) / jnp.maximum(n * s2, 1e-30)


def reinforce_loss(
    logits, values, batch: Dict[str, jax.Array], cfg: RLConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Truncated-IS REINFORCE (Eq. 5) + value MSE.

    batch: packed train batch (tokens, loss_mask, behavior_logprobs,
    rewards (per-token broadcast), ...). `values` may be None.
    """
    tokens, mask = batch["tokens"], batch["loss_mask"]
    cur_lp = token_logprobs(logits, tokens)             # (B,S) f32
    beh_lp = batch["behavior_logprobs"]
    rewards = batch["rewards"]

    log_ratio = jnp.where(mask > 0, cur_lp - beh_lp, 0.0)
    ratio = jnp.exp(log_ratio)
    clamped = jnp.minimum(ratio, cfg.is_clamp)

    if values is not None:
        baseline = values
        value_loss = jnp.sum(jnp.square(rewards - values) * mask) \
            / jnp.maximum(mask.sum(), 1.0)
    else:
        baseline = jnp.zeros_like(rewards)
        value_loss = jnp.zeros((), jnp.float32)
    adv = jax.lax.stop_gradient(rewards - baseline)

    pg = -jnp.sum(jax.lax.stop_gradient(clamped) * adv * cur_lp * mask) \
        / jnp.maximum(mask.sum(), 1.0)

    loss = pg + cfg.value_coef * value_loss
    ent = -jnp.sum(jnp.exp(cur_lp) * cur_lp * mask) / jnp.maximum(mask.sum(), 1.0)
    if cfg.entropy_coef:
        loss = loss - cfg.entropy_coef * ent

    metrics = {
        "pg_loss": pg,
        "value_loss": value_loss,
        "ess": ess(ratio, mask),
        "mean_is_weight": jnp.sum(ratio * mask) / jnp.maximum(mask.sum(), 1.0),
        "clip_frac": jnp.sum((ratio > cfg.is_clamp) * mask)
            / jnp.maximum(mask.sum(), 1.0),
        "token_kl": jnp.sum((beh_lp - cur_lp) * mask) / jnp.maximum(mask.sum(), 1.0),
        "mean_reward_tok": jnp.sum(rewards * mask) / jnp.maximum(mask.sum(), 1.0),
    }
    return loss, metrics
