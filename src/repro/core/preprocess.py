"""Preprocessor — the middle pipeline stage of the paper's implementation
(Fig. 4): computes reference-model log-probabilities for finished rollouts
and applies the RLHF-style per-token KL penalty

    r_t  <-  r_task/T  -  beta * (log mu(y_t) - log pi_ref(y_t))

before sequences reach the trainer. Streams between Actor and Trainer like
the Redis stage in the paper; in the co-simulation it contributes its own
stage latency (a pure forward pass at tau/3 flashes/token on its chips).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.algo import token_logprobs
from repro.data.packing import Rollout
from repro.models import model as M


@dataclasses.dataclass
class PreprocessConfig:
    kl_coef: float = 0.0        # beta; 0 disables the KL term
    n_chips: int = 2            # preprocessor workers (sim timing)
    # hard cap on rollout length (the engine's max_len). The jitted ref
    # forward pads each batch to the next power of two of its longest
    # rollout, bounded by this — at most log2(max_len) trace buckets, and
    # a rollout can never be silently clipped to a shorter buffer (which
    # used to drop the KL term on the tail of long rollouts).
    max_len: int = 64
    fwd_flashes_per_token: float = 4.92 / 3.0  # forward-only share of tau


class Preprocessor:
    """Computes pi_ref token logprobs for rollouts and KL-shapes rewards."""

    def __init__(self, cfg: ModelConfig, ref_params, pc: PreprocessConfig):
        self.cfg, self.pc = cfg, pc
        self.ref_params = ref_params

        @jax.jit
        def ref_logprobs(params, tokens, positions, lengths):
            T = tokens.shape[1]
            if cfg.fused_loss:
                # the KL penalty only needs per-token ref logprobs of the
                # rollout's own tokens — exactly the fused-loss contract
                # (DESIGN.md §6): pass the next-token targets and let the
                # blockwise kernel return token_logprobs without ever
                # materializing the (B,S,V) ref logits. The final target
                # column is dead (nothing to predict) — fill it with pad,
                # never a duplicate of the row's own last token, so no
                # self-scored logprob exists even pre-shift.
                tgt = jnp.concatenate(
                    [tokens[:, 1:], jnp.zeros_like(tokens[:, -1:])], axis=1)
                out = M.forward(params, tokens, positions, cfg,
                                loss_targets=tgt)
                lp = out["token_logprobs"]
            else:
                out = M.forward(params, tokens, positions, cfg)
                lp = token_logprobs(out["logits"], tokens)
            # mask the pad tail (and with it the dead last position of
            # rows shorter than the bucket): entries at positions >= the
            # rollout's length are pad-token logprobs in the unfused path
            # and kernel garbage in the fused one — zero in both, so the
            # two paths agree entry-for-entry over the whole buffer
            valid = jnp.arange(T)[None, :] < lengths[:, None]
            return jnp.where(valid, lp, 0.0)

        self._ref_logprobs = ref_logprobs

    @staticmethod
    def _bucket(max_rollout_len: int, cap: int) -> int:
        """Next power of two >= the longest rollout, bounded by `cap`."""
        return min(1 << max(int(max_rollout_len) - 1, 0).bit_length(), cap)

    def process(self, rollouts: List[Rollout]) -> List[Rollout]:
        if not rollouts:
            return rollouts
        n = len(rollouts)
        max_len = max(r.length for r in rollouts)
        if max_len > self.pc.max_len:
            raise ValueError(
                f"rollout of length {max_len} exceeds PreprocessConfig."
                f"max_len={self.pc.max_len}; the ref forward would clip it "
                f"and silently drop the KL term on the tail — raise "
                f"max_len to the engine's max_len")
        T = self._bucket(max_len, self.pc.max_len)
        toks = np.zeros((n, T), np.int32)
        lens = np.zeros(n, np.int32)
        for i, r in enumerate(rollouts):
            toks[i, :r.length] = r.tokens
            lens[i] = r.length
        pos = jnp.broadcast_to(jnp.arange(T)[None], (n, T))
        ref_lp = np.asarray(self._ref_logprobs(self.ref_params,
                                               jnp.asarray(toks), pos,
                                               jnp.asarray(lens)))
        out = []
        for i, r in enumerate(rollouts):
            L = r.length
            r.ref_logprobs = ref_lp[i, :L].copy()
            if self.pc.kl_coef > 0:
                mask = np.arange(L) >= r.prompt_len
                kl = (r.behavior_logprobs[:L] - r.ref_logprobs) * mask
                penalty = np.zeros(L, np.float32)
                penalty[mask] = self.pc.kl_coef * kl[mask]
                n_tok = max(int(mask.sum()), 1)
                r.token_rewards = (np.full(L, r.reward / n_tok, np.float32)
                                   * mask - penalty)
                assert len(r.token_rewards) == r.length
            assert len(r.ref_logprobs) == r.length
            out.append(r)
        return out

    def stage_time(self, n_tokens: int) -> float:
        """Simulated stage latency (flashes) for a batch of tokens."""
        return n_tokens * self.pc.fwd_flashes_per_token / max(
            self.pc.n_chips, 1)
