"""Preprocessor — the middle pipeline stage of the paper's implementation
(Fig. 4): computes reference-model log-probabilities for finished rollouts
and applies the RLHF-style per-token KL penalty

    r_t  <-  r_task/T  -  beta * (log mu(y_t) - log pi_ref(y_t))

before sequences reach the trainer. Streams between Actor and Trainer like
the Redis stage in the paper; in the co-simulation it contributes its own
stage latency (a pure forward pass at tau/3 flashes/token on its chips).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.algo import token_logprobs
from repro.data.packing import Rollout
from repro.models import model as M


@dataclasses.dataclass
class PreprocessConfig:
    kl_coef: float = 0.0        # beta; 0 disables the KL term
    n_chips: int = 2            # preprocessor workers (sim timing)
    max_len: int = 64           # padding bucket for the jitted ref forward
    fwd_flashes_per_token: float = 4.92 / 3.0  # forward-only share of tau


class Preprocessor:
    """Computes pi_ref token logprobs for rollouts and KL-shapes rewards."""

    def __init__(self, cfg: ModelConfig, ref_params, pc: PreprocessConfig):
        self.cfg, self.pc = cfg, pc
        self.ref_params = ref_params

        @jax.jit
        def ref_logprobs(params, tokens, positions):
            if cfg.fused_loss:
                # the KL penalty only needs per-token ref logprobs of the
                # rollout's own tokens — exactly the fused-loss contract
                # (DESIGN.md §6): pass the next-token targets and let the
                # blockwise kernel return token_logprobs without ever
                # materializing the (B,S,V) ref logits
                tgt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]],
                                      axis=1)
                out = M.forward(params, tokens, positions, cfg,
                                loss_targets=tgt)
                return out["token_logprobs"]
            out = M.forward(params, tokens, positions, cfg)
            return token_logprobs(out["logits"], tokens)

        self._ref_logprobs = ref_logprobs

    def process(self, rollouts: List[Rollout]) -> List[Rollout]:
        if not rollouts:
            return rollouts
        T = self.pc.max_len
        n = len(rollouts)
        toks = np.zeros((n, T), np.int32)
        for i, r in enumerate(rollouts):
            L = min(r.length, T)
            toks[i, :L] = r.tokens[:L]
        pos = jnp.broadcast_to(jnp.arange(T)[None], (n, T))
        ref_lp = np.asarray(self._ref_logprobs(self.ref_params,
                                               jnp.asarray(toks), pos))
        out = []
        for i, r in enumerate(rollouts):
            L = min(r.length, T)
            r.ref_logprobs = ref_lp[i, :L].copy()
            if self.pc.kl_coef > 0:
                mask = np.arange(L) >= r.prompt_len
                kl = (r.behavior_logprobs[:L] - r.ref_logprobs) * mask
                penalty = np.zeros(L, np.float32)
                penalty[mask] = self.pc.kl_coef * kl[mask]
                n_tok = max(int(mask.sum()), 1)
                r.token_rewards = (np.full(L, r.reward / n_tok, np.float32)
                                   * mask - penalty)
            out.append(r)
        return out

    def stage_time(self, n_tokens: int) -> float:
        """Simulated stage latency (flashes) for a batch of tokens."""
        return n_tokens * self.pc.fwd_flashes_per_token / max(
            self.pc.n_chips, 1)
