"""Trainer: the π side of PipelineRL (Algorithm 2, Trainer process).

`train_step` is a pure function (pjit-able with the sharding rules); the
`Trainer` class wraps it with weight-version bookkeeping — each optimizer
step bumps `version`, which is what the in-flight weight update ships to
the generation engine.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.algo import RLConfig, reinforce_loss
from repro.models import model as M
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    version: jax.Array  # == number of optimizer steps taken


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt=adam_init(params),
                      version=jnp.zeros((), jnp.int32))


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            rl: RLConfig):
    out = M.forward(
        params, batch["tokens"], batch["positions"], cfg,
        segment_ids=batch.get("segment_ids"),
        prefix_embeds=batch.get("prefix_embeds"),
    )
    loss, metrics = reinforce_loss(out["logits"], out.get("values"), batch, rl)
    if cfg.n_experts:
        loss = loss + rl.aux_coef * out["aux_loss"]
        metrics["moe_aux"] = out["aux_loss"]
    metrics["loss"] = loss
    return loss, metrics


def train_step(state: TrainState, batch, cfg: ModelConfig, rl: RLConfig,
               adam: AdamConfig, microbatch: int = 1, lr_schedule=None,
               ) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One optimizer step. microbatch > 1 enables gradient accumulation:
    the global batch is split into `microbatch` chunks processed by a scan,
    dividing activation memory by the same factor (beyond-paper memory
    optimization, see EXPERIMENTS.md §Perf)."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if microbatch <= 1:
        (_, metrics), grads = grad_fn(state.params, batch, cfg, rl)
    else:
        def split(x):
            return x.reshape((microbatch, x.shape[0] // microbatch)
                             + x.shape[1:])

        mb = jax.tree.map(split, batch)
        first = jax.tree.map(lambda x: x[0], mb)
        m_shapes = jax.eval_shape(
            lambda p, c: grad_fn(p, c, cfg, rl)[0][1], state.params, first)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
        zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shapes)

        def acc(carry, chunk):
            g_acc, m_acc = carry
            (_, m), g = grad_fn(state.params, chunk, cfg, rl)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / microbatch, g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b / microbatch, m_acc, m)
            return (g_acc, m_acc), None

        (grads, metrics), _ = jax.lax.scan(acc, (zero_g, zero_m), mb)
    lr = lr_schedule(state.opt.step) if lr_schedule is not None else None
    new_params, new_opt, gnorm = adam_update(state.params, grads, state.opt,
                                             adam, lr=lr)
    metrics["grad_norm"] = gnorm
    if lr is not None:
        metrics["lr"] = lr
    return TrainState(new_params, new_opt, state.version + 1), metrics


def make_train_step(cfg: ModelConfig, rl: RLConfig, adam: AdamConfig,
                    donate: bool = True, microbatch: int = 1,
                    lr_schedule=None):
    fn = functools.partial(train_step, cfg=cfg, rl=rl, adam=adam,
                           microbatch=microbatch, lr_schedule=lr_schedule)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


class Trainer:
    """Consumes packed batches, performs optimizer steps, exposes the
    current policy weights + version for in-flight updates."""

    def __init__(self, cfg: ModelConfig, params, rl: RLConfig = RLConfig(),
                 adam: AdamConfig = AdamConfig(), lr_schedule=None):
        self.cfg, self.rl, self.adam = cfg, rl, adam
        self.state = init_train_state(params)
        # no donation: the generation engine aliases these buffers between
        # in-flight updates (the co-sim shares one device)
        self._step = make_train_step(cfg, rl, adam, donate=False,
                                     lr_schedule=lr_schedule)
        self.history: list = []

    @property
    def version(self) -> int:
        return int(self.state.version)

    @property
    def params(self):
        return self.state.params

    def step(self, batch) -> Dict[str, float]:
        self.state, metrics = self._step(self.state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        self.history.append(metrics)
        return metrics
