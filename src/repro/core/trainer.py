"""Trainer: the π side of PipelineRL (Algorithm 2, Trainer process).

`train_step` is a pure function (pjit-able with the sharding rules); the
`Trainer` class wraps it with weight-version bookkeeping — each optimizer
step bumps `version`, which is what the in-flight weight update ships to
the generation engine.

The step loop is *device-resident* (DESIGN.md §6): packed host batches are
staged onto the device in one jitted transfer (one dispatch for the whole
tree, not one blocking copy per field), and per-step metrics stay on
device — `Trainer.step` returns a `LazyMetrics` view and the host syncs
only when (and if) a value is actually read, in one batched `device_get`
per record instead of one blocking `float()` per metric per step.
"""
from __future__ import annotations

import functools
from collections.abc import Mapping
from typing import Any, Dict, Iterator, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.algo import RLConfig, reinforce_loss
from repro.models import model as M
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    version: jax.Array  # == number of optimizer steps taken


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt=adam_init(params),
                      version=jnp.zeros((), jnp.int32))


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            rl: RLConfig):
    tokens = batch["tokens"]
    kw: Dict[str, Any] = {}
    if cfg.fused_loss:
        # next-token targets: position t holds tokens[t+1]; the last column
        # is dead (nothing to predict) and masked by loss alignment anyway
        kw["loss_targets"] = jnp.concatenate(
            [tokens[:, 1:], tokens[:, -1:]], axis=1)
    out = M.forward(
        params, tokens, batch["positions"], cfg,
        segment_ids=batch.get("segment_ids"),
        prefix_embeds=batch.get("prefix_embeds"), **kw,
    )
    if "logits" in out:
        outputs = out["logits"]
    else:  # fused path: per-token stats, no (B,S,V) logits exist
        outputs = {"token_logprobs": out["token_logprobs"],
                   "entropy": out["entropy"]}
    loss, metrics = reinforce_loss(outputs, out.get("values"), batch, rl)
    if cfg.n_experts:
        loss = loss + rl.aux_coef * out["aux_loss"]
        metrics["moe_aux"] = out["aux_loss"]
    metrics["loss"] = loss
    return loss, metrics


def train_step(state: TrainState, batch, cfg: ModelConfig, rl: RLConfig,
               adam: AdamConfig, microbatch: int = 1, lr_schedule=None,
               guard: bool = False, poison=None,
               ) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One optimizer step. microbatch > 1 enables gradient accumulation:
    the global batch is split into `microbatch` chunks processed by a scan,
    dividing activation memory by the same factor (beyond-paper memory
    optimization, see EXPERIMENTS.md §Perf).

    guard=True arms the fused non-finite check (DESIGN.md §10): if the
    global grad norm or the loss is non-finite, the whole update is
    dropped *inside the jitted step* — params/opt/version keep their old
    values via `lax.select`, so a poisoned batch can never write NaN into
    the state — and `metrics["nonfinite"]` reports the skip. The check
    rides on `grad_norm`, which `adam_update` already computes (any
    non-finite gradient leaf makes the global norm non-finite), so the
    healthy path runs the same math and `where(False, old, new)` returns
    `new` bitwise: a guarded healthy run is bit-identical to an
    unguarded one. `poison` (traced bool) replaces the gradients with
    NaN — the §10 `nan_step` fault injection point, inside the step so
    the guard is exercised end to end."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if microbatch <= 1:
        (_, metrics), grads = grad_fn(state.params, batch, cfg, rl)
    else:
        def split(x):
            return x.reshape((microbatch, x.shape[0] // microbatch)
                             + x.shape[1:])

        mb = jax.tree.map(split, batch)
        first = jax.tree.map(lambda x: x[0], mb)
        m_shapes = jax.eval_shape(
            lambda p, c: grad_fn(p, c, cfg, rl)[0][1], state.params, first)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state.params)
        zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shapes)

        def acc(carry, chunk):
            g_acc, m_acc = carry
            (_, m), g = grad_fn(state.params, chunk, cfg, rl)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / microbatch, g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b / microbatch, m_acc, m)
            return (g_acc, m_acc), None

        (grads, metrics), _ = jax.lax.scan(acc, (zero_g, zero_m), mb)
    if guard and poison is not None:
        # fault injection (nan_step): a select, not an add — `g + nan*0`
        # style arithmetic would flip -0.0 grads on the healthy path,
        # while where(False, nan, g) returns g bitwise
        pz = jnp.asarray(poison, bool)
        grads = jax.tree.map(
            lambda g: jnp.where(pz, jnp.full_like(g, jnp.nan), g), grads)
    lr = lr_schedule(state.opt.step) if lr_schedule is not None else None
    new_params, new_opt, gnorm = adam_update(state.params, grads, state.opt,
                                             adam, lr=lr)
    metrics["grad_norm"] = gnorm
    if lr is not None:
        metrics["lr"] = lr
    if guard:
        bad = ~(jnp.isfinite(gnorm) & jnp.isfinite(metrics["loss"]))
        new_params = jax.tree.map(lambda o, n: jnp.where(bad, o, n),
                                  state.params, new_params)
        new_opt = jax.tree.map(lambda o, n: jnp.where(bad, o, n),
                               state.opt, new_opt)
        metrics["nonfinite"] = bad.astype(jnp.float32)
        return TrainState(new_params, new_opt,
                          state.version + jnp.where(bad, 0, 1)), metrics
    return TrainState(new_params, new_opt, state.version + 1), metrics


def make_train_step(cfg: ModelConfig, rl: RLConfig, adam: AdamConfig,
                    donate: bool = True, microbatch: int = 1,
                    lr_schedule=None, guard: bool = False):
    fn = functools.partial(train_step, cfg=cfg, rl=rl, adam=adam,
                           microbatch=microbatch, lr_schedule=lr_schedule,
                           guard=guard)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


class LazyMetrics(Mapping):
    """Device-resident metrics record. Holding one costs no host sync; the
    first key access fetches *all* values in one batched `device_get` and
    caches them as python floats."""

    def __init__(self, dev: Dict[str, jax.Array]):
        self._dev = dev
        self._host: Optional[Dict[str, float]] = None

    def fetch(self) -> Dict[str, float]:
        if self._host is None:
            self._host = {k: float(v)
                          for k, v in jax.device_get(self._dev).items()}
            self._dev = {}
        return self._host

    def peek(self, k: str) -> float:
        """Fetch ONE metric without materializing the record: a single
        tiny scalar transfer, so per-step guard polling (DESIGN.md §10)
        does not force the full batched sync the lazy design avoids."""
        if self._host is not None:
            return self._host[k]
        return float(jax.device_get(self._dev[k]))

    def __getitem__(self, k: str) -> float:
        return self.fetch()[k]

    def __iter__(self) -> Iterator[str]:
        return iter(self._host if self._host is not None else self._dev)

    def __len__(self) -> int:
        return len(self._host if self._host is not None else self._dev)

    def __repr__(self) -> str:
        state = "synced" if self._host is not None else "on-device"
        return f"LazyMetrics({state}: {list(self)})"


# batch fields the train step does not consume (bookkeeping riding along
# in pack() output); dropped before staging so no dead transfers happen
_NON_MODEL_KEYS = ("packing_stats", "weight_versions")
# staleness-contract fields (pack(..., trainer_version=...)): consumed by
# the loss only when a lag mode is armed; dropped otherwise so the "off"
# staging (and therefore the whole step) stays bit-identical to pre-lag
_LAG_KEYS = ("lag", "truncated")


class Trainer:
    """Consumes packed batches, performs optimizer steps, exposes the
    current policy weights + version for in-flight updates."""

    def __init__(self, cfg: ModelConfig, params, rl: RLConfig = RLConfig(),
                 adam: AdamConfig = AdamConfig(), lr_schedule=None,
                 guard: bool = True, mesh=None, rules=None):
        self.cfg, self.rl, self.adam = cfg, rl, adam
        self.state = init_train_state(params)
        self.guard = bool(guard)
        self.nonfinite_steps = 0   # updates dropped by the in-step guard
        # real-mesh placement (DESIGN.md §11): params/opt state live in
        # the FSDP+TP train layout from `state_shardings`; the step runs
        # under `sharding_context` so `constrain` annotations bind, and
        # staged batches land replicated on the mesh (their sharding is
        # decided by GSPMD inside the step)
        self.mesh, self.rules = mesh, rules
        if mesh is not None:
            from repro.launch.steps import abstract_train_state, \
                state_shardings
            ann, _ = abstract_train_state(cfg)
            self.state = jax.device_put(self.state,
                                        state_shardings(ann, mesh, rules))
        # no donation of the state: the generation engine aliases these
        # buffers between in-flight updates (the co-sim shares one device)
        self._step = make_train_step(cfg, rl, adam, donate=False,
                                     lr_schedule=lr_schedule,
                                     guard=self.guard)
        # jitted staging: one dispatch moves the whole packed batch to the
        # device (vs one blocking transfer per field, like PR 1's `_admit`
        # killed the per-array admission copies). The staged copy is
        # trainer-owned, so its buffers free at their last use inside the
        # step; explicit donation would add nothing (XLA donation aliases
        # inputs to *outputs* only, and a consumed batch has no matching
        # output — it would just warn "donated buffers were not usable").
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            self._stage = jax.jit(
                lambda b: b,
                out_shardings=NamedSharding(mesh, PartitionSpec()))
        else:
            self._stage = jax.jit(lambda b: b)
        self.history: List[LazyMetrics] = []

    def _ctx(self):
        if self.mesh is None:
            import contextlib
            return contextlib.nullcontext()
        from repro.shardctx import sharding_context
        return sharding_context(self.mesh, self.rules)

    @property
    def version(self) -> int:
        return int(self.state.version)

    @property
    def params(self):
        return self.state.params

    def step(self, batch, poison: bool = False) -> LazyMetrics:
        """One optimizer step. `batch` may be host numpy (the pack()
        output — staged on device in one jitted transfer) or already
        device-resident (used as-is). Returns a `LazyMetrics` view;
        nothing syncs to host unless a metric value is actually read.
        `poison` (guard mode only) injects NaN gradients inside the step
        — the §10 `nan_step` fault; the guard must catch it."""
        drop = _NON_MODEL_KEYS if self.rl.lag_mode != "off" \
            else _NON_MODEL_KEYS + _LAG_KEYS
        batch = {k: v for k, v in batch.items() if k not in drop}
        with self._ctx():
            if not all(isinstance(v, jax.Array) for v in batch.values()):
                batch = self._stage(batch)
            if self.guard:
                self.state, metrics = self._step(self.state, batch,
                                                 poison=poison)
            else:
                self.state, metrics = self._step(self.state, batch)
        m = LazyMetrics(metrics)
        self.history.append(m)
        return m

    def last_nonfinite(self) -> bool:
        """Guard verdict of the newest step — did the fused non-finite
        check drop the update? One scalar `peek`, not a full sync."""
        if not self.guard or not self.history:
            return False
        bad = self.history[-1].peek("nonfinite") > 0.0
        if bad:
            self.nonfinite_steps += 1
        return bad

    # ---- crash-restart checkpointing (DESIGN.md §8) -------------------
    def save(self, path: str) -> str:
        """Atomic checkpoint of the full TrainState (params + optimizer
        moments + version) — everything a crash-restart needs to resume
        with a bit-identical next optimizer step."""
        from repro.checkpoint import checkpoint
        checkpoint.save(path, self.state)
        return checkpoint._norm(path)

    def restore(self, path: str) -> int:
        """Restore params/opt-state/version from `path`; returns the
        restored version. The compiled step function is untouched (same
        cfg), so the next `step` after a restore is bit-identical to the
        step an uninterrupted run would have taken on the same batch."""
        from repro.checkpoint import checkpoint
        loaded = checkpoint.load(path, self.state)
        self.state = jax.tree.map(jnp.asarray, loaded)
        if self.mesh is not None:
            from repro.launch.steps import abstract_train_state, \
                state_shardings
            ann, _ = abstract_train_state(self.cfg)
            self.state = jax.device_put(
                self.state, state_shardings(ann, self.mesh, self.rules))
        return self.version

    def fetch_metrics(self) -> List[Dict[str, float]]:
        """Materialize the whole history in one batched device_get (the
        on-demand sync point of the device-resident loop)."""
        pending = [m for m in self.history if m._host is None]
        if pending:
            fetched = jax.device_get([m._dev for m in pending])
            for m, h in zip(pending, fetched):
                m._host = {k: float(v) for k, v in h.items()}
                m._dev = {}
        return [m.fetch() for m in self.history]
