"""Sample queue between Actor and Trainer (the Redis stream of the paper's
implementation, collapsed to an in-process ring buffer — single-controller
JAX has no network hop between stages, but the back-pressure semantics are
preserved: a bounded buffer that drops the *oldest* samples keeps lag
minimal when the trainer stalls, e.g. during a checkpoint)."""
from __future__ import annotations

from collections import deque
from typing import List, Optional

from repro.data.packing import Rollout


class QueueUnderflow(ValueError):
    """`pop(n)` asked for more rollouts than the queue holds. Carries the
    observed `depth` and the `requested` count so stage code can tell
    starvation (depth shrank under it — wait and re-kick) from a bug
    (requested more than the stage's own batch size). Subclasses
    ValueError so pre-existing handlers keep working."""

    def __init__(self, depth: int, requested: int):
        self.depth, self.requested = depth, requested
        super().__init__(f"queue has {depth} < {requested}")


class SampleQueue:
    def __init__(self, maxsize: Optional[int] = None):
        self.buf: deque = deque()
        self.maxsize = maxsize
        self.dropped = 0
        self.total_put = 0
        self.requeued = 0         # salvage re-insertions (recovery path)
        self.high_watermark = 0   # max depth seen (trainer-stall telemetry)

    def put(self, rollouts: List[Rollout]) -> None:
        for r in rollouts:
            self.buf.append(r)
            self.total_put += 1
            # sample depth BEFORE the drop: the intra-put peak (maxsize+1
            # while a drop is pending) is the telemetry that shows the
            # queue actually overflowed, not merely sat full
            self.high_watermark = max(self.high_watermark, len(self.buf))
            if self.maxsize is not None and len(self.buf) > self.maxsize:
                self.buf.popleft()  # ring-buffer semantics: drop oldest
                self.dropped += 1

    def requeue_front(self, rollouts: List[Rollout]) -> None:
        """Recovery path: put salvaged rollouts back at the FRONT of the
        queue in their original order (they are the oldest samples, so
        they must be the first ones the next pop sees and the first ones
        a drop-oldest overflow evicts). Does not inflate `total_put` —
        these samples were already counted when first produced; `requeued`
        tracks the salvage traffic separately. maxsize still holds: if
        re-insertion overflows the queue, the oldest (i.e. the salvaged)
        samples are dropped."""
        for r in reversed(rollouts):
            self.buf.appendleft(r)
            self.requeued += 1
            self.high_watermark = max(self.high_watermark, len(self.buf))
        while self.maxsize is not None and len(self.buf) > self.maxsize:
            self.buf.popleft()
            self.dropped += 1

    def pop(self, n: int) -> List[Rollout]:
        if len(self.buf) < n:
            raise QueueUnderflow(len(self.buf), n)
        return [self.buf.popleft() for _ in range(n)]

    def __len__(self) -> int:
        return len(self.buf)
