"""Conventional RL baseline (Algorithm 1): alternate full-fleet generation
of B*G sequences with G optimizer steps; the behavior policy lags the
current policy by up to G-1 steps. Same engine, same trainer, same
simulated clock — only the schedule differs."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pipeline import _lag_stats
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.data.packing import pack


@dataclasses.dataclass
class ConventionalConfig:
    batch_size: int = 16          # B per optimizer step
    g_steps: int = 4              # G optimizer steps per RL step
    n_opt_steps: int = 48
    n_chips: int = 8              # all chips generate, then all train
    pack_rows: int = 8
    pack_seq: int = 128


class ConventionalRL:
    def __init__(self, cfg: ModelConfig, params, task: MathTask,
                 ec: EngineConfig, cc: ConventionalConfig,
                 hw: HardwareModel = HardwareModel(),
                 trainer: Optional[Trainer] = None, seed: int = 0):
        if ec.n_slots < cc.batch_size * cc.g_steps:
            ec = dataclasses.replace(ec, n_slots=cc.batch_size * cc.g_steps)
        self.cfg, self.task, self.ec, self.cc, self.hw = cfg, task, ec, cc, hw
        self.trainer = trainer or Trainer(cfg, params)
        self.engine = GenerationEngine(cfg, self.trainer.params, ec,
                                       task.sample, seed=seed)
        self.time = 0.0
        self.log: List[Dict] = []

    def run(self, n_opt_steps: Optional[int] = None) -> List[Dict]:
        n = n_opt_steps or self.cc.n_opt_steps
        cc, hw = self.cc, self.hw
        while self.trainer.version < n:
            # --- generation phase: mu <- pi, drain B*G sequences ---------
            self.engine.set_weights(self.trainer.params, self.trainer.version)
            self.engine.refill(self.time)
            # chunked-prefill admission is batched prefill FLOPs on the
            # fleet (the legacy forcing loop charges decode steps instead)
            self.time += hw.prefill_time(
                self.engine.last_admit_prefill_tokens, cc.n_chips)
            rollouts = []
            while self.engine.n_active > 0:
                h = self.engine.n_active
                finished = self.engine.step(self.task, now=self.time)
                self.time += hw.step_cost(h / cc.n_chips)
                for r in finished:
                    r.finished_at = self.time
                rollouts.extend(finished)
            # --- training phase: G optimizer steps -----------------------
            order = np.random.RandomState(self.trainer.version).permutation(
                len(rollouts))
            for g in range(cc.g_steps):
                idx = order[g * cc.batch_size:(g + 1) * cc.batch_size]
                chunk = [rollouts[i] for i in idx]
                batch = pack(chunk, cc.pack_rows, cc.pack_seq)
                stats = batch.pop("packing_stats")
                # host batch goes straight in: the trainer stages it with
                # one jitted donated transfer (DESIGN.md §6)
                metrics = self.trainer.step(batch)
                n_tokens = sum(r.length for r in chunk)
                self.time += hw.train_time(n_tokens, cc.n_chips)
                max_lag, mean_lag = _lag_stats(chunk, self.trainer.version - 1)
                self.log.append({
                    "version": self.trainer.version,
                    "samples": self.trainer.version * cc.batch_size,
                    "time": self.time,
                    "reward": float(np.mean([r.reward for r in chunk])),
                    "mean_len": float(np.mean([r.length for r in chunk])),
                    "max_lag": max_lag,
                    "mean_lag": mean_lag,
                    "fill": stats["fill"],
                    **metrics,
                })
        return self.log
