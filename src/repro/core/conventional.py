"""Conventional RL baseline (Algorithm 1): alternate full-fleet generation
of B*G sequences with G optimizer steps; the behavior policy lags the
current policy by up to G-1 steps. Same engine, same trainer, same
simulated clock — and, since DESIGN.md §7, the same event-driven
substrate as PipelineRL: the alternating schedule is expressed as an
`ActorStage` that drains without refilling (`on_drained` hands control to
the `TrainerStage`) and a trainer whose G-th completion restarts the
generation phase. Only the configuration differs, not the loop.

The phase-boundary weight sync is costed: the fleet sits idle for
`HardwareModel.broadcast_time` of the full param tree before every
generation phase (the conventional analogue of the in-flight broadcast
pause, charged to the same clock so the Fig. 5 comparison is fair)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.events import (
    ActorStage, EventLoop, TrainerStage, tree_bytes,
)
from repro.core.pipeline import _lag_stats  # noqa: F401  (legacy export)
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask


@dataclasses.dataclass
class ConventionalConfig:
    batch_size: int = 16          # B per optimizer step
    g_steps: int = 4              # G optimizer steps per RL step
    n_opt_steps: int = 48
    n_chips: int = 8              # all chips generate, then all train
    pack_rows: int = 8
    pack_seq: int = 128


class ConventionalRL:
    def __init__(self, cfg: ModelConfig, params, task: MathTask,
                 ec: EngineConfig, cc: ConventionalConfig,
                 hw: HardwareModel = HardwareModel(),
                 trainer: Optional[Trainer] = None, seed: int = 0):
        if ec.n_slots < cc.batch_size * cc.g_steps:
            ec = dataclasses.replace(ec, n_slots=cc.batch_size * cc.g_steps)
        self.cfg, self.task, self.ec, self.cc, self.hw = cfg, task, ec, cc, hw
        self.trainer = trainer or Trainer(cfg, params)
        self.engine = GenerationEngine(cfg, self.trainer.params, ec,
                                       task.sample, seed=seed)
        self.log: List[Dict] = []
        self.loop = EventLoop()
        self._started = False
        self.trainer_stage = TrainerStage(
            self.loop, self.trainer,
            train_time=lambda n: hw.train_time(n, cc.n_chips),
            pack_rows=cc.pack_rows, pack_seq=cc.pack_seq, log=self.log,
            samples_per_step=cc.batch_size)
        self._rollouts: List = []
        self.actor = ActorStage(
            self.loop, self.engine, task=task, name="fleet",
            step_cost=lambda h: hw.step_cost(h / cc.n_chips),
            auto_refill=False,
            deliver=lambda rollouts, t: self._rollouts.extend(rollouts),
            on_drained=self._train_phase)

    @property
    def time(self) -> float:
        return self.loop.now

    # ----- phases (event callbacks, not a loop) -------------------------
    def _generation_phase(self, now: float) -> None:
        """mu <- pi (the fleet idles for the weight transfer), then admit
        B*G prompts and drain them without refilling."""
        t = now + self.hw.broadcast_time(tree_bytes(self.trainer.params))
        self.engine.set_weights(self.trainer.params, self.trainer.version)
        self._rollouts = []
        self.engine.refill(t)
        # chunked-prefill admission is batched prefill FLOPs on the fleet
        # (the legacy forcing loop charges decode steps instead)
        t += self.hw.prefill_time(self.engine.last_admit_prefill_tokens,
                                  self.cc.n_chips)
        self.actor.start(t)

    def _train_phase(self, now: float) -> None:
        """Drained: G optimizer steps over a fixed shuffle of the phase's
        rollouts; the G-th completion starts the next generation phase."""
        cc = self.cc
        rollouts = self._rollouts
        order = np.random.RandomState(self.trainer.version).permutation(
            len(rollouts))
        for g in range(cc.g_steps):
            idx = order[g * cc.batch_size:(g + 1) * cc.batch_size]
            chunk = [rollouts[i] for i in idx]
            self.trainer_stage.submit(
                chunk, now,
                on_done=(self._generation_phase
                         if g == cc.g_steps - 1 else None))

    # ----- run ----------------------------------------------------------
    def run(self, n_opt_steps: Optional[int] = None) -> List[Dict]:
        n = n_opt_steps or self.cc.n_opt_steps
        if not self._started:
            self._started = True
            self.loop.post(self.loop.now, self._generation_phase)
        self.loop.run(until=lambda: self.trainer.version >= n)
        return self.log
