"""Periodic greedy evaluation during RL training (the paper's MATH500/AIME
evals, at testbed scale): success rate over a fixed held-out problem set."""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.data.math_task import MathTask, Problem


class Evaluator:
    def __init__(self, cfg: ModelConfig, task: MathTask, n_problems: int = 32,
                 max_len: int = 16, seed: int = 1234):
        self.cfg, self.task = cfg, task
        eval_task = MathTask(max_operand=task.max_operand, ops=task.ops,
                             seed=seed)
        self.problems: List[Problem] = eval_task.sample_batch(n_problems)
        self.max_len = max_len

    def evaluate(self, params) -> dict:
        probs = list(self.problems)
        it = iter(probs)

        def source():
            try:
                return next(it)
            except StopIteration:  # engine refills past the set; recycle
                return probs[0]

        ec = EngineConfig(n_slots=len(probs), max_len=self.max_len,
                          temperature=1e-4)  # ~greedy
        eng = GenerationEngine(self.cfg, params, ec, source, seed=0)
        eng.refill()
        rollouts = []
        for _ in range(self.max_len + 2):
            rollouts.extend(eng.step(self.task))
            if eng.n_active == 0:
                break
        if not rollouts:
            return {"success_rate": 0.0, "mean_len": 0.0, "n": 0}
        succ = float(np.mean([r.reward > 0.5 for r in rollouts]))
        return {
            "success_rate": succ,
            "mean_len": float(np.mean([r.length - r.prompt_len
                                       for r in rollouts])),
            "n": len(rollouts),
        }
