"""Event-driven orchestration substrate (DESIGN.md §7).

One discrete-event scheduler replaces the three bespoke orchestration
loops (`PipelineRL.run`, `ConventionalRL.run`, `Server.step`): stages
post callbacks onto a shared simulated clock and react to each other's
completions. `PipelineRL`, `ConventionalRL` and `Server` become
*configurations* of the same stage library rather than separate control
flows — which is what lets the orchestration layer grow new scenarios
(actor pools, overlapped preprocessing, costed weight broadcast, trainer
stalls) without forking the loop again.

Stage contracts (all times are simulated flashes unless a stage installs
its own cost model, e.g. the Server's step-denominated clock):

  ActorStage        owns one `GenerationEngine`; self-schedules decode
                    ticks; at each tick boundary it first installs any
                    arrived weight publications (atomic swaps or streamed
                    chunks — the *only* place weights may change, so
                    per-token version stamps stay exact), then steps the
                    engine, delivers finished rollouts downstream, and
                    refills. Goes idle when the engine drains and
                    `auto_refill` is off (ConventionalRL's phase end) or
                    when externally driven (`chain=False`, the Server).
                    `preempt(at, d)` takes the engine offline for
                    [at, at+d): ticks starting inside the window defer to
                    its end; in-flight slots are untouched and resume.
  PoolRouter        pluggable admission between one shared prompt source
                    and the pool's engines: fifo (pass-through pull,
                    today's behavior), shortest_queue (decline engines
                    whose speed-normalized backlog is deep), and
                    length_affinity (buffer `lookahead` pending prompts;
                    fast engines take the longest, slow the shortest —
                    long-prompt prefill lands on the cheapest compute).
  PreprocessStage   pulls B rollouts from the SampleQueue when free,
                    holds them for `stage_time`, delivers the processed
                    batch to the trainer — an *overlapped* stage on its
                    own chips (paper Fig. 4), not latency serialized into
                    the trainer tick. It runs at most one batch ahead so
                    back-pressure still lands on the SampleQueue (whose
                    drop-oldest policy is what bounds lag).
  TrainerStage      consumes batches (from its inbox or by pulling from
                    the queue), runs the real optimizer step eagerly,
                    stamps completion on the clock, publishes weights
                    through the WeightBroadcaster every `update_every`
                    versions, and can stall for checkpoints.
  WeightBroadcaster turns a publication into per-engine delivery
                    schedules costed by `HardwareModel.broadcast_time`:
                    atomic (engine pauses for the whole transfer) or
                    streamed (chunks overlap decode; the engine only
                    pauses `bcast_install_flash` per installed chunk and
                    pointer-swaps on the last one).

  FaultPlan          failure as a first-class, deterministic event source
                    (DESIGN.md §8): scripted or seed-deterministic
                    stochastic faults — engine crash (permanent or
                    restart-after-delay), trainer crash with
                    checkpoint-restore, preprocessor failure (in-flight
                    batch's samples re-queued, not lost), and interconnect
                    degradation windows under which streamed broadcast
                    chunks are lost and retransmitted with capped
                    exponential backoff. All decisions are functions of
                    (seed, fault identity, counter) — never of wall-clock
                    or iteration order — so two identical-seed chaos runs
                    are bit-equal.

Clock invariants: events fire in nondecreasing time order (FIFO on
ties); a stage's own timeline is nondecreasing; rollout `finished_at`
stamps are the actor-tick completion times, so `SampleQueue` arrival
order is consistent with the simulated clock.
"""
from __future__ import annotations

import dataclasses
import heapq
import os
import re
import struct
import zlib
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.packing import Rollout, pack


# ---------------------------------------------------------------------------
# event loop
# ---------------------------------------------------------------------------

class EventLoop:
    """Minimal deterministic discrete-event scheduler: a time-ordered heap
    of callbacks with FIFO tie-breaking. `run(until=...)` processes events
    until the predicate holds or the heap drains; pending events survive,
    so orchestrators built on top are resumable (`run(n)` then `run(m)`)."""

    def __init__(self):
        self._heap: List[Tuple[float, int, Callable[[float], None]]] = []
        self._seq = 0
        self.now = 0.0
        self.events_processed = 0

    def post(self, time: float, fn: Callable[[float], None]) -> None:
        """Schedule `fn(fire_time)`. Times before `now` are clamped to
        `now` (a stage may not rewind the clock)."""
        heapq.heappush(self._heap, (max(time, self.now), self._seq, fn))
        self._seq += 1

    def __len__(self) -> int:
        return len(self._heap)

    def step(self) -> bool:
        """Process the earliest event; False if none remain."""
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self.now = t
        self.events_processed += 1
        fn(t)
        return True

    def run(self, until: Optional[Callable[[], bool]] = None,
            max_events: int = 10_000_000) -> None:
        for _ in range(max_events):
            if until is not None and until():
                return
            if not self.step():
                return
        raise RuntimeError("EventLoop.run exceeded max_events — "
                           "a stage is posting events without progress")


# ---------------------------------------------------------------------------
# param-tree helpers (shared by the engine's stream API, the broadcaster's
# costing and the launcher's chunked weight-update lowering)
# ---------------------------------------------------------------------------

def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (anything with .size/.dtype)."""
    import jax
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def chunk_spans(leaves: Sequence[Any], n_chunks: int) -> List[Tuple[int, int]]:
    """Partition a leaf list into <= n_chunks contiguous, byte-balanced
    [lo, hi) spans — the layer-chunked publication unit of the streamed
    broadcast. Leaf granularity keeps the swap trivially exact (a leaf is
    never split across chunks)."""
    n_chunks = max(int(n_chunks), 1)
    sizes = [int(x.size * x.dtype.itemsize) for x in leaves]
    total = sum(sizes)
    if not leaves:
        return []
    target = total / n_chunks
    spans: List[Tuple[int, int]] = []
    lo, acc = 0, 0
    for i, s in enumerate(sizes):
        acc += s
        # close the span once it reaches the byte target, keeping enough
        # leaves for the remaining chunks to be non-empty
        remaining_chunks = n_chunks - len(spans)
        remaining_leaves = len(leaves) - (i + 1)
        if (acc >= target and remaining_chunks > 1) or \
                remaining_leaves < remaining_chunks - 1:
            if i + 1 > lo:
                spans.append((lo, i + 1))
                lo, acc = i + 1, 0
        if len(spans) == n_chunks - 1:
            break
    if lo < len(leaves):
        spans.append((lo, len(leaves)))
    return spans


def span_bytes(leaves: Sequence[Any],
               spans: Sequence[Tuple[int, int]]) -> List[int]:
    return [int(sum(x.size * x.dtype.itemsize for x in leaves[lo:hi]))
            for lo, hi in spans]


def chunk_token(version: int, k: int, nbytes: int) -> int:
    """Integrity checksum carried with streamed chunk `k` of publication
    `version` (DESIGN.md §10). Sender and receiver compute it
    independently from the publication identity and their own span
    tables (`chunk_spans` is deterministic, so both sides agree on
    `nbytes`); a damaged transmission surfaces as a token mismatch and
    is rejected before it can touch the shadow buffer."""
    return zlib.crc32(struct.pack("<qqq", int(version), int(k),
                                  int(nbytes)))


def stream_digest(tokens: Sequence[int]) -> int:
    """Whole-publication checksum: CRC over the in-order chunk tokens.
    Verified by the engine immediately before the pointer swap, so a
    torn or misassembled stream can never install."""
    d = 0
    for t in tokens:
        d = zlib.crc32(struct.pack("<q", int(t)), d)
    return d


# ---------------------------------------------------------------------------
# fault plan (DESIGN.md §8 failure model)
# ---------------------------------------------------------------------------

# retransmit backstop: after this many lost transmissions of one chunk the
# broadcaster delivers it anyway (a drop_prob<1 link terminates w.p. 1, but
# a scripted drop_prob=1 window must not spin forever)
_MAX_XMIT_ATTEMPTS = 16


@dataclasses.dataclass
class Fault:
    """One scheduled fault.

    Fail-stop kinds (DESIGN.md §8):

      engine_crash     kill engine `engine` at `at` mid-decode (in-flight
                       rollouts lost, prompts salvaged); restart after
                       `restart_after` flashes, or permanent when None
      trainer_crash    kill the trainer at `at` (in-flight step lost);
                       restart from the last checkpoint after
                       `restart_after` (None = permanent)
      preprocess_fail  transient preprocessor failure at `at`: the
                       in-flight batch's samples re-enter the SampleQueue
      link_degrade     for [at, at+duration), streamed broadcast chunks to
                       engine `engine` (None = every engine) are lost with
                       probability `drop_prob` per transmission

    Gray kinds (DESIGN.md §10 — the process survives but misbehaves):

      engine_slowdown  for [at, at+duration), engine `engine`'s compute
                       costs are multiplied by `factor` (>1): a degraded
                       chip / noisy neighbor. The engine keeps working —
                       the HealthMonitor's straggler detector is what
                       notices and demotes it in the PoolRouter.
      engine_hang      at `at`, engine `engine` stops completing ticks
                       WITHOUT crashing (wedged process: slots held, no
                       heartbeats). Only the HealthMonitor's watchdog can
                       recover it — escalation runs the fail/salvage/
                       requeue path; `restart_after` (from *detection*)
                       schedules the restart, None = stays down.
      chunk_corrupt    for [at, at+duration), streamed broadcast chunks
                       to engine `engine` (None = all) arrive *damaged*
                       with probability `drop_prob` per transmission: the
                       per-chunk checksum gate detects them, the install
                       is blocked, and the chunk retransmits via the
                       same backoff machinery as a loss.
      nan_step         the next `count` optimizer steps started at or
                       after `at` produce non-finite gradients (the
                       trainer's in-step guard must skip them).
      poison_prompt    the `at`-th prompt drawn from the shared source
                       (an ordinal, not a time) deterministically wedges
                       whichever engine decodes it — the watchdog +
                       K-attempt quarantine path is what breaks the
                       crash-loop.
    """
    kind: str
    at: float
    engine: Optional[int] = None
    restart_after: Optional[float] = None
    duration: float = 0.0
    drop_prob: float = 1.0
    factor: float = 1.0      # engine_slowdown cost multiplier
    count: int = 1           # nan_step: consecutive poisoned steps


def _fault_sort_key(f: Fault):
    """Total, None-safe ordering for fault schedules: `engine=None`
    (pool-wide) sorts before any numbered engine instead of colliding
    with `engine=0`, and every remaining field participates so plan
    determinism never depends on insertion order."""
    return (f.at, f.kind,
            f.engine is not None, -1 if f.engine is None else f.engine,
            f.restart_after is not None,
            -1.0 if f.restart_after is None else f.restart_after,
            f.duration, f.drop_prob, f.factor, f.count)


class FaultPlan:
    """Deterministic, replayable fault schedule for the event substrate.

    Faults are injected by the orchestrator (`PipelineRL._schedule_faults`)
    as ordinary events on the simulated clock, so failure interleaves with
    decode/train/broadcast exactly like any other stage activity — and the
    chunk-loss oracle is counter-based (`default_rng((seed, tag, engine,
    version, chunk, attempt))`), i.e. a pure function of the fault identity
    rather than of draw order. Two runs with the same plan (same seed for
    `chaos()` plans) therefore produce bit-identical rollout streams.

    Build scripted plans with the fluent helpers::

        FaultPlan().engine_crash(300.0, engine=1, restart_after=150.0) \\
                   .degrade_link(200.0, duration=100.0, drop_prob=0.5)

    or seed-deterministic stochastic ones with `FaultPlan.chaos(seed, ...)`,
    or parse the launcher's compact `--fault-plan` spec with `parse()`.
    """

    def __init__(self, faults: Sequence[Fault] = (), seed: int = 0):
        self.faults: List[Fault] = list(faults)
        self.seed = int(seed)

    # ---- fluent builders ----------------------------------------------
    def add(self, fault: Fault) -> "FaultPlan":
        self.faults.append(fault)
        return self

    def engine_crash(self, at: float, engine: int = 0,
                     restart_after: Optional[float] = None) -> "FaultPlan":
        return self.add(Fault("engine_crash", float(at), engine=int(engine),
                              restart_after=restart_after))

    def trainer_crash(self, at: float,
                      restart_after: Optional[float] = None) -> "FaultPlan":
        return self.add(Fault("trainer_crash", float(at),
                              restart_after=restart_after))

    def preprocess_fail(self, at: float) -> "FaultPlan":
        return self.add(Fault("preprocess_fail", float(at)))

    def degrade_link(self, at: float, duration: float,
                     engine: Optional[int] = None,
                     drop_prob: float = 1.0) -> "FaultPlan":
        return self.add(Fault("link_degrade", float(at),
                              engine=None if engine is None else int(engine),
                              duration=float(duration),
                              drop_prob=float(drop_prob)))

    # ---- gray-fault builders (DESIGN.md §10) --------------------------
    def engine_slowdown(self, at: float, duration: float, engine: int = 0,
                        factor: float = 4.0) -> "FaultPlan":
        return self.add(Fault("engine_slowdown", float(at),
                              engine=int(engine), duration=float(duration),
                              factor=float(factor)))

    def engine_hang(self, at: float, engine: int = 0,
                    restart_after: Optional[float] = None) -> "FaultPlan":
        return self.add(Fault("engine_hang", float(at), engine=int(engine),
                              restart_after=restart_after))

    def chunk_corrupt(self, at: float, duration: float,
                      engine: Optional[int] = None,
                      drop_prob: float = 1.0) -> "FaultPlan":
        return self.add(Fault("chunk_corrupt", float(at),
                              engine=None if engine is None else int(engine),
                              duration=float(duration),
                              drop_prob=float(drop_prob)))

    def nan_step(self, at: float, count: int = 1) -> "FaultPlan":
        return self.add(Fault("nan_step", float(at), count=int(count)))

    def poison_prompt(self, ordinal: int) -> "FaultPlan":
        """Poison the `ordinal`-th prompt drawn from the shared source
        (`at` holds the ordinal — the 'when' of this fault is a draw
        index, not a clock time)."""
        return self.add(Fault("poison_prompt", float(int(ordinal))))

    # ---- stochastic generation ----------------------------------------
    @classmethod
    def chaos(cls, seed: int, horizon: float, n_engines: int = 1,
              n_crashes: int = 2, mean_outage: Optional[float] = None,
              link_windows: int = 1, drop_prob: float = 0.3,
              trainer_crashes: int = 0,
              slowdowns: int = 0, slow_factor: float = 4.0,
              hangs: int = 0, corrupt_windows: int = 0,
              corrupt_prob: float = 0.3, nan_bursts: int = 0,
              poison_prompts: int = 0) -> "FaultPlan":
        """Seed-deterministic stochastic churn over `horizon` flashes:
        `n_crashes` engine kill/restore pairs (spot-instance churn),
        `link_windows` interconnect-degradation windows, and optional
        trainer crashes. The gray knobs (`slowdowns`, `hangs`,
        `corrupt_windows`, `nan_bursts`, `poison_prompts` — all default
        0, so pre-existing plans reproduce draw-for-draw) add the
        §10 gray fault kinds from the same seed stream. Same seed =>
        same plan, draw for draw."""
        rng = np.random.default_rng(int(seed))
        plan = cls(seed=seed)
        mean_outage = horizon / 8 if mean_outage is None else mean_outage
        for _ in range(max(int(n_crashes), 0)):
            plan.engine_crash(
                at=float(rng.uniform(0.05, 0.7)) * horizon,
                engine=int(rng.integers(max(n_engines, 1))),
                restart_after=float(rng.exponential(mean_outage)) + 1.0)
        for _ in range(max(int(link_windows), 0)):
            plan.degrade_link(
                at=float(rng.uniform(0.0, 0.8)) * horizon,
                duration=float(rng.uniform(0.05, 0.25)) * horizon,
                drop_prob=drop_prob)
        for _ in range(max(int(trainer_crashes), 0)):
            plan.trainer_crash(
                at=float(rng.uniform(0.2, 0.8)) * horizon,
                restart_after=float(rng.exponential(mean_outage)) + 1.0)
        # gray kinds — drawn after the fail-stop kinds so plans built
        # before these knobs existed keep their exact draw sequence
        for _ in range(max(int(slowdowns), 0)):
            plan.engine_slowdown(
                at=float(rng.uniform(0.05, 0.6)) * horizon,
                duration=float(rng.uniform(0.1, 0.3)) * horizon,
                engine=int(rng.integers(max(n_engines, 1))),
                factor=float(slow_factor))
        for _ in range(max(int(hangs), 0)):
            plan.engine_hang(
                at=float(rng.uniform(0.05, 0.6)) * horizon,
                engine=int(rng.integers(max(n_engines, 1))),
                restart_after=float(rng.exponential(mean_outage)) + 1.0)
        for _ in range(max(int(corrupt_windows), 0)):
            plan.chunk_corrupt(
                at=float(rng.uniform(0.0, 0.8)) * horizon,
                duration=float(rng.uniform(0.05, 0.25)) * horizon,
                drop_prob=corrupt_prob)
        for _ in range(max(int(nan_bursts), 0)):
            plan.nan_step(
                at=float(rng.uniform(0.1, 0.8)) * horizon,
                count=int(rng.integers(1, 3)))
        for _ in range(max(int(poison_prompts), 0)):
            plan.poison_prompt(int(rng.integers(2, 40)))
        plan.faults.sort(key=_fault_sort_key)
        return plan

    # ---- chunk-loss oracle (consulted by WeightBroadcaster) -----------
    def has_link_faults(self) -> bool:
        """Any fault that perturbs streamed chunk transmission — loss or
        corruption.  The broadcaster only takes the serialized lossy-
        arrivals path when this is true, so healthy plans keep the exact
        pre-fault arrival arithmetic (bit-equality of healthy runs)."""
        return any(f.kind in ("link_degrade", "chunk_corrupt")
                   for f in self.faults)

    def chunk_lost(self, engine: int, version: int, chunk: int,
                   attempt: int, t: float) -> bool:
        """Is transmission `attempt` of chunk `chunk` of publication
        `version` to `engine`, scheduled at time `t`, lost? Deterministic:
        the Bernoulli draw is keyed on the fault identity, not draw order,
        so replays agree regardless of event interleaving."""
        for f in self.faults:
            if f.kind != "link_degrade":
                continue
            if f.engine is not None and f.engine != engine:
                continue
            if not (f.at <= t < f.at + f.duration):
                continue
            if f.drop_prob >= 1.0:
                return True
            rng = np.random.default_rng(
                (self.seed, 0x10ED, int(engine), int(version), int(chunk),
                 int(attempt)))
            return bool(rng.random() < f.drop_prob)
        return False

    def chunk_corrupted(self, engine: int, version: int, chunk: int,
                        attempt: int, t: float) -> bool:
        """Does transmission `attempt` of chunk `chunk` of publication
        `version` to `engine`, scheduled at `t`, arrive *damaged*?
        Counter-keyed like `chunk_lost` (distinct tag) so replays agree
        regardless of event interleaving. A corrupt chunk is delivered —
        the engine's checksum gate must reject it."""
        for f in self.faults:
            if f.kind != "chunk_corrupt":
                continue
            if f.engine is not None and f.engine != engine:
                continue
            if not (f.at <= t < f.at + f.duration):
                continue
            if f.drop_prob >= 1.0:
                return True
            rng = np.random.default_rng(
                (self.seed, 0xC0F3, int(engine), int(version), int(chunk),
                 int(attempt)))
            return bool(rng.random() < f.drop_prob)
        return False

    # ---- gray-fault queries (consulted by stages / orchestrator) ------
    def slowdown_factor(self, engine: int, t: float) -> float:
        """Compute-cost multiplier for `engine` at time `t` (>= 1.0;
        overlapping windows multiply)."""
        factor = 1.0
        for f in self.faults:
            if (f.kind == "engine_slowdown" and f.engine == engine
                    and f.at <= t < f.at + f.duration):
                factor *= max(float(f.factor), 1.0)
        return factor

    def has_slowdown_faults(self) -> bool:
        return any(f.kind == "engine_slowdown" for f in self.faults)

    def nan_step_count(self, at: float) -> int:
        """How many consecutive trainer steps starting at-or-after `at`
        are poisoned (0 if no nan_step fault fires at `at`)."""
        for f in self.faults:
            if f.kind == "nan_step" and f.at == at:
                return max(int(f.count), 1)
        return 0

    def poison_ordinals(self) -> List[int]:
        """Ordinals (draw indices into the shared prompt source) of
        poisoned prompts."""
        return sorted(int(f.at) for f in self.faults
                      if f.kind == "poison_prompt")

    # ---- launcher spec ------------------------------------------------
    _SPEC_RES = (
        ("engine_crash",
         re.compile(r"^engine:(\d+)@([\d.]+)(?:r([\d.]+))?$")),
        ("trainer_crash", re.compile(r"^trainer@([\d.]+)(?:r([\d.]+))?$")),
        ("preprocess_fail", re.compile(r"^pre@([\d.]+)$")),
        ("link_degrade",
         re.compile(r"^link(?::(\d+))?@([\d.]+)d([\d.]+)(?:p([\d.]+))?$")),
        ("engine_slowdown",
         re.compile(r"^slow:(\d+)@([\d.]+)d([\d.]+)(?:x([\d.]+))?$")),
        ("engine_hang",
         re.compile(r"^hang:(\d+)@([\d.]+)(?:r([\d.]+))?$")),
        ("chunk_corrupt",
         re.compile(r"^corrupt(?::(\d+))?@([\d.]+)d([\d.]+)(?:p([\d.]+))?$")),
        ("nan_step", re.compile(r"^nan@([\d.]+)(?:x(\d+))?$")),
        ("poison_prompt", re.compile(r"^poison@(\d+)$")),
    )

    @classmethod
    def parse(cls, spec: str, n_engines: int = 1,
              horizon: float = 2000.0) -> "FaultPlan":
        """Compact `--fault-plan` spec: comma-separated faults —

            engine:<i>@<t>[r<delay>]   kill engine i at t (restart after delay)
            trainer@<t>[r<delay>]      trainer crash (checkpoint restore)
            pre@<t>                    preprocessor failure
            link[:<i>]@<t>d<dur>[p<p>] lossy interconnect window
            slow:<i>@<t>d<dur>[x<f>]   engine i runs f-times slower over window
            hang:<i>@<t>[r<delay>]     engine i wedges (watchdog recovers it)
            corrupt[:<i>]@<t>d<dur>[p<p>]  corrupted-chunk window
            nan@<t>[x<n>]              n non-finite trainer steps from t
            poison@<n>                 n-th prompt drawn wedges its engine
            chaos:<seed>[:<horizon>]   stochastic churn plan (see `chaos`)
        """
        spec = spec.strip()
        m = re.match(r"^chaos:(\d+)(?::([\d.]+))?$", spec)
        if m:
            return cls.chaos(int(m.group(1)),
                             float(m.group(2)) if m.group(2) else horizon,
                             n_engines=n_engines, trainer_crashes=0)
        plan = cls()
        for part in filter(None, (p.strip() for p in spec.split(","))):
            for kind, rx in cls._SPEC_RES:
                m = rx.match(part)
                if not m:
                    continue
                g = m.groups()
                if kind == "engine_crash":
                    plan.engine_crash(float(g[1]), engine=int(g[0]),
                                      restart_after=(float(g[2])
                                                     if g[2] else None))
                elif kind == "trainer_crash":
                    plan.trainer_crash(float(g[0]),
                                       restart_after=(float(g[1])
                                                      if g[1] else None))
                elif kind == "preprocess_fail":
                    plan.preprocess_fail(float(g[0]))
                elif kind == "link_degrade":
                    plan.degrade_link(
                        float(g[1]), duration=float(g[2]),
                        engine=int(g[0]) if g[0] else None,
                        drop_prob=float(g[3]) if g[3] else 1.0)
                elif kind == "engine_slowdown":
                    plan.engine_slowdown(
                        float(g[1]), duration=float(g[2]),
                        engine=int(g[0]),
                        factor=float(g[3]) if g[3] else 4.0)
                elif kind == "engine_hang":
                    plan.engine_hang(float(g[1]), engine=int(g[0]),
                                     restart_after=(float(g[2])
                                                    if g[2] else None))
                elif kind == "chunk_corrupt":
                    plan.chunk_corrupt(
                        float(g[1]), duration=float(g[2]),
                        engine=int(g[0]) if g[0] else None,
                        drop_prob=float(g[3]) if g[3] else 1.0)
                elif kind == "nan_step":
                    plan.nan_step(float(g[0]),
                                  count=int(g[1]) if g[1] else 1)
                else:
                    plan.poison_prompt(int(g[0]))
                break
            else:
                raise ValueError(f"unparseable fault spec {part!r}")
        return plan

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, faults={self.faults!r})"


# ---------------------------------------------------------------------------
# shared metric helpers (exported to pipeline.py for API compatibility)
# ---------------------------------------------------------------------------

def lag_stats(rollouts: List[Rollout], trainer_version: int):
    """(max, mean) token lag of completion tokens vs `trainer_version`."""
    lags = []
    for r in rollouts:
        mask = np.arange(r.length) >= r.prompt_len
        lags.append((trainer_version - r.weight_versions)[mask])
    if not lags:
        return 0.0, 0.0
    cat = np.concatenate(lags)
    if cat.size == 0:
        return 0.0, 0.0
    return float(cat.max()), float(cat.mean())


def apply_group_baseline(rollouts: List[Rollout]) -> List[Rollout]:
    """GRPO-style: reward <- reward - mean(rewards of same-prompt rollouts).
    Returns shallow copies so queue bookkeeping is untouched."""
    import copy
    groups: Dict[int, List[float]] = {}
    for r in rollouts:
        groups.setdefault(r.prompt_key, []).append(r.reward)
    means = {k: float(np.mean(v)) for k, v in groups.items()}
    out = []
    for r in rollouts:
        r2 = copy.copy(r)
        r2.reward = r.reward - means[r.prompt_key]
        out.append(r2)
    return out


# ---------------------------------------------------------------------------
# periodic-asynchrony gate (DESIGN.md §12)
# ---------------------------------------------------------------------------

class LagGate:
    """Bounded-staleness barrier shared by the actor pool
    (`PipelineConfig.max_lag`): an actor whose engine weights are more
    than `max_lag` versions behind the learner pauses — via the PR-5
    preemption-window machinery — until its pending weight delivery
    installs, instead of stamping tokens that the lag bound would force
    the trainer to discard. `max_lag=0` is conventional-RL lockstep
    (every sampled token is trained at lag 0); `max_lag=None` (no gate)
    is the paper's free-running pipeline.

    The gate is keyed on `engine.version` — what a *new* token would be
    stamped with — never on the oldest in-flight stamp: pausing decode
    can't freshen an already-stamped token, it can only stop digging, so
    gating on in-flight stamps would deadlock (the rollout could never
    finish). In-flight staleness is bounded instead by the pack-time
    mask (`pack(..., max_lag=...)`), which guarantees no over-bound
    token reaches the objective."""

    def __init__(self, max_lag: int, trainer_version: Callable[[], int]):
        self.max_lag = int(max_lag)
        self.trainer_version = trainer_version
        self.blocks = 0        # gate decisions that paused an actor
        self.parks = 0         # pauses with no delivery yet scheduled
        self.wait_total = 0.0  # flashes of decode deferred by the gate

    def blocked(self, actor: "ActorStage") -> bool:
        """Would a token sampled now exceed the lag bound?"""
        return (self.trainer_version()
                - int(actor.engine.version)) > self.max_lag

    def stats(self) -> Dict[str, Any]:
        return {"max_lag": self.max_lag, "blocks": self.blocks,
                "parks": self.parks, "wait_total": self.wait_total}


# ---------------------------------------------------------------------------
# actor stage
# ---------------------------------------------------------------------------

class ActorStage:
    """One generation engine on the event loop.

    step_cost(h) / prefill_cost(tokens, invocations) are the stage's cost
    model — PipelineRL passes HardwareModel closures over its chip share,
    the Server passes its step-denominated dt costs. Weight publications
    arrive via `deliver_atomic` / `deliver_stream` and are installed only
    at tick boundaries (Algorithm 2 l. 9-11), charging the decode-pause
    the HardwareModel assigns to the mode.
    """

    def __init__(self, loop: EventLoop, engine, *,
                 task=None, name: str = "actor0",
                 step_cost: Callable[[float], float] = lambda h: 1.0,
                 prefill_cost: Callable[[int, int], float] = lambda t, i: 0.0,
                 page_cost: Callable[[int], float] = lambda p: 0.0,
                 deliver: Optional[Callable[[List[Rollout], float], None]] = None,
                 auto_refill: bool = True, refill_first: bool = False,
                 chain: bool = True,
                 on_drained: Optional[Callable[[float], None]] = None,
                 recompute_kv: bool = False,
                 lag_gate: Optional["LagGate"] = None):
        self.loop, self.engine, self.task, self.name = loop, engine, task, name
        self.step_cost, self.prefill_cost = step_cost, prefill_cost
        self.page_cost = page_cost
        # periodic-asynchrony (DESIGN.md §12): pool-shared staleness gate
        self.lag_gate = lag_gate
        self.lag_pauses = 0                # gate deferrals taken
        self.lag_wait_total = 0.0          # decode flashes deferred
        self._lag_parked = False           # offline awaiting a publication
        self._lag_parked_at = 0.0
        self._lag_carry_pause = 0.0        # install pause owed at unpark
        self.deliver = deliver or (lambda rollouts, t: None)
        self.auto_refill, self.refill_first = auto_refill, refill_first
        self.chain, self.on_drained = chain, on_drained
        self.recompute_kv = recompute_kv
        self.running = False
        self.time = 0.0                    # this engine's own clock
        # weight deliveries
        self._atomic: List[Tuple[float, Any, int, float]] = []
        self._stream: Optional[Dict[str, Any]] = None
        self._next_stream: Optional[Tuple] = None   # newest pending publish
        # timed preemption windows [start, end) — sorted by start
        self._preempt: List[Tuple[float, float]] = []
        self.preempt_total = 0.0           # wall-time spent offline
        self.preemptions_taken = 0         # deferrals actually hit
        # failure / recovery (DESIGN.md §8): `fail` crashes the engine
        # mid-decode, `restore` brings it back after a catch-up sync
        self.failed = False
        self.failures = 0
        self.recoveries = 0
        self.rollouts_lost = 0             # in-flight sequences killed
        self.prompts_salvaged = 0          # prompts handed back to the pool
        self.failed_at: Optional[float] = None
        self.downtime = 0.0                # wall-time spent crashed
        self._epoch = 0                    # bumped on fail: stale queued
        #                                    tick chains become no-ops
        # gray-failure surface (DESIGN.md §10): a hung stage is NOT
        # failed — it holds its slots, stops completing ticks, and keeps
        # `running=True`, so only an external watchdog reading the
        # heartbeat (`last_tick_at`) can tell it from a busy engine
        self.hung = False
        self.hangs = 0
        self.cost_scale: Optional[Callable[[float], float]] = None
        #   ^ compute-cost multiplier vs time (engine_slowdown windows);
        #     None on healthy plans so the tick arithmetic is untouched
        self.poison_check = False          # plan poisons prompts: inspect
        #                                    slots for a wedging prompt
        self.ticks_completed = 0
        self.last_tick_at: Optional[float] = None    # heartbeat
        self.ewma_tick_cost: Optional[float] = None  # EWMA decode-step
        #   cost (pauses/prefill excluded). step_cost(h) = h/U(h)/speed
        #   is load-independent in the linear-utilization region, so
        #   after the monitor multiplies by the declared speed this is a
        #   cross-engine-comparable progress statistic: busy != straggler
        # accounting (read by orchestrators / benchmarks)
        self.updates_applied = 0
        self.streams_completed = 0
        self.streams_aborted = 0
        self.pause_total = 0.0             # decode pause charged to updates
        self.pause_log: List[Tuple[int, float]] = []   # (version, pause)

    _EWMA_ALPHA = 0.25                     # per-tick progress smoothing

    # ---- weight delivery (called by WeightBroadcaster / Server) --------
    def deliver_atomic(self, arrive: float, params, version: int,
                       pause: float) -> None:
        """Whole-tree publication arriving at `arrive`; the engine pauses
        `pause` flashes at the install boundary (the blocking transfer).
        Dropped when the engine is crashed — the restore path re-syncs."""
        if self.failed:
            return
        self._atomic.append((arrive, params, version, pause))
        self._atomic.sort(key=lambda x: x[0])
        self._lag_unpark(arrive)

    def deliver_stream(self, params, version: int, arrivals: Sequence[float],
                       install_pause: float, per_tick: int = 0,
                       recompute_kv: Optional[bool] = None,
                       tokens: Optional[Sequence[Optional[int]]] = None,
                       n_chunks: Optional[int] = None,
                       digest: Optional[int] = None,
                       chunk_leaves=None) -> None:
        """Chunked publication: chunk k arrives at arrivals[k]; each
        install pauses decode `install_pause`; pointer-swap after the
        last. While a stream is in flight, a new publication *waits* (the
        in-flight transfer always completes, so the policy keeps making
        forward progress even when `broadcast_time` exceeds the publish
        interval) — but only the newest waiting publication survives:
        superseded pending ones are counted in `streams_aborted`.

        Integrity gate (DESIGN.md §10): `tokens[k]` is the checksum
        carried by transmission k — the engine recomputes it from its own
        span table and rejects mismatches without touching the shadow
        buffer, so corrupt transmissions never install; `arrivals` may
        then hold more entries than `n_chunks` (rejected deliveries plus
        their retransmissions). `digest` is the whole-publication
        checksum verified before the pointer swap. `chunk_leaves` carries
        executor-resharded span buffers (real-mesh runtime, DESIGN.md
        §11) straight through to the engine."""
        if self.failed:
            return
        rk = self.recompute_kv if recompute_kv is None else recompute_kv
        if self._stream is not None:
            if self._next_stream is not None:
                self.streams_aborted += 1
            self._next_stream = (params, version, list(arrivals),
                                 install_pause, per_tick, rk,
                                 list(tokens) if tokens is not None else None,
                                 n_chunks, digest, chunk_leaves)
            if arrivals:
                self._lag_unpark(list(arrivals)[-1])
            return
        nc = len(arrivals) if n_chunks is None else int(n_chunks)
        # only pass the kwarg when set: stub engines in tests implement the
        # pre-§11 begin_weight_stream signature
        kw = {} if chunk_leaves is None else {"chunk_leaves": chunk_leaves}
        sizes = self.engine.begin_weight_stream(
            params, version, n_chunks=nc, recompute_kv=rk,
            expect_digest=digest, **kw)
        self._stream = dict(version=version, arrivals=deque(arrivals),
                            tokens=(deque(tokens) if tokens is not None
                                    else None),
                            n_chunks=len(sizes), pause=install_pause,
                            per_tick=per_tick, accum=0.0)
        if arrivals:
            self._lag_unpark(list(arrivals)[-1])

    def _install_weights(self, now: float) -> float:
        """Apply every publication that has arrived by `now`; returns the
        decode pause charged to this tick."""
        pause = 0.0
        while self._atomic and self._atomic[0][0] <= now:
            _, params, version, cost = self._atomic.pop(0)
            # an atomic swap supersedes any in-flight/pending stream
            if self._stream is not None:
                self.streams_aborted += 1
                self._stream = None
            if self._next_stream is not None:
                self.streams_aborted += 1
                self._next_stream = None
            self.engine.set_weights(params, version,
                                    recompute_kv=self.recompute_kv)
            pause += cost
            self.updates_applied += 1
            self.pause_log.append((version, cost))
        st = self._stream
        if st is not None:
            installed = 0
            while st["arrivals"] and st["arrivals"][0] <= now:
                if st["per_tick"] and installed >= st["per_tick"]:
                    break
                st["arrivals"].popleft()
                tok = (st["tokens"].popleft() if st["tokens"] is not None
                       else None)
                done = self.engine.stream_weight_chunk(token=tok)
                pause += st["pause"]
                st["accum"] += st["pause"]
                installed += 1
                if done:
                    self.updates_applied += 1
                    if getattr(self.engine, "last_stream_installed", True):
                        self.streams_completed += 1
                    else:
                        # torn stream caught by the pre-swap digest gate:
                        # nothing installed, μ stays on the old weights
                        self.updates_applied -= 1
                        self.streams_aborted += 1
                    self.pause_log.append((st["version"], st["accum"]))
                    self._stream = None
                    # promote the newest publication that waited for the
                    # in-flight transfer to finish
                    if self._next_stream is not None:
                        nxt, self._next_stream = self._next_stream, None
                        self.deliver_stream(nxt[0], nxt[1], nxt[2], nxt[3],
                                            per_tick=nxt[4],
                                            recompute_kv=nxt[5],
                                            tokens=nxt[6], n_chunks=nxt[7],
                                            digest=nxt[8],
                                            chunk_leaves=nxt[9])
                    break
        self.pause_total += pause
        return pause

    def _pending_install_time(self) -> Optional[float]:
        """Earliest future time a *version-advancing* install can land:
        the first queued atomic swap, the in-flight stream's last chunk
        (the pointer swap), or the pending next stream's last chunk. None
        when no publication is in flight (the gate must park, not spin)."""
        cands = []
        if self._atomic:
            cands.append(self._atomic[0][0])
        if self._stream is not None and self._stream["arrivals"]:
            cands.append(self._stream["arrivals"][-1])
        if self._next_stream is not None and self._next_stream[2]:
            cands.append(self._next_stream[2][-1])
        return min(cands) if cands else None

    def _lag_unpark(self, t: float) -> None:
        """Resume a gate-parked actor once a publication is scheduled;
        the owed install pause is served before the first post-park tick."""
        if not self._lag_parked or self.failed:
            return
        self._lag_parked = False
        carry, self._lag_carry_pause = self._lag_carry_pause, 0.0
        wake = max(t, self._lag_parked_at) + carry
        self.lag_wait_total += wake - self._lag_parked_at
        if self.lag_gate is not None:
            self.lag_gate.wait_total += wake - self._lag_parked_at
        self.running = True
        self._post_tick(wake)

    # ---- preemption (DESIGN.md §7 pool scheduling) ---------------------
    def preempt(self, start: float, duration: float) -> None:
        """Take the engine offline for [start, start+duration): any tick
        that would *begin* inside the window is deferred to the window
        end (a decode step already under way when the window opens
        completes — discrete-event granularity, checkpoint-style
        preemption). In-flight slots keep their KV/recurrent state and
        resume untouched; weight publications that arrive during the
        window install at the deferred tick. Overlapping and abutting
        windows compose."""
        if duration <= 0:
            return
        self._preempt.append((float(start), float(start) + float(duration)))
        self._preempt.sort()

    def _preempt_until(self, now: float) -> Optional[float]:
        """Resume time if `now` falls inside a preemption window (chained
        windows are followed transitively); None when online. Windows
        wholly in the past are discarded."""
        t = now
        for s, e in self._preempt:
            if s <= t < e:
                t = e
        self._preempt = [(s, e) for (s, e) in self._preempt if e > t]
        return t if t > now else None

    # ---- failure / recovery (DESIGN.md §8) -----------------------------
    def fail(self, now: float) -> List[Any]:
        """Crash the engine at `now`, mid-decode: every live slot's
        rollout-in-progress is lost (its sampled tokens die with the
        process — counted in `rollouts_lost`), but the slots' *prompts*
        are salvaged and returned so the pool can re-offer them to
        surviving engines. Pending weight deliveries (atomic and
        streamed) are dropped; the restore path collapses everything the
        engine missed into one catch-up atomic sync. Idempotent: failing
        a failed stage salvages nothing."""
        if self.failed:
            return []
        self.failed = True
        self.hung = False         # escalation path: a wedged stage is
        #                           killed to be salvaged (DESIGN.md §10)
        self.failed_at = now
        self.failures += 1
        self._epoch += 1          # kill any queued tick chain
        self.running = False
        self._lag_parked = False  # restore() restarts the tick chain
        self._lag_carry_pause = 0.0
        self._atomic.clear()
        self._stream = None
        self._next_stream = None
        eng = self.engine
        salvaged = [eng.problems[s] for s in np.where(eng._host_active)[0]
                    if eng.problems[s] is not None]
        # paged engines may hold prompts parked by page-exhaustion
        # deferral/preemption — those were admitted work too, and must be
        # pulled BEFORE reset_slots drops the deferral queue
        drain = getattr(eng, "drain_deferred", None)
        if drain is not None:
            salvaged.extend(drain())
        self.rollouts_lost += eng.reset_slots()
        self.prompts_salvaged += len(salvaged)
        return salvaged

    def hang(self, now: float) -> None:
        """Gray failure (DESIGN.md §10): the engine wedges at `now`
        WITHOUT crashing. The queued tick chain dies (epoch bump) but the
        stage keeps `running=True` and `failed=False` — its slots hold
        their prompts, pending weight deliveries pile up uninstalled, and
        heartbeats (`last_tick_at`) simply stop. Nothing inside the stage
        can recover it; only the `HealthMonitor` watchdog notices the
        missed heartbeat deadline and escalates through the ordinary
        fail/salvage/requeue path."""
        if self.failed or self.hung:
            return
        self.hung = True
        self.hangs += 1
        self._epoch += 1          # queued ticks become stale no-ops

    def restore(self, now: float, params=None,
                version: Optional[int] = None) -> None:
        """Bring a failed engine back online at `now` (crash restart or
        elastic rejoin). `params`/`version` is the catch-up atomic weight
        sync — every publication the engine missed while down, collapsed
        to the newest — applied BEFORE admission resumes, so a rejoining
        engine never decodes under stale weights and its per-token
        version stamps stay exact from the first post-rejoin token."""
        if not self.failed:
            return
        self.failed = False
        self.recoveries += 1
        # a restarted process starts with a clean health record: the old
        # heartbeat/progress EWMAs describe the pre-outage (possibly
        # degraded) incarnation and must not flag the fresh one
        self.last_tick_at = None
        self.ewma_tick_cost = None
        if self.failed_at is not None:
            self.downtime += now - self.failed_at
            self.failed_at = None
        if params is not None:
            self.engine.set_weights(params, int(version or 0),
                                    recompute_kv=self.recompute_kv)
            self.updates_applied += 1
        self.start(now)

    # ---- lifecycle -----------------------------------------------------
    def start(self, t: float) -> None:
        if not self.running and not self.failed:
            self.running = True
            self._lag_parked = False   # an explicit start supersedes a park
            self._post_tick(t)

    def _post_tick(self, t: float) -> None:
        """Schedule the next tick under the current failure epoch: a
        crash between post and fire invalidates the chain (the closure's
        epoch goes stale), so a restored stage never runs two interleaved
        tick chains."""
        epoch = self._epoch
        self.loop.post(t, lambda now: self._tick(now, epoch))

    def _refill(self, now: float) -> float:
        inv0 = getattr(self.engine, "prefill_invocations", 0)
        admitted = self.engine.refill(now)
        if not admitted:
            return 0.0
        inv = getattr(self.engine, "prefill_invocations", 0) - inv0
        # paged engines report the pages the admission actually allocated
        # (a COW-forked GRPO group costs its prefix pages once) — the page
        # cost models allocator/table traffic on top of the prefill flops
        pages = getattr(self.engine, "last_admit_pages", 0)
        return (self.prefill_cost(self.engine.last_admit_prefill_tokens, inv)
                + self.page_cost(pages))

    def tick(self, now: float) -> None:
        """External tick entry point (the Server's step-driven mode);
        self-scheduled chains go through `_post_tick`."""
        self._tick(now, self._epoch)

    def _tick(self, now: float, epoch: int) -> None:
        """One decode step: install weights -> (refill) -> step -> deliver
        -> (refill) -> reschedule."""
        if epoch != self._epoch or self.failed or self.hung:
            return   # stale chain from before a crash/hang, or offline
        resume = self._preempt_until(now)
        if resume is not None:
            self.preempt_total += resume - now
            self.preemptions_taken += 1
            self._post_tick(resume)
            return
        pause = self._install_weights(now)
        # periodic-asynchrony gate (DESIGN.md §12): checked AFTER installs
        # so an already-arrived publication unblocks this very tick. A
        # blocked actor defers to its pending delivery through the PR-5
        # preemption machinery (HealthMonitor-exempt by construction); the
        # install pause already charged above rides the window so its
        # wall-time isn't dropped from the timeline.
        if self.lag_gate is not None and self.lag_gate.blocked(self):
            self.lag_gate.blocks += 1
            self.lag_pauses += 1
            wake = self._pending_install_time()
            if wake is None:
                # nothing published yet: park until a delivery lands
                # (deliver_atomic / deliver_stream unpark)
                self.lag_gate.parks += 1
                self._lag_parked = True
                self._lag_parked_at = now
                self._lag_carry_pause += pause
                self.running = False
                return
            wake = max(wake, now + 1e-9)
            self.lag_wait_total += wake - now
            self.lag_gate.wait_total += wake - now
            self.preempt(now, (wake - now) + pause)
            self._post_tick(now)
            return
        c_pre = 0.0
        if self.auto_refill and (self.refill_first
                                 or self.engine.n_active == 0):
            c_pre += self._refill(now)
        if self.poison_check and any(
                p is not None and getattr(p, "_poison", False)
                for p in self.engine.problems):
            # a poisoned prompt wedges whichever engine admitted it the
            # moment it would decode — the watchdog + K-attempt
            # quarantine path is what breaks the resulting crash loop
            self.hang(now)
            return
        h = self.engine.n_active
        if h == 0:
            # nothing to decode: drained (conventional phase end) or idle
            # (server with no requests). The tick still consumes wall time
            # under a per-step cost model (step_cost(0) is dt for the
            # Server, 0 for the flash model) and any weight-install pause
            # stays on the timeline.
            t = now + pause + c_pre + self.step_cost(0)
            self.time = max(self.time, t)
            self.deliver([], t)
            self.running = False
            if self.on_drained is not None:
                self.on_drained(t)
            return
        finished = self.engine.step(self.task, now=now)
        cost = self.step_cost(h)
        if self.cost_scale is not None:
            # gray degradation (engine_slowdown window): the chip is
            # slower, so every compute charge on this tick scales
            scale = self.cost_scale(now)
            cost *= scale
            c_pre *= scale
        t_done = now + pause + c_pre + cost
        for r in finished:
            r.finished_at = t_done
        self.time = t_done
        # heartbeat + per-tick progress EWMA (the HealthMonitor's inputs)
        self.ticks_completed += 1
        self.last_tick_at = t_done
        self.ewma_tick_cost = cost if self.ewma_tick_cost is None else (
            self._EWMA_ALPHA * cost
            + (1.0 - self._EWMA_ALPHA) * self.ewma_tick_cost)
        self.deliver(finished, t_done)
        if self.auto_refill and not self.refill_first:
            c_post = self._refill(t_done)
            if self.cost_scale is not None:
                c_post *= self.cost_scale(t_done)
            t_done += c_post
        if self.engine.n_active == 0 and not self.auto_refill:
            self.running = False
            if self.on_drained is not None:
                self.on_drained(t_done)
            return
        if self.chain:
            self._post_tick(t_done)
        else:
            self.running = False


# ---------------------------------------------------------------------------
# pool router (priority/affinity admission across the actor pool)
# ---------------------------------------------------------------------------

class PoolRouter:
    """Pluggable admission layer between one shared prompt source and the
    engines of an actor pool (DESIGN.md §7 "Pool scheduling").

    Engines keep their pull-based admission: each free slot asks its
    per-engine view (`source_for(i)`) for a prompt during refill. The
    router decides what that pull returns:

      fifo             pass-through: the requesting engine takes the next
                       prompt from the source — bit-identical to wiring
                       the source into every engine directly (default).
      shortest_queue   the requesting engine is granted the next prompt
                       only while its speed-normalized outstanding decode
                       work is within `slack` tokens of the pool minimum;
                       otherwise the pull is declined (the slot stays
                       free and is re-offered at the engine's next tick),
                       so slow/deep engines stop hoarding prompts.
      length_affinity  the router keeps up to `lookahead` pending prompts
                       drawn from the source; engines at or above the
                       mean pool speed take the *longest* pending prompt,
                       slower engines the *shortest* — long prompts'
                       prefill (and their short remaining completion
                       budget) land on the cheapest compute.

    All decisions read only the prompt stream and the engines' host
    mirrors (`_host_active`/`_host_ncached` — the prompt-length histogram
    the engines already keep on host): no wall-clock, no RNG, so routing
    is deterministic under the simulated clock.
    """

    POLICIES = ("fifo", "shortest_queue", "length_affinity")

    def __init__(self, source: Callable[[], Optional[Any]],
                 policy: str = "fifo", lookahead: int = 0,
                 slack: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"choose from {self.POLICIES}")
        self.source, self.policy = source, policy
        self.lookahead, self.slack = int(lookahead), slack
        # sim-clock accessor: only read for recovery telemetry (salvaged-
        # prompt re-admission latency), never for routing decisions — so
        # routing stays deterministic and clockless as before
        self.clock = clock or (lambda: 0.0)
        self.pending: deque = deque()
        self.engines: List[Any] = []
        self.speeds: List[float] = []
        self.assigned: List[int] = []
        self.assigned_tokens: List[int] = []
        self.declined: List[int] = []
        self.alive: List[bool] = []
        # §10 straggler demotion weight (1.0 = healthy), set by the
        # HealthMonitor; multiplies declared speed in routing scores
        self.health: List[float] = []
        # failure recovery (DESIGN.md §8)
        self.requeued = 0
        self.requeue_latency: List[float] = []

    def attach(self, engines: Sequence[Any],
               speeds: Optional[Sequence[float]] = None) -> None:
        self.engines = list(engines)
        n = len(self.engines)
        self.speeds = [float(s) for s in speeds] if speeds is not None \
            else [1.0] * n
        if len(self.speeds) != n:
            raise ValueError(f"{len(self.speeds)} speeds for {n} engines")
        self.assigned = [0] * n
        self.assigned_tokens = [0] * n
        self.declined = [0] * n
        self.alive = [True] * n
        self.health = [1.0] * n
        if self.lookahead <= 0:
            self.lookahead = sum(e.ec.n_slots for e in self.engines)
        if self.slack is None:
            self.slack = float(max(e.ec.max_len for e in self.engines))

    # ---- elastic pool / failure recovery (DESIGN.md §8) ----------------
    def add_engine(self, engine, speed: float = 1.0) -> int:
        """Elastic join: extend the pool with one engine at runtime."""
        self.engines.append(engine)
        self.speeds.append(float(speed))
        self.assigned.append(0)
        self.assigned_tokens.append(0)
        self.declined.append(0)
        self.alive.append(True)
        self.health.append(1.0)
        return len(self.engines) - 1

    def set_alive(self, i: int, alive: bool) -> None:
        """Crashed/detached engines leave the routing population: load
        comparisons and speed means ignore them (they cannot pull anyway
        — a dead stage never refills)."""
        self.alive[i] = bool(alive)

    def set_health(self, i: int, health: float) -> None:
        """Straggler demotion (DESIGN.md §10): scale engine `i`'s
        *effective* speed by `health` in (0, 1]. Routing treats a demoted
        engine as a proportionally slower chip — shortest_queue stops
        granting it prompts once its normalized backlog rises, and
        length_affinity steers long prompts away — without removing it
        from the pool. The HealthMonitor sets this from the measured
        degradation and resets it to 1.0 on recovery."""
        self.health[i] = min(max(float(health), 1e-3), 1.0)

    def _eff_speed(self, j: int) -> float:
        return self.speeds[j] * self.health[j]

    def requeue(self, problems: Sequence[Any],
                now: Optional[float] = None) -> None:
        """Recovery path: salvaged prompts from a failed engine re-enter
        at the FRONT of the pending buffer — they are the pool's oldest
        admitted work, so they must win the next pulls — and are
        timestamped so `stats()` can report re-admission latency."""
        t = self.clock() if now is None else now
        for p in reversed(list(problems)):
            p._salvaged_at = t  # type: ignore[attr-defined]
            self.pending.appendleft(p)
        self.requeued += len(problems)

    def source_for(self, i: int) -> Callable[[], Optional[Any]]:
        """The prompt-source callable engine `i` pulls from."""
        return lambda: self.request(i)

    # ---- internals -----------------------------------------------------
    def _load(self, j: int) -> float:
        """Speed-normalized outstanding decode work of engine j: remaining
        token budget of its active slots, in slow-chip token units."""
        eng = self.engines[j]
        act = eng._host_active
        rem = int((eng.ec.max_len - 1 - eng._host_ncached[act]).sum())
        return rem / max(self._eff_speed(j), 1e-9)

    def _draw(self) -> Optional[Any]:
        if self.pending:
            return self.pending.popleft()
        return self.source()

    def _admissible(self, i: int, prob: Any) -> bool:
        """Page-costed admission gate (DESIGN.md §9): a paged engine that
        cannot back the prompt's blocks right now declines the pull — the
        prompt stays pooled for an engine with free pages instead of
        parking in the full engine's deferral queue."""
        fn = getattr(self.engines[i], "can_admit", None)
        return fn is None or bool(fn(len(prob.prompt_ids)))

    def _grant(self, i: int, prob: Any) -> Any:
        self.assigned[i] += 1
        self.assigned_tokens[i] += len(prob.prompt_ids)
        t0 = getattr(prob, "_salvaged_at", None)
        if t0 is not None:
            self.requeue_latency.append(self.clock() - t0)
            prob._salvaged_at = None
        return prob

    # ---- the per-engine pull -------------------------------------------
    def request(self, i: int) -> Optional[Any]:
        if self.policy == "shortest_queue":
            loads = [self._load(j) for j in range(len(self.engines))]
            floor = min((l for l, ok in zip(loads, self.alive) if ok),
                        default=0.0)
            if loads[i] - floor > self.slack:
                self.declined[i] += 1
                return None
        if self.policy != "length_affinity":
            prob = self._draw()
            if prob is None:
                return None
            if not self._admissible(i, prob):
                self.pending.appendleft(prob)  # keep pool order
                self.declined[i] += 1
                return None
            return self._grant(i, prob)
        # length_affinity: top up the pending buffer, then pick by length
        while len(self.pending) < self.lookahead:
            p = self.source()
            if p is None:
                break
            self.pending.append(p)
        if not self.pending:
            return None
        lens = [len(p.prompt_ids) for p in self.pending]
        eff = [self._eff_speed(j) for j in range(len(self.engines))]
        live = [s for s, ok in zip(eff, self.alive) if ok] or eff
        mean_speed = sum(live) / max(len(live), 1)
        if eff[i] >= mean_speed:
            # ties break toward the earliest pending prompt (FIFO within
            # equal lengths) so routing stays deterministic
            k = max(range(len(lens)), key=lambda j: (lens[j], -j))
        else:
            k = min(range(len(lens)), key=lambda j: (lens[j], j))
        prob = self.pending[k]
        if not self._admissible(i, prob):
            self.declined[i] += 1
            return None
        del self.pending[k]
        return self._grant(i, prob)

    def stats(self) -> Dict[str, Any]:
        lat = self.requeue_latency
        return {
            "policy": self.policy,
            "pending": len(self.pending),
            "prompts_requeued": self.requeued,
            "requeues_readmitted": len(lat),
            "requeue_latency_mean": float(np.mean(lat)) if lat else 0.0,
            "requeue_latency_max": float(np.max(lat)) if lat else 0.0,
            "engines": [
                {"assigned": a, "prompt_tokens": t, "declined": d,
                 "alive": ok, "health": h}
                for a, t, d, ok, h in zip(self.assigned,
                                          self.assigned_tokens,
                                          self.declined, self.alive,
                                          self.health)],
        }


# ---------------------------------------------------------------------------
# health monitor (DESIGN.md §10 gray-failure watchdog)
# ---------------------------------------------------------------------------

class HealthMonitor:
    """Gray-failure watchdog over an actor pool (DESIGN.md §10). Crashes
    announce themselves (the fault handler calls `fail`); gray failures
    don't — a wedged engine keeps `running=True` and simply stops
    heartbeating, a degraded chip keeps completing ticks but slower. The
    monitor is a periodic observer stage that reads only what the stages
    already record (`last_tick_at` heartbeats, `ewma_tick_cost` progress)
    and routes every mitigation through existing machinery:

      hang       `now - last_tick_at` exceeds the per-engine deadline
                 `max(hang_grace, hang_factor * EWMA heartbeat gap)`
                 (preemption windows extend the deadline — a scheduled
                 offline engine is not a hang). Escalation: `on_hang`
                 runs the §8 fail/salvage/requeue path, exactly as if the
                 wedged process had been killed by an operator.
      straggler  speed-normalized progress `ewma_tick_cost * speed_i`
                 exceeds `straggler_factor` x the pool minimum for
                 `straggler_patience` consecutive sweeps. step_cost is
                 load-independent in the linear-utilization region, so
                 declared-slow engines normalize to the same statistic as
                 fast ones and never false-positive; a demoted engine
                 gets `PoolRouter.set_health(i, measured ratio)` — it
                 keeps decoding, the router just stops feeding it long
                 work — and is restored the first sweep it looks healthy.
      quarantine salvaged prompts carry a failure-attribution counter;
                 a prompt whose count crosses `quarantine_after` is
                 withheld from requeue (returned to the caller for
                 terminal accounting) instead of wedging engine after
                 engine. Attribution is per-prompt, not per-cause: a
                 prompt unlucky enough to sit on `quarantine_after`
                 genuinely-crashing engines is over-quarantined — the
                 blast-radius tradeoff is documented, counted, and
                 surfaced, never silent.

    The monitor reschedules itself only while some watched stage is
    `running and not failed` (a hung stage stays running, so it stays
    watched); `kick()` re-arms it when the pool comes back."""

    def __init__(self, loop: EventLoop, actors: Sequence[ActorStage], *,
                 router: Optional[PoolRouter] = None,
                 speeds: Optional[Sequence[float]] = None,
                 interval: float = 20.0,
                 hang_grace: float = 120.0, hang_factor: float = 8.0,
                 straggler_factor: float = 2.5,
                 straggler_patience: int = 2,
                 quarantine_after: int = 3,
                 on_hang: Optional[Callable[[int, float], None]] = None):
        self.loop, self.actors = loop, list(actors)
        self.router = router
        self.speeds = ([float(s) for s in speeds] if speeds is not None
                       else [1.0] * len(self.actors))
        self.interval = float(interval)
        self.hang_grace = float(hang_grace)
        self.hang_factor = float(hang_factor)
        self.straggler_factor = float(straggler_factor)
        self.straggler_patience = int(straggler_patience)
        self.quarantine_after = int(quarantine_after)
        self.on_hang = on_hang
        n = len(self.actors)
        self._hb_seen: List[Optional[float]] = [None] * n
        self._watch_since: List[float] = [0.0] * n
        self._gap_ewma: List[Optional[float]] = [None] * n
        self._slow_streak: List[int] = [0] * n
        self._demoted: List[bool] = [False] * n
        self._armed = False
        # accounting (read by pipeline stats / benches / tests)
        self.sweeps = 0
        self.hangs_detected: List[Tuple[int, float, float]] = []
        #   (engine, detected_at, latency since last heartbeat)
        self.stragglers_demoted = 0
        self.stragglers_restored = 0
        self.prompts_quarantined = 0
        self.quarantined: List[Any] = []

    _GAP_ALPHA = 0.25

    # ---- lifecycle -----------------------------------------------------
    def watch_engine(self, speed: float = 1.0) -> None:
        """Track an engine appended to the pool (elastic join)."""
        self.speeds.append(float(speed))
        self._hb_seen.append(None)
        self._watch_since.append(self.loop.now)
        self._gap_ewma.append(None)
        self._slow_streak.append(0)
        self._demoted.append(False)

    def start(self, t: float) -> None:
        if not self._armed:
            self._armed = True
            for i in range(len(self.actors)):
                self._watch_since[i] = t
            self.loop.post(t + self.interval, self._sweep)

    def kick(self, now: float) -> None:
        """Re-arm after the pool went quiet (e.g. every engine was down
        and one restored): monitoring resumes with fresh deadlines."""
        if self._armed:
            return
        if any(a.running and not a.failed for a in self.actors):
            self._armed = True
            for i, a in enumerate(self.actors):
                self._watch_since[i] = now
            self.loop.post(now + self.interval, self._sweep)

    def notice_restore(self, i: int, now: float) -> None:
        """Reset engine `i`'s hang clock on restore: its last heartbeat
        predates the outage, so without this a long `restart_after` would
        read as an instant re-hang."""
        self._hb_seen[i] = None
        self._gap_ewma[i] = None
        self._watch_since[i] = now
        self._slow_streak[i] = 0
        self._demoted[i] = False   # router health was reset by the caller
        self.kick(now)

    # ---- the periodic sweep -------------------------------------------
    def _sweep(self, now: float) -> None:
        self.sweeps += 1
        self._check_hangs(now)
        self._check_stragglers(now)
        if any(a.running and not a.failed for a in self.actors):
            self.loop.post(now + self.interval, self._sweep)
        else:
            # nothing left to watch: disarm so a dead pool drains the
            # loop instead of spinning to max_events. `kick()` re-arms.
            self._armed = False

    def _deadline(self, i: int) -> float:
        gap = self._gap_ewma[i]
        if gap is None:
            return self.hang_grace
        return max(self.hang_grace, self.hang_factor * gap)

    def _check_hangs(self, now: float) -> None:
        for i, a in enumerate(self.actors):
            if not a.running or a.failed:
                self._hb_seen[i] = None
                continue
            hb = a.last_tick_at
            if hb is not None and hb != self._hb_seen[i]:
                if self._hb_seen[i] is not None and hb > self._hb_seen[i]:
                    gap = hb - self._hb_seen[i]
                    self._gap_ewma[i] = gap if self._gap_ewma[i] is None \
                        else (self._GAP_ALPHA * gap
                              + (1 - self._GAP_ALPHA) * self._gap_ewma[i])
                self._hb_seen[i] = hb
            # a scheduled preemption window is not a hang: while inside
            # one (read-only scan — no state change on the healthy path)
            # the heartbeat clock effectively restarts at the window end
            base = max((hb if hb is not None else self._watch_since[i]),
                       self._watch_since[i])
            for s, e in a._preempt:
                if s <= base:
                    base = max(base, e)
            if now - base > self._deadline(i):
                self.hangs_detected.append((i, now, now - base))
                if self.on_hang is not None:
                    self.on_hang(i, now)
                self._hb_seen[i] = None
                self._gap_ewma[i] = None
                self._watch_since[i] = now

    def _check_stragglers(self, now: float) -> None:
        if self.router is None:
            return
        norm: Dict[int, float] = {}
        for i, a in enumerate(self.actors):
            if a.failed or a.ewma_tick_cost is None:
                continue
            norm[i] = a.ewma_tick_cost * self.speeds[i]
        if len(norm) < 2:
            return   # no pool baseline to compare against
        floor = min(norm.values())
        if floor <= 0.0:
            return
        for i, v in norm.items():
            if v > self.straggler_factor * floor:
                self._slow_streak[i] += 1
                if self._slow_streak[i] >= self.straggler_patience:
                    health = max(floor / v, 0.05)
                    self.router.set_health(i, health)
                    if not self._demoted[i]:
                        self._demoted[i] = True
                        self.stragglers_demoted += 1
            else:
                self._slow_streak[i] = 0
                if self._demoted[i]:
                    self._demoted[i] = False
                    self.router.set_health(i, 1.0)
                    self.stragglers_restored += 1

    # ---- quarantine attribution ---------------------------------------
    def attribute_failure(self, salvaged: Sequence[Any]
                          ) -> Tuple[List[Any], List[Any]]:
        """Charge one failure attribution to each salvaged prompt and
        split them into (requeue, quarantine): prompts whose attribution
        count crossed `quarantine_after` are withheld from the pool (the
        §10 poison-prompt circuit breaker). The caller requeues the first
        list and surfaces the second as terminally failed."""
        requeue, quarantine = [], []
        for p in salvaged:
            count = getattr(p, "_fail_count", 0) + 1
            p._fail_count = count
            if count >= self.quarantine_after:
                quarantine.append(p)
            else:
                requeue.append(p)
        self.prompts_quarantined += len(quarantine)
        self.quarantined.extend(quarantine)
        return requeue, quarantine

    def stats(self) -> Dict[str, Any]:
        return {
            "sweeps": self.sweeps,
            "hangs_detected": len(self.hangs_detected),
            "hang_detect_latency": [lat for _, _, lat in
                                    self.hangs_detected],
            "stragglers_demoted": self.stragglers_demoted,
            "stragglers_restored": self.stragglers_restored,
            "prompts_quarantined": self.prompts_quarantined,
            "health": (list(self.router.health)
                       if self.router is not None else []),
        }


# ---------------------------------------------------------------------------
# preprocessor stage (paper Fig. 4 middle stage, overlapped)
# ---------------------------------------------------------------------------

class PreprocessStage:
    """Pulls B rollouts from the SampleQueue when both it and the trainer
    inbox are free, holds them for `preprocessor.stage_time`, then submits
    the processed batch to the trainer. Runs concurrently with both
    neighbors — while batch k preprocesses, the actors generate k+1 and
    the trainer trains k-1 — instead of adding its latency to the trainer
    tick. At most one batch is in flight and one may wait in the trainer
    inbox, so a trainer stall backs pressure up into the SampleQueue
    (drop-oldest) rather than into an unbounded inbox."""

    def __init__(self, loop: EventLoop, preprocessor, queue, batch_size: int,
                 trainer_stage: "TrainerStage"):
        self.loop, self.pre, self.queue = loop, preprocessor, queue
        self.batch_size = batch_size
        self.trainer_stage = trainer_stage
        self.busy = False
        self.busy_until = 0.0
        self.batches = 0
        # failure recovery (DESIGN.md §8)
        self.batches_failed = 0
        self.rollouts_requeued = 0
        self._epoch = 0
        self._current: Optional[List[Rollout]] = None

    def kick(self, now: float) -> None:
        if self.busy or len(self.queue) < self.batch_size:
            return
        # overlap contract: preprocess batch k+1 while the trainer runs
        # batch k, but never queue a second *finished* batch at the
        # trainer — that's where back-pressure must fold back into the
        # SampleQueue (a busy trainer alone does not block us)
        if self.trainer_stage.inbox_waiting() > 0:
            return
        rollouts = self.queue.pop(self.batch_size)
        self._current = rollouts   # salvageable until delivery
        raw_reward = float(np.mean([r.reward for r in rollouts]))
        t_avail = max((r.finished_at for r in rollouts), default=now)
        processed = self.pre.process(rollouts)
        start = max(now, t_avail, self.busy_until)
        done = start + self.pre.stage_time(
            sum(r.length for r in processed))
        self.busy, self.busy_until = True, done
        self.batches += 1
        epoch = self._epoch

        def _deliver(t: float) -> None:
            if epoch != self._epoch:
                return   # the stage failed while this batch was in flight
            self.busy = False
            self._current = None
            self.trainer_stage.submit(processed, t, raw_reward=raw_reward)
            self.kick(t)

        self.loop.post(done, _deliver)

    def fail(self, now: float) -> int:
        """Transient preprocessor failure (DESIGN.md §8): the in-flight
        batch's *processing* is lost but its samples are not — the raw
        rollouts go back to the FRONT of the SampleQueue (`requeue_front`:
        oldest-first order preserved, `total_put` untouched) and are
        reprocessed on the immediate restart kick. Returns the number of
        rollouts salvaged."""
        self._epoch += 1
        n = 0
        if self.busy and self._current is not None:
            self.queue.requeue_front(self._current)
            n = len(self._current)
            self.rollouts_requeued += n
        self.busy = False
        self.busy_until = now   # the aborted batch's compute no longer
        #                         gates the restarted stage
        self._current = None
        self.batches_failed += 1
        self.kick(now)
        return n


# ---------------------------------------------------------------------------
# trainer stage
# ---------------------------------------------------------------------------

class TrainerStage:
    """Wraps a `Trainer` on the event loop: consumes batches from an inbox
    (fed by `submit`) or by pulling B rollouts from `queue` when idle,
    runs the real optimizer step eagerly, stamps completion on the
    simulated clock, publishes weights via the broadcaster, and models
    checkpoint stalls (`ckpt_every`/`ckpt_pause` — the scenario the
    SampleQueue's drop-oldest policy exists for).

    When `ckpt_dir` is given, the stall is no longer just a pause: each
    checkpoint step atomically persists the full TrainState to
    `<ckpt_dir>/trainer_latest.npz` plus a rotated, checksummed
    `trainer_step_<v>.npz` (last `ckpt_keep` kept), and `crash`/`restore`
    implement the crash-restart path of DESIGN.md §8 — a restore reloads
    params + optimizer moments + version from the last durable
    checkpoint, so the next optimizer step is bit-identical to the one
    an uninterrupted run (from that checkpoint) would take.

    Numerical robustness (DESIGN.md §10): when the wrapped trainer runs
    with its fused non-finite guard, a poisoned step is skipped *inside*
    the jitted step (state/version untouched) and counted here; the
    optional EWMA loss-spike detector (`loss_spike_factor` > 0) flags
    silently diverging steps the same way; `bad_step_rollback`
    consecutive bad steps trigger an automatic restore from the newest
    INTACT checkpoint — corrupt/truncated files are skipped via the
    content checksum (`checkpoint.load` verifies it)."""

    def __init__(self, loop: EventLoop, trainer, *, queue=None,
                 batch_size: int = 0,
                 train_time: Callable[[int], float] = lambda n: 0.0,
                 pack_rows: int = 8, pack_seq: int = 128,
                 log: Optional[List[Dict]] = None,
                 broadcaster: Optional["WeightBroadcaster"] = None,
                 update_every: int = 1, group_baseline: bool = False,
                 ckpt_every: int = 0, ckpt_pause: float = 0.0,
                 ckpt_dir: Optional[str] = None, ckpt_keep: int = 3,
                 bad_step_rollback: int = 3,
                 loss_spike_factor: float = 0.0,
                 samples_per_step: Optional[int] = None,
                 on_free: Optional[Callable[[float], None]] = None,
                 max_lag: Optional[int] = None):
        self.loop, self.trainer = loop, trainer
        self.queue, self.batch_size = queue, batch_size
        self.train_time = train_time
        self.pack_rows, self.pack_seq = pack_rows, pack_seq
        self.log = log if log is not None else []
        self.broadcaster = broadcaster
        self.update_every = max(int(update_every), 1)
        self.group_baseline = group_baseline
        self.ckpt_every, self.ckpt_pause = ckpt_every, ckpt_pause
        self.samples_per_step = samples_per_step or batch_size
        self.on_free = on_free
        self.busy = False
        self.free_at = 0.0
        self.stalls = 0
        self._inbox: deque = deque()   # (rollouts, raw_reward, avail, on_done)
        # crash-restart checkpointing (DESIGN.md §8)
        self.ckpt_dir = ckpt_dir
        self.ckpt_keep = max(int(ckpt_keep), 1)
        self.ckpt_path: Optional[str] = None
        self.ckpts_saved = 0
        self.last_ckpt_version = 0
        self.failed = False
        self.crashes = 0
        self.recoveries = 0
        self.steps_lost = 0
        self._epoch = 0
        self._prestep_state = None
        self._rotated: List[str] = []   # rotated ckpt paths, oldest first
        # numerical robustness (DESIGN.md §10)
        self.bad_step_rollback = int(bad_step_rollback)
        self.loss_spike_factor = float(loss_spike_factor)
        self.bad_steps = 0             # guard skips + divergence flags
        self.divergences = 0           # loss-spike detector hits alone
        self.consecutive_bad = 0
        self.rollbacks = 0
        self.ckpts_corrupt = 0         # skipped by the intact-fallback
        self._poison_pending = 0       # nan_step fault injection counter
        self._loss_ewma: Optional[float] = None
        # staleness contract (DESIGN.md §12): every packed batch carries
        # per-token lag vs the version this stage steps FROM; max_lag
        # additionally hard-masks over-bound tokens out of the loss
        self.max_lag = max_lag
        self.lag_hist: Dict[int, int] = {}   # lag -> trained-token count
        self.lag_masked_tokens = 0           # tokens dropped by the bound
        if ckpt_dir is not None:
            # version-0 seed checkpoint: a crash before the first periodic
            # save must still have something durable to restore from
            self.ckpt_path = self._save_ckpt(0)

    _LOSS_ALPHA = 0.2                  # loss-spike EWMA smoothing

    # ---- checkpoint rotation (DESIGN.md §10) --------------------------
    def _save_ckpt(self, version: int) -> str:
        """Persist the TrainState to `trainer_latest.npz` AND a rotated
        `trainer_step_<version>.npz`, keeping the newest `ckpt_keep`
        rotated files — the NaN-rollback path always has more than one
        restore target, so one corrupt/truncated file cannot strand it."""
        rotated = self.trainer.save(
            os.path.join(self.ckpt_dir, f"trainer_step_{version:06d}"))
        if rotated in self._rotated:    # re-save of the same version
            self._rotated.remove(rotated)
        self._rotated.append(rotated)
        while len(self._rotated) > self.ckpt_keep:
            old = self._rotated.pop(0)
            try:
                os.remove(old)
            except OSError:
                pass
        path = self.trainer.save(
            os.path.join(self.ckpt_dir, "trainer_latest"))
        self.ckpts_saved += 1
        return path

    def restore_newest_intact(self) -> Optional[str]:
        """Restore the TrainState from the newest checkpoint that passes
        integrity verification (`trainer_latest` first, then the rotated
        files newest-to-oldest). Corrupt, truncated or unreadable files
        are counted (`ckpts_corrupt`) and skipped. Returns the path
        restored from, or None when no intact checkpoint exists (the
        state is left untouched)."""
        from repro.checkpoint.checkpoint import CheckpointError
        seen = set()
        candidates = []
        for p in ([self.ckpt_path] if self.ckpt_path else []) + \
                list(reversed(self._rotated)):
            if p not in seen:
                seen.add(p)
                candidates.append(p)
        for path in candidates:
            try:
                self.trainer.restore(path)
                return path
            except CheckpointError:
                self.ckpts_corrupt += 1
        return None

    # ---- nan_step fault injection (DESIGN.md §10) ---------------------
    def poison_steps(self, count: int = 1) -> None:
        """The next `count` optimizer steps produce non-finite gradients
        (injected inside the jitted step so the fused guard is exercised
        end to end)."""
        self._poison_pending += max(int(count), 0)

    def inbox_depth(self) -> int:
        """Batches owned by the trainer: waiting in the inbox + in step."""
        return len(self._inbox) + (1 if self.busy else 0)

    def inbox_waiting(self) -> int:
        """Batches delivered but not yet started (excludes the running
        step) — the quantity the preprocessor's run-ahead bound is on."""
        return len(self._inbox)

    def submit(self, rollouts: List[Rollout], now: float,
               raw_reward: Optional[float] = None,
               on_done: Optional[Callable[[float], None]] = None) -> None:
        avail = max((r.finished_at for r in rollouts), default=now)
        self._inbox.append((rollouts, raw_reward, avail, on_done))
        self.kick(now)

    def kick(self, now: float) -> None:
        if self.busy or self.failed:
            return
        if self._inbox:
            rollouts, raw_reward, avail, on_done = self._inbox.popleft()
        elif (self.queue is not None and self.batch_size
                and len(self.queue) >= self.batch_size):
            rollouts = self.queue.pop(self.batch_size)
            raw_reward, on_done = None, None
            avail = max((r.finished_at for r in rollouts), default=now)
        else:
            return
        self._train(rollouts, raw_reward, avail, now, on_done)

    def _train(self, rollouts, raw_reward, avail, now, on_done) -> None:
        start = max(now, self.free_at, avail)
        if raw_reward is None:
            raw_reward = float(np.mean([r.reward for r in rollouts]))
        queue_depth = len(self.queue) if self.queue is not None else 0
        if self.group_baseline:
            rollouts = apply_group_baseline(rollouts)
        # staleness is computed against the version the learner steps
        # FROM (pre-step `trainer.version`), typed into the batch by
        # pack() — not recomputed ad hoc from the rollouts afterwards
        pre_version = self.trainer.version
        batch = pack(rollouts, self.pack_rows, self.pack_seq,
                     trainer_version=pre_version, max_lag=self.max_lag)
        stats = batch.pop("packing_stats")
        trained = batch["loss_mask"] > 0
        lag_vals = batch["lag"][trained]
        max_lag = float(lag_vals.max()) if lag_vals.size else 0.0
        mean_lag = float(lag_vals.mean()) if lag_vals.size else 0.0
        for v, c in zip(*np.unique(lag_vals, return_counts=True)):
            self.lag_hist[int(v)] = self.lag_hist.get(int(v), 0) + int(c)
        self.lag_masked_tokens += int(stats.get("lag_masked", 0))
        # pre-step snapshot (free: the state is not donated, this is a
        # tuple of references) — crash() rolls back to it so the eagerly
        # computed step is truly lost if the trainer dies before `done`
        self._prestep_state = self.trainer.state
        # host batch goes straight in: the trainer stages it with one
        # jitted donated transfer; returned metrics are device-resident
        # and sync only when the log entry below reads them
        if self._poison_pending > 0:
            self._poison_pending -= 1
            metrics = self.trainer.step(batch, poison=True)
        else:
            metrics = self.trainer.step(batch)
        # §10 bad-step policy: a non-finite step was already dropped
        # inside the jitted step (skip-and-count — state and version are
        # untouched); the optional loss-spike detector flags silent
        # divergence. Either way the step consumed its batch and its
        # wall-time, and `consecutive_bad` arms the rollback.
        bad = bool(getattr(self.trainer, "guard", False)) \
            and self.trainer.last_nonfinite()
        if not bad and self.loss_spike_factor > 0.0:
            loss = (metrics.peek("loss") if hasattr(metrics, "peek")
                    else float(metrics["loss"]))
            if self._loss_ewma is not None and \
                    abs(loss) > self.loss_spike_factor * \
                    max(abs(self._loss_ewma), 1e-8):
                bad = True
                self.divergences += 1
            else:
                self._loss_ewma = loss if self._loss_ewma is None else (
                    self._LOSS_ALPHA * loss
                    + (1.0 - self._LOSS_ALPHA) * self._loss_ewma)
        if bad:
            self.bad_steps += 1
            self.consecutive_bad += 1
        else:
            self.consecutive_bad = 0
        n_tokens = sum(r.length for r in rollouts)
        done = start + self.train_time(n_tokens)
        version = self.trainer.version
        stall = 0.0
        do_ckpt = bool(self.ckpt_every and not bad
                       and version % self.ckpt_every == 0)
        if do_ckpt:
            stall = self.ckpt_pause
            done += stall
            self.stalls += 1
        self.busy, self.free_at = True, done
        entry = {
            "version": version,
            "samples": version * self.samples_per_step,
            "time": done,
            "reward": raw_reward,
            "mean_len": float(np.mean([r.length for r in rollouts])),
            "max_lag": max_lag,
            "mean_lag": mean_lag,
            "fill": stats["fill"],
            "queue_depth": queue_depth,
            "stall": stall,
            "bad_step": float(bad),
            **metrics,
        }
        if self.max_lag is not None:
            entry["lag_masked"] = int(stats.get("lag_masked", 0))
        self.log.append(entry)

        epoch = self._epoch

        def _finish(t: float) -> None:
            if epoch != self._epoch:
                return   # the trainer crashed while this step was in flight
            self.busy = False
            # the checkpoint becomes *durable* only when the step that
            # produced it completes: a crash mid-step loses both the step
            # and its would-be checkpoint (exactly a real crash's window)
            if do_ckpt and self.ckpt_dir is not None:
                self.ckpt_path = self._save_ckpt(version)
                self.last_ckpt_version = version
            # a bad step never publishes: its version did not advance,
            # and re-broadcasting the previous weights would only burn
            # interconnect and pause decode for nothing
            if not bad and self.broadcaster is not None and \
                    version % self.update_every == 0:
                self.broadcaster.publish(self.trainer.params, version, t)
            if bad and self.ckpt_dir is not None \
                    and self.bad_step_rollback > 0 \
                    and self.consecutive_bad >= self.bad_step_rollback:
                # divergence circuit breaker: rewind to the newest intact
                # checkpoint (corrupt files are skipped) and start clean
                if self.restore_newest_intact() is not None:
                    self.rollbacks += 1
                    self.consecutive_bad = 0
                    self.free_at = max(self.free_at, t + self.ckpt_pause)
            if on_done is not None:
                on_done(t)
            self.kick(t)
            if self.on_free is not None:
                self.on_free(t)

        self.loop.post(done, _finish)

    # ---- crash-restart (DESIGN.md §8) ---------------------------------
    def crash(self, now: float) -> None:
        """Trainer process dies. The in-flight step (if any) is lost — its
        completion callback is epoch-invalidated, its weights never
        publish, and `steps_lost` counts it. Idempotent while down."""
        if self.failed:
            return
        self.failed = True
        self.crashes += 1
        self._epoch += 1
        if self.busy:
            # the in-flight step was computed eagerly at schedule time;
            # roll its effects back (state snapshot, log entry, history)
            # so it is as if the crash interrupted the step itself
            self.steps_lost += 1
            self.trainer.state = self._prestep_state
            if self.trainer.history:
                self.trainer.history.pop()
            if self.log:
                self.log.pop()
        self.busy = False

    def restore(self, now: float) -> int:
        """Restart the trainer. With a checkpoint directory, the full
        TrainState (params + opt moments + version) reloads from the last
        durable checkpoint — anything trained past it is rolled back, the
        price of crash consistency. Without one this is a warm restart:
        in-memory state survives (the single-process co-sim has no real
        process boundary) but the in-flight step stays lost. Returns the
        version training resumes from."""
        if not self.failed:
            return self.trainer.version
        self.failed = False
        self.recoveries += 1
        self.free_at = max(self.free_at, now)
        if self.ckpt_path is not None:
            # newest-intact fallback (DESIGN.md §10): `trainer_latest`
            # first — bit-identical to the plain restart when it is
            # healthy — then the rotated files, newest to oldest
            self.restore_newest_intact()
        self.kick(now)
        return self.trainer.version


# ---------------------------------------------------------------------------
# weight broadcaster
# ---------------------------------------------------------------------------

class WeightBroadcaster:
    """Publication path from the trainer to an actor pool. The transfer is
    serialized over the trainer's egress interconnect (unicast chain), so
    engine i's data lands after engine i-1's — the pool's staggered
    weight-arrival times fall out of the cost model rather than being a
    separate knob.

    mode:
      "free"     legacy zero-cost instant swap (the pre-§7 behavior;
                 useful as an ablation upper bound)
      "atomic"   whole-tree transfer, engine pauses `broadcast_time`
                 for it (the naive load_weights-style update)
      "streamed" layer-chunked transfer overlapped with decode: chunks
                 arrive every `broadcast_time/n_chunks`; the engine only
                 pauses `bcast_install_flash` per installed chunk and
                 pointer-swaps on the last (the paper's "brief pause")

    Failure semantics (DESIGN.md §8): actors whose stage has `failed`
    set are skipped entirely (no ghost deliveries into a dead engine; a
    rejoining engine instead gets a catch-up atomic sync before
    admission). With a `fault_plan` carrying link faults, the streamed
    path models a lossy interconnect: each chunk transmission consults
    `fault_plan.chunk_lost(engine, version, chunk, attempt, t)` — a pure
    function of the fault identity, so replays are bit-equal — and lost
    chunks retransmit after a capped exponential backoff
    (`t_chunk * min(retransmit_backoff_chunks * 2**attempt,
    backoff_cap_chunks)`), preserving in-order chunk installs."""

    def __init__(self, hw, actors: Sequence[ActorStage],
                 mode: str = "streamed", n_chunks: int = 8,
                 fault_plan: Optional["FaultPlan"] = None,
                 retransmit_backoff_chunks: float = 1.0,
                 backoff_cap_chunks: float = 16.0,
                 executor=None):
        if mode not in ("free", "atomic", "streamed"):
            raise ValueError(f"unknown broadcast mode {mode!r}")
        self.hw, self.actors, self.mode = hw, list(actors), mode
        self.n_chunks = max(int(n_chunks), 1)
        self.fault_plan = fault_plan
        self.retransmit_backoff_chunks = retransmit_backoff_chunks
        self.backoff_cap_chunks = backoff_cap_chunks
        # execution backend (DESIGN.md §11 real-mesh runtime): when set,
        # streamed publications to mesh-placed engines actually reshard
        # every chunk span onto the target's devices at publish time (e.g.
        # launch.meshrt.MeshBroadcastExecutor) and the engine installs the
        # resulting device buffers; the sim's arrival arithmetic is
        # untouched, so the twin keeps predicting the same timeline.
        self.executor = executor
        self.exec_records: List[Dict[str, Any]] = []
        self.published = 0
        self.bytes_published = 0
        self.chunks_lost = 0
        self.chunks_corrupt = 0
        self.retransmit_wait = 0.0
        self.deliveries_skipped = 0

    def _backoff(self, t_chunk: float, attempt: int) -> float:
        backoff = t_chunk * min(
            self.retransmit_backoff_chunks * (2.0 ** attempt),
            self.backoff_cap_chunks)
        self.retransmit_wait += backoff
        return backoff

    def _lossy_arrivals(self, engine: int, version: int, base: float,
                        t_chunk: float, good: Sequence[int]
                        ) -> Tuple[List[float], List[Optional[int]]]:
        """Serialized chunk cursor over a lossy link: chunk k cannot start
        until chunk k-1 landed; each lost transmission burns its slot plus
        a backoff before the retry. Corrupt transmissions (DESIGN.md §10)
        *do* arrive — with a damaged integrity token the engine-side gate
        will reject — then retransmit on the same backoff schedule as a
        loss, so both gray kinds share one recovery path."""
        arrivals: List[float] = []
        tokens: List[Optional[int]] = []
        cursor = base
        for k in range(self.n_chunks):
            attempt = 0
            while True:
                cursor += t_chunk
                if attempt >= _MAX_XMIT_ATTEMPTS:
                    break
                if self.fault_plan.chunk_lost(engine, version, k, attempt,
                                              cursor):
                    self.chunks_lost += 1
                    cursor += self._backoff(t_chunk, attempt)
                    attempt += 1
                    continue
                if k < len(good) and self.fault_plan.chunk_corrupted(
                        engine, version, k, attempt, cursor):
                    # delivered but damaged: the receiver sees the chunk,
                    # its checksum mismatches, and the sender retransmits
                    self.chunks_corrupt += 1
                    arrivals.append(cursor)
                    tokens.append(good[k] ^ 0x5AD0BAD)
                    cursor += self._backoff(t_chunk, attempt)
                    attempt += 1
                    continue
                break
            arrivals.append(cursor)
            tokens.append(good[k] if k < len(good) else None)
        return arrivals, tokens

    def publish(self, params, version: int, now: float) -> None:
        self.published += 1
        targets = [(i, a) for i, a in enumerate(self.actors)
                   if not getattr(a, "failed", False)]
        self.deliveries_skipped += len(self.actors) - len(targets)
        nbytes = tree_bytes(params)
        self.bytes_published += nbytes * len(targets)
        if self.mode == "free":
            for _, a in targets:
                a.deliver_atomic(now, params, version, pause=0.0)
            return
        t_full = self.hw.broadcast_time(nbytes)
        if self.mode == "atomic":
            for j, (_, a) in enumerate(targets):
                a.deliver_atomic(now + (j + 1) * t_full, params, version,
                                 pause=t_full)
            return
        t_chunk = t_full / self.n_chunks
        lossy = self.fault_plan is not None and self.fault_plan.has_link_faults()
        # integrity gate (DESIGN.md §10): per-chunk checksum tokens +
        # whole-publication digest, computed sender-side from the same
        # deterministic span table the engines derive independently
        import jax
        leaves = jax.tree.leaves(params)
        sizes = span_bytes(leaves, chunk_spans(leaves, self.n_chunks))
        good = [chunk_token(version, k, sizes[k])
                for k in range(len(sizes))]
        digest = stream_digest(good)
        for j, (i, a) in enumerate(targets):
            base = now + j * t_full
            if lossy:
                arrivals, tokens = self._lossy_arrivals(
                    i, version, base, t_chunk, good)
            else:
                # keep the exact pre-fault arithmetic on healthy links so
                # no-fault runs stay bit-identical to earlier behavior
                arrivals = [base + (k + 1) * t_chunk
                            for k in range(self.n_chunks)]
                tokens = [good[k] if k < len(good) else None
                          for k in range(self.n_chunks)]
            ck = None
            if (self.executor is not None
                    and getattr(a.engine, "_pshard_leaves", None)
                    is not None):
                rec = self.executor.run(a.engine, params, version,
                                        self.n_chunks)
                ck = rec["chunks"]
                self.exec_records.append({
                    "engine": a.name, "version": version,
                    "nbytes": rec["nbytes"], "seconds": rec["seconds"],
                    "per_chunk": rec["per_chunk"]})
            a.deliver_stream(params, version, arrivals,
                             install_pause=self.hw.bcast_install_flash,
                             tokens=tokens, n_chunks=self.n_chunks,
                             digest=digest, chunk_leaves=ck)

    def stats(self) -> Dict[str, Any]:
        per_engine = []
        for a in self.actors:
            per_engine.append({
                "name": a.name,
                "updates_applied": a.updates_applied,
                "streams_completed": a.streams_completed,
                "streams_aborted": a.streams_aborted,
                "wchunks_rejected": getattr(a.engine, "wchunks_rejected", 0),
                "wstreams_torn": getattr(a.engine, "wstreams_torn", 0),
                "pause_total": a.pause_total,
                "pause_per_update": (a.pause_total / a.updates_applied
                                     if a.updates_applied else 0.0),
            })
        return {
            "mode": self.mode,
            "published": self.published,
            "executed": len(self.exec_records),
            "exec_seconds": sum(r["seconds"] for r in self.exec_records),
            "bytes_published": self.bytes_published,
            "chunks_lost": self.chunks_lost,
            "chunks_corrupt": self.chunks_corrupt,
            "retransmit_wait": self.retransmit_wait,
            "deliveries_skipped": self.deliveries_skipped,
            "engines": per_engine,
        }
