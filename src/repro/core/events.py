"""Event-driven orchestration substrate (DESIGN.md §7).

One discrete-event scheduler replaces the three bespoke orchestration
loops (`PipelineRL.run`, `ConventionalRL.run`, `Server.step`): stages
post callbacks onto a shared simulated clock and react to each other's
completions. `PipelineRL`, `ConventionalRL` and `Server` become
*configurations* of the same stage library rather than separate control
flows — which is what lets the orchestration layer grow new scenarios
(actor pools, overlapped preprocessing, costed weight broadcast, trainer
stalls) without forking the loop again.

Stage contracts (all times are simulated flashes unless a stage installs
its own cost model, e.g. the Server's step-denominated clock):

  ActorStage        owns one `GenerationEngine`; self-schedules decode
                    ticks; at each tick boundary it first installs any
                    arrived weight publications (atomic swaps or streamed
                    chunks — the *only* place weights may change, so
                    per-token version stamps stay exact), then steps the
                    engine, delivers finished rollouts downstream, and
                    refills. Goes idle when the engine drains and
                    `auto_refill` is off (ConventionalRL's phase end) or
                    when externally driven (`chain=False`, the Server).
                    `preempt(at, d)` takes the engine offline for
                    [at, at+d): ticks starting inside the window defer to
                    its end; in-flight slots are untouched and resume.
  PoolRouter        pluggable admission between one shared prompt source
                    and the pool's engines: fifo (pass-through pull,
                    today's behavior), shortest_queue (decline engines
                    whose speed-normalized backlog is deep), and
                    length_affinity (buffer `lookahead` pending prompts;
                    fast engines take the longest, slow the shortest —
                    long-prompt prefill lands on the cheapest compute).
  PreprocessStage   pulls B rollouts from the SampleQueue when free,
                    holds them for `stage_time`, delivers the processed
                    batch to the trainer — an *overlapped* stage on its
                    own chips (paper Fig. 4), not latency serialized into
                    the trainer tick. It runs at most one batch ahead so
                    back-pressure still lands on the SampleQueue (whose
                    drop-oldest policy is what bounds lag).
  TrainerStage      consumes batches (from its inbox or by pulling from
                    the queue), runs the real optimizer step eagerly,
                    stamps completion on the clock, publishes weights
                    through the WeightBroadcaster every `update_every`
                    versions, and can stall for checkpoints.
  WeightBroadcaster turns a publication into per-engine delivery
                    schedules costed by `HardwareModel.broadcast_time`:
                    atomic (engine pauses for the whole transfer) or
                    streamed (chunks overlap decode; the engine only
                    pauses `bcast_install_flash` per installed chunk and
                    pointer-swaps on the last one).

Clock invariants: events fire in nondecreasing time order (FIFO on
ties); a stage's own timeline is nondecreasing; rollout `finished_at`
stamps are the actor-tick completion times, so `SampleQueue` arrival
order is consistent with the simulated clock.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.packing import Rollout, pack


# ---------------------------------------------------------------------------
# event loop
# ---------------------------------------------------------------------------

class EventLoop:
    """Minimal deterministic discrete-event scheduler: a time-ordered heap
    of callbacks with FIFO tie-breaking. `run(until=...)` processes events
    until the predicate holds or the heap drains; pending events survive,
    so orchestrators built on top are resumable (`run(n)` then `run(m)`)."""

    def __init__(self):
        self._heap: List[Tuple[float, int, Callable[[float], None]]] = []
        self._seq = 0
        self.now = 0.0
        self.events_processed = 0

    def post(self, time: float, fn: Callable[[float], None]) -> None:
        """Schedule `fn(fire_time)`. Times before `now` are clamped to
        `now` (a stage may not rewind the clock)."""
        heapq.heappush(self._heap, (max(time, self.now), self._seq, fn))
        self._seq += 1

    def __len__(self) -> int:
        return len(self._heap)

    def step(self) -> bool:
        """Process the earliest event; False if none remain."""
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self.now = t
        self.events_processed += 1
        fn(t)
        return True

    def run(self, until: Optional[Callable[[], bool]] = None,
            max_events: int = 10_000_000) -> None:
        for _ in range(max_events):
            if until is not None and until():
                return
            if not self.step():
                return
        raise RuntimeError("EventLoop.run exceeded max_events — "
                           "a stage is posting events without progress")


# ---------------------------------------------------------------------------
# param-tree helpers (shared by the engine's stream API, the broadcaster's
# costing and the launcher's chunked weight-update lowering)
# ---------------------------------------------------------------------------

def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (anything with .size/.dtype)."""
    import jax
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def chunk_spans(leaves: Sequence[Any], n_chunks: int) -> List[Tuple[int, int]]:
    """Partition a leaf list into <= n_chunks contiguous, byte-balanced
    [lo, hi) spans — the layer-chunked publication unit of the streamed
    broadcast. Leaf granularity keeps the swap trivially exact (a leaf is
    never split across chunks)."""
    n_chunks = max(int(n_chunks), 1)
    sizes = [int(x.size * x.dtype.itemsize) for x in leaves]
    total = sum(sizes)
    if not leaves:
        return []
    target = total / n_chunks
    spans: List[Tuple[int, int]] = []
    lo, acc = 0, 0
    for i, s in enumerate(sizes):
        acc += s
        # close the span once it reaches the byte target, keeping enough
        # leaves for the remaining chunks to be non-empty
        remaining_chunks = n_chunks - len(spans)
        remaining_leaves = len(leaves) - (i + 1)
        if (acc >= target and remaining_chunks > 1) or \
                remaining_leaves < remaining_chunks - 1:
            if i + 1 > lo:
                spans.append((lo, i + 1))
                lo, acc = i + 1, 0
        if len(spans) == n_chunks - 1:
            break
    if lo < len(leaves):
        spans.append((lo, len(leaves)))
    return spans


def span_bytes(leaves: Sequence[Any],
               spans: Sequence[Tuple[int, int]]) -> List[int]:
    return [int(sum(x.size * x.dtype.itemsize for x in leaves[lo:hi]))
            for lo, hi in spans]


# ---------------------------------------------------------------------------
# shared metric helpers (exported to pipeline.py for API compatibility)
# ---------------------------------------------------------------------------

def lag_stats(rollouts: List[Rollout], trainer_version: int):
    """(max, mean) token lag of completion tokens vs `trainer_version`."""
    lags = []
    for r in rollouts:
        mask = np.arange(r.length) >= r.prompt_len
        lags.append((trainer_version - r.weight_versions)[mask])
    if not lags:
        return 0.0, 0.0
    cat = np.concatenate(lags)
    if cat.size == 0:
        return 0.0, 0.0
    return float(cat.max()), float(cat.mean())


def apply_group_baseline(rollouts: List[Rollout]) -> List[Rollout]:
    """GRPO-style: reward <- reward - mean(rewards of same-prompt rollouts).
    Returns shallow copies so queue bookkeeping is untouched."""
    import copy
    groups: Dict[int, List[float]] = {}
    for r in rollouts:
        groups.setdefault(r.prompt_key, []).append(r.reward)
    means = {k: float(np.mean(v)) for k, v in groups.items()}
    out = []
    for r in rollouts:
        r2 = copy.copy(r)
        r2.reward = r.reward - means[r.prompt_key]
        out.append(r2)
    return out


# ---------------------------------------------------------------------------
# actor stage
# ---------------------------------------------------------------------------

class ActorStage:
    """One generation engine on the event loop.

    step_cost(h) / prefill_cost(tokens, invocations) are the stage's cost
    model — PipelineRL passes HardwareModel closures over its chip share,
    the Server passes its step-denominated dt costs. Weight publications
    arrive via `deliver_atomic` / `deliver_stream` and are installed only
    at tick boundaries (Algorithm 2 l. 9-11), charging the decode-pause
    the HardwareModel assigns to the mode.
    """

    def __init__(self, loop: EventLoop, engine, *,
                 task=None, name: str = "actor0",
                 step_cost: Callable[[float], float] = lambda h: 1.0,
                 prefill_cost: Callable[[int, int], float] = lambda t, i: 0.0,
                 deliver: Optional[Callable[[List[Rollout], float], None]] = None,
                 auto_refill: bool = True, refill_first: bool = False,
                 chain: bool = True,
                 on_drained: Optional[Callable[[float], None]] = None,
                 recompute_kv: bool = False):
        self.loop, self.engine, self.task, self.name = loop, engine, task, name
        self.step_cost, self.prefill_cost = step_cost, prefill_cost
        self.deliver = deliver or (lambda rollouts, t: None)
        self.auto_refill, self.refill_first = auto_refill, refill_first
        self.chain, self.on_drained = chain, on_drained
        self.recompute_kv = recompute_kv
        self.running = False
        self.time = 0.0                    # this engine's own clock
        # weight deliveries
        self._atomic: List[Tuple[float, Any, int, float]] = []
        self._stream: Optional[Dict[str, Any]] = None
        self._next_stream: Optional[Tuple] = None   # newest pending publish
        # timed preemption windows [start, end) — sorted by start
        self._preempt: List[Tuple[float, float]] = []
        self.preempt_total = 0.0           # wall-time spent offline
        self.preemptions_taken = 0         # deferrals actually hit
        # accounting (read by orchestrators / benchmarks)
        self.updates_applied = 0
        self.streams_completed = 0
        self.streams_aborted = 0
        self.pause_total = 0.0             # decode pause charged to updates
        self.pause_log: List[Tuple[int, float]] = []   # (version, pause)

    # ---- weight delivery (called by WeightBroadcaster / Server) --------
    def deliver_atomic(self, arrive: float, params, version: int,
                       pause: float) -> None:
        """Whole-tree publication arriving at `arrive`; the engine pauses
        `pause` flashes at the install boundary (the blocking transfer)."""
        self._atomic.append((arrive, params, version, pause))
        self._atomic.sort(key=lambda x: x[0])

    def deliver_stream(self, params, version: int, arrivals: Sequence[float],
                       install_pause: float, per_tick: int = 0,
                       recompute_kv: Optional[bool] = None) -> None:
        """Chunked publication: chunk k arrives at arrivals[k]; each
        install pauses decode `install_pause`; pointer-swap after the
        last. While a stream is in flight, a new publication *waits* (the
        in-flight transfer always completes, so the policy keeps making
        forward progress even when `broadcast_time` exceeds the publish
        interval) — but only the newest waiting publication survives:
        superseded pending ones are counted in `streams_aborted`."""
        rk = self.recompute_kv if recompute_kv is None else recompute_kv
        if self._stream is not None:
            if self._next_stream is not None:
                self.streams_aborted += 1
            self._next_stream = (params, version, list(arrivals),
                                 install_pause, per_tick, rk)
            return
        sizes = self.engine.begin_weight_stream(
            params, version, n_chunks=len(arrivals), recompute_kv=rk)
        self._stream = dict(version=version, arrivals=deque(arrivals),
                            n_chunks=len(sizes), pause=install_pause,
                            per_tick=per_tick, accum=0.0)

    def _install_weights(self, now: float) -> float:
        """Apply every publication that has arrived by `now`; returns the
        decode pause charged to this tick."""
        pause = 0.0
        while self._atomic and self._atomic[0][0] <= now:
            _, params, version, cost = self._atomic.pop(0)
            # an atomic swap supersedes any in-flight/pending stream
            if self._stream is not None:
                self.streams_aborted += 1
                self._stream = None
            if self._next_stream is not None:
                self.streams_aborted += 1
                self._next_stream = None
            self.engine.set_weights(params, version,
                                    recompute_kv=self.recompute_kv)
            pause += cost
            self.updates_applied += 1
            self.pause_log.append((version, cost))
        st = self._stream
        if st is not None:
            installed = 0
            while st["arrivals"] and st["arrivals"][0] <= now:
                if st["per_tick"] and installed >= st["per_tick"]:
                    break
                st["arrivals"].popleft()
                done = self.engine.stream_weight_chunk()
                pause += st["pause"]
                st["accum"] += st["pause"]
                installed += 1
                if done:
                    self.updates_applied += 1
                    self.streams_completed += 1
                    self.pause_log.append((st["version"], st["accum"]))
                    self._stream = None
                    # promote the newest publication that waited for the
                    # in-flight transfer to finish
                    if self._next_stream is not None:
                        nxt, self._next_stream = self._next_stream, None
                        self.deliver_stream(nxt[0], nxt[1], nxt[2], nxt[3],
                                            per_tick=nxt[4],
                                            recompute_kv=nxt[5])
                    break
        self.pause_total += pause
        return pause

    # ---- preemption (DESIGN.md §7 pool scheduling) ---------------------
    def preempt(self, start: float, duration: float) -> None:
        """Take the engine offline for [start, start+duration): any tick
        that would *begin* inside the window is deferred to the window
        end (a decode step already under way when the window opens
        completes — discrete-event granularity, checkpoint-style
        preemption). In-flight slots keep their KV/recurrent state and
        resume untouched; weight publications that arrive during the
        window install at the deferred tick. Overlapping and abutting
        windows compose."""
        if duration <= 0:
            return
        self._preempt.append((float(start), float(start) + float(duration)))
        self._preempt.sort()

    def _preempt_until(self, now: float) -> Optional[float]:
        """Resume time if `now` falls inside a preemption window (chained
        windows are followed transitively); None when online. Windows
        wholly in the past are discarded."""
        t = now
        for s, e in self._preempt:
            if s <= t < e:
                t = e
        self._preempt = [(s, e) for (s, e) in self._preempt if e > t]
        return t if t > now else None

    # ---- lifecycle -----------------------------------------------------
    def start(self, t: float) -> None:
        if not self.running:
            self.running = True
            self.loop.post(t, self.tick)

    def _refill(self, now: float) -> float:
        inv0 = getattr(self.engine, "prefill_invocations", 0)
        admitted = self.engine.refill(now)
        if not admitted:
            return 0.0
        inv = getattr(self.engine, "prefill_invocations", 0) - inv0
        return self.prefill_cost(self.engine.last_admit_prefill_tokens, inv)

    def tick(self, now: float) -> None:
        """One decode step: install weights -> (refill) -> step -> deliver
        -> (refill) -> reschedule."""
        resume = self._preempt_until(now)
        if resume is not None:
            self.preempt_total += resume - now
            self.preemptions_taken += 1
            self.loop.post(resume, self.tick)
            return
        pause = self._install_weights(now)
        c_pre = 0.0
        if self.auto_refill and (self.refill_first
                                 or self.engine.n_active == 0):
            c_pre += self._refill(now)
        h = self.engine.n_active
        if h == 0:
            # nothing to decode: drained (conventional phase end) or idle
            # (server with no requests). The tick still consumes wall time
            # under a per-step cost model (step_cost(0) is dt for the
            # Server, 0 for the flash model) and any weight-install pause
            # stays on the timeline.
            t = now + pause + c_pre + self.step_cost(0)
            self.time = max(self.time, t)
            self.deliver([], t)
            self.running = False
            if self.on_drained is not None:
                self.on_drained(t)
            return
        finished = self.engine.step(self.task, now=now)
        t_done = now + pause + c_pre + self.step_cost(h)
        for r in finished:
            r.finished_at = t_done
        self.time = t_done
        self.deliver(finished, t_done)
        if self.auto_refill and not self.refill_first:
            t_done += self._refill(t_done)
        if self.engine.n_active == 0 and not self.auto_refill:
            self.running = False
            if self.on_drained is not None:
                self.on_drained(t_done)
            return
        if self.chain:
            self.loop.post(t_done, self.tick)
        else:
            self.running = False


# ---------------------------------------------------------------------------
# pool router (priority/affinity admission across the actor pool)
# ---------------------------------------------------------------------------

class PoolRouter:
    """Pluggable admission layer between one shared prompt source and the
    engines of an actor pool (DESIGN.md §7 "Pool scheduling").

    Engines keep their pull-based admission: each free slot asks its
    per-engine view (`source_for(i)`) for a prompt during refill. The
    router decides what that pull returns:

      fifo             pass-through: the requesting engine takes the next
                       prompt from the source — bit-identical to wiring
                       the source into every engine directly (default).
      shortest_queue   the requesting engine is granted the next prompt
                       only while its speed-normalized outstanding decode
                       work is within `slack` tokens of the pool minimum;
                       otherwise the pull is declined (the slot stays
                       free and is re-offered at the engine's next tick),
                       so slow/deep engines stop hoarding prompts.
      length_affinity  the router keeps up to `lookahead` pending prompts
                       drawn from the source; engines at or above the
                       mean pool speed take the *longest* pending prompt,
                       slower engines the *shortest* — long prompts'
                       prefill (and their short remaining completion
                       budget) land on the cheapest compute.

    All decisions read only the prompt stream and the engines' host
    mirrors (`_host_active`/`_host_ncached` — the prompt-length histogram
    the engines already keep on host): no wall-clock, no RNG, so routing
    is deterministic under the simulated clock.
    """

    POLICIES = ("fifo", "shortest_queue", "length_affinity")

    def __init__(self, source: Callable[[], Optional[Any]],
                 policy: str = "fifo", lookahead: int = 0,
                 slack: Optional[float] = None):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"choose from {self.POLICIES}")
        self.source, self.policy = source, policy
        self.lookahead, self.slack = int(lookahead), slack
        self.pending: deque = deque()
        self.engines: List[Any] = []
        self.speeds: List[float] = []
        self.assigned: List[int] = []
        self.assigned_tokens: List[int] = []
        self.declined: List[int] = []

    def attach(self, engines: Sequence[Any],
               speeds: Optional[Sequence[float]] = None) -> None:
        self.engines = list(engines)
        n = len(self.engines)
        self.speeds = [float(s) for s in speeds] if speeds is not None \
            else [1.0] * n
        if len(self.speeds) != n:
            raise ValueError(f"{len(self.speeds)} speeds for {n} engines")
        self.assigned = [0] * n
        self.assigned_tokens = [0] * n
        self.declined = [0] * n
        if self.lookahead <= 0:
            self.lookahead = sum(e.ec.n_slots for e in self.engines)
        if self.slack is None:
            self.slack = float(max(e.ec.max_len for e in self.engines))

    def source_for(self, i: int) -> Callable[[], Optional[Any]]:
        """The prompt-source callable engine `i` pulls from."""
        return lambda: self.request(i)

    # ---- internals -----------------------------------------------------
    def _load(self, j: int) -> float:
        """Speed-normalized outstanding decode work of engine j: remaining
        token budget of its active slots, in slow-chip token units."""
        eng = self.engines[j]
        act = eng._host_active
        rem = int((eng.ec.max_len - 1 - eng._host_ncached[act]).sum())
        return rem / max(self.speeds[j], 1e-9)

    def _draw(self) -> Optional[Any]:
        if self.pending:
            return self.pending.popleft()
        return self.source()

    def _grant(self, i: int, prob: Any) -> Any:
        self.assigned[i] += 1
        self.assigned_tokens[i] += len(prob.prompt_ids)
        return prob

    # ---- the per-engine pull -------------------------------------------
    def request(self, i: int) -> Optional[Any]:
        if self.policy == "shortest_queue":
            loads = [self._load(j) for j in range(len(self.engines))]
            if loads[i] - min(loads) > self.slack:
                self.declined[i] += 1
                return None
        if self.policy != "length_affinity":
            prob = self._draw()
            return self._grant(i, prob) if prob is not None else None
        # length_affinity: top up the pending buffer, then pick by length
        while len(self.pending) < self.lookahead:
            p = self.source()
            if p is None:
                break
            self.pending.append(p)
        if not self.pending:
            return None
        lens = [len(p.prompt_ids) for p in self.pending]
        mean_speed = sum(self.speeds) / max(len(self.speeds), 1)
        if self.speeds[i] >= mean_speed:
            # ties break toward the earliest pending prompt (FIFO within
            # equal lengths) so routing stays deterministic
            k = max(range(len(lens)), key=lambda j: (lens[j], -j))
        else:
            k = min(range(len(lens)), key=lambda j: (lens[j], j))
        prob = self.pending[k]
        del self.pending[k]
        return self._grant(i, prob)

    def stats(self) -> Dict[str, Any]:
        return {
            "policy": self.policy,
            "pending": len(self.pending),
            "engines": [
                {"assigned": a, "prompt_tokens": t, "declined": d}
                for a, t, d in zip(self.assigned, self.assigned_tokens,
                                   self.declined)],
        }


# ---------------------------------------------------------------------------
# preprocessor stage (paper Fig. 4 middle stage, overlapped)
# ---------------------------------------------------------------------------

class PreprocessStage:
    """Pulls B rollouts from the SampleQueue when both it and the trainer
    inbox are free, holds them for `preprocessor.stage_time`, then submits
    the processed batch to the trainer. Runs concurrently with both
    neighbors — while batch k preprocesses, the actors generate k+1 and
    the trainer trains k-1 — instead of adding its latency to the trainer
    tick. At most one batch is in flight and one may wait in the trainer
    inbox, so a trainer stall backs pressure up into the SampleQueue
    (drop-oldest) rather than into an unbounded inbox."""

    def __init__(self, loop: EventLoop, preprocessor, queue, batch_size: int,
                 trainer_stage: "TrainerStage"):
        self.loop, self.pre, self.queue = loop, preprocessor, queue
        self.batch_size = batch_size
        self.trainer_stage = trainer_stage
        self.busy = False
        self.busy_until = 0.0
        self.batches = 0

    def kick(self, now: float) -> None:
        if self.busy or len(self.queue) < self.batch_size:
            return
        # overlap contract: preprocess batch k+1 while the trainer runs
        # batch k, but never queue a second *finished* batch at the
        # trainer — that's where back-pressure must fold back into the
        # SampleQueue (a busy trainer alone does not block us)
        if self.trainer_stage.inbox_waiting() > 0:
            return
        rollouts = self.queue.pop(self.batch_size)
        raw_reward = float(np.mean([r.reward for r in rollouts]))
        t_avail = max((r.finished_at for r in rollouts), default=now)
        processed = self.pre.process(rollouts)
        start = max(now, t_avail, self.busy_until)
        done = start + self.pre.stage_time(
            sum(r.length for r in processed))
        self.busy, self.busy_until = True, done
        self.batches += 1

        def _deliver(t: float) -> None:
            self.busy = False
            self.trainer_stage.submit(processed, t, raw_reward=raw_reward)
            self.kick(t)

        self.loop.post(done, _deliver)


# ---------------------------------------------------------------------------
# trainer stage
# ---------------------------------------------------------------------------

class TrainerStage:
    """Wraps a `Trainer` on the event loop: consumes batches from an inbox
    (fed by `submit`) or by pulling B rollouts from `queue` when idle,
    runs the real optimizer step eagerly, stamps completion on the
    simulated clock, publishes weights via the broadcaster, and models
    checkpoint stalls (`ckpt_every`/`ckpt_pause` — the scenario the
    SampleQueue's drop-oldest policy exists for)."""

    def __init__(self, loop: EventLoop, trainer, *, queue=None,
                 batch_size: int = 0,
                 train_time: Callable[[int], float] = lambda n: 0.0,
                 pack_rows: int = 8, pack_seq: int = 128,
                 log: Optional[List[Dict]] = None,
                 broadcaster: Optional["WeightBroadcaster"] = None,
                 update_every: int = 1, group_baseline: bool = False,
                 ckpt_every: int = 0, ckpt_pause: float = 0.0,
                 samples_per_step: Optional[int] = None,
                 on_free: Optional[Callable[[float], None]] = None):
        self.loop, self.trainer = loop, trainer
        self.queue, self.batch_size = queue, batch_size
        self.train_time = train_time
        self.pack_rows, self.pack_seq = pack_rows, pack_seq
        self.log = log if log is not None else []
        self.broadcaster = broadcaster
        self.update_every = max(int(update_every), 1)
        self.group_baseline = group_baseline
        self.ckpt_every, self.ckpt_pause = ckpt_every, ckpt_pause
        self.samples_per_step = samples_per_step or batch_size
        self.on_free = on_free
        self.busy = False
        self.free_at = 0.0
        self.stalls = 0
        self._inbox: deque = deque()   # (rollouts, raw_reward, avail, on_done)

    def inbox_depth(self) -> int:
        """Batches owned by the trainer: waiting in the inbox + in step."""
        return len(self._inbox) + (1 if self.busy else 0)

    def inbox_waiting(self) -> int:
        """Batches delivered but not yet started (excludes the running
        step) — the quantity the preprocessor's run-ahead bound is on."""
        return len(self._inbox)

    def submit(self, rollouts: List[Rollout], now: float,
               raw_reward: Optional[float] = None,
               on_done: Optional[Callable[[float], None]] = None) -> None:
        avail = max((r.finished_at for r in rollouts), default=now)
        self._inbox.append((rollouts, raw_reward, avail, on_done))
        self.kick(now)

    def kick(self, now: float) -> None:
        if self.busy:
            return
        if self._inbox:
            rollouts, raw_reward, avail, on_done = self._inbox.popleft()
        elif (self.queue is not None and self.batch_size
                and len(self.queue) >= self.batch_size):
            rollouts = self.queue.pop(self.batch_size)
            raw_reward, on_done = None, None
            avail = max((r.finished_at for r in rollouts), default=now)
        else:
            return
        self._train(rollouts, raw_reward, avail, now, on_done)

    def _train(self, rollouts, raw_reward, avail, now, on_done) -> None:
        start = max(now, self.free_at, avail)
        if raw_reward is None:
            raw_reward = float(np.mean([r.reward for r in rollouts]))
        queue_depth = len(self.queue) if self.queue is not None else 0
        if self.group_baseline:
            rollouts = apply_group_baseline(rollouts)
        batch = pack(rollouts, self.pack_rows, self.pack_seq)
        stats = batch.pop("packing_stats")
        # host batch goes straight in: the trainer stages it with one
        # jitted donated transfer; returned metrics are device-resident
        # and sync only when the log entry below reads them
        metrics = self.trainer.step(batch)
        n_tokens = sum(r.length for r in rollouts)
        done = start + self.train_time(n_tokens)
        version = self.trainer.version
        max_lag, mean_lag = lag_stats(rollouts, version - 1)
        stall = 0.0
        if self.ckpt_every and version % self.ckpt_every == 0:
            stall = self.ckpt_pause
            done += stall
            self.stalls += 1
        self.busy, self.free_at = True, done
        self.log.append({
            "version": version,
            "samples": version * self.samples_per_step,
            "time": done,
            "reward": raw_reward,
            "mean_len": float(np.mean([r.length for r in rollouts])),
            "max_lag": max_lag,
            "mean_lag": mean_lag,
            "fill": stats["fill"],
            "queue_depth": queue_depth,
            "stall": stall,
            **metrics,
        })

        def _finish(t: float) -> None:
            self.busy = False
            if self.broadcaster is not None and \
                    version % self.update_every == 0:
                self.broadcaster.publish(self.trainer.params, version, t)
            if on_done is not None:
                on_done(t)
            self.kick(t)
            if self.on_free is not None:
                self.on_free(t)

        self.loop.post(done, _finish)


# ---------------------------------------------------------------------------
# weight broadcaster
# ---------------------------------------------------------------------------

class WeightBroadcaster:
    """Publication path from the trainer to an actor pool. The transfer is
    serialized over the trainer's egress interconnect (unicast chain), so
    engine i's data lands after engine i-1's — the pool's staggered
    weight-arrival times fall out of the cost model rather than being a
    separate knob.

    mode:
      "free"     legacy zero-cost instant swap (the pre-§7 behavior;
                 useful as an ablation upper bound)
      "atomic"   whole-tree transfer, engine pauses `broadcast_time`
                 for it (the naive load_weights-style update)
      "streamed" layer-chunked transfer overlapped with decode: chunks
                 arrive every `broadcast_time/n_chunks`; the engine only
                 pauses `bcast_install_flash` per installed chunk and
                 pointer-swaps on the last (the paper's "brief pause")
    """

    def __init__(self, hw, actors: Sequence[ActorStage],
                 mode: str = "streamed", n_chunks: int = 8):
        if mode not in ("free", "atomic", "streamed"):
            raise ValueError(f"unknown broadcast mode {mode!r}")
        self.hw, self.actors, self.mode = hw, list(actors), mode
        self.n_chunks = max(int(n_chunks), 1)
        self.published = 0
        self.bytes_published = 0

    def publish(self, params, version: int, now: float) -> None:
        self.published += 1
        nbytes = tree_bytes(params)
        self.bytes_published += nbytes * len(self.actors)
        if self.mode == "free":
            for a in self.actors:
                a.deliver_atomic(now, params, version, pause=0.0)
            return
        t_full = self.hw.broadcast_time(nbytes)
        if self.mode == "atomic":
            for i, a in enumerate(self.actors):
                a.deliver_atomic(now + (i + 1) * t_full, params, version,
                                 pause=t_full)
            return
        t_chunk = t_full / self.n_chunks
        for i, a in enumerate(self.actors):
            base = now + i * t_full
            arrivals = [base + (k + 1) * t_chunk
                        for k in range(self.n_chunks)]
            a.deliver_stream(params, version, arrivals,
                             install_pause=self.hw.bcast_install_flash)

    def stats(self) -> Dict[str, Any]:
        per_engine = []
        for a in self.actors:
            per_engine.append({
                "name": a.name,
                "updates_applied": a.updates_applied,
                "streams_completed": a.streams_completed,
                "streams_aborted": a.streams_aborted,
                "pause_total": a.pause_total,
                "pause_per_update": (a.pause_total / a.updates_applied
                                     if a.updates_applied else 0.0),
            })
        return {
            "mode": self.mode,
            "published": self.published,
            "bytes_published": self.bytes_published,
            "engines": per_engine,
        }
