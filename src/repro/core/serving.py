"""Request-level serving front over the continuous-batching engine.

Mirrors the paper's three integration endpoints (§4 "Architecture and
Implementation Details") in-process:

  - submit()/step()            ~ /v1/chat/completions (batched, continuous)
  - connect_trainer()          ~ /init_process_group (weight-transfer pairing)
  - request_weight_update()    ~ /request_weight_update (in-flight update)

Since DESIGN.md §7 the server is a configuration of the shared
event-driven substrate: one externally-driven `ActorStage`
(`chain=False` — each `step(dt)` posts exactly one admission+decode tick
onto the `EventLoop`) with a step-denominated cost model (`dt` per decode
step, `dt` per chunked-prefill invocation) instead of the RL
orchestrators' flash-unit HardwareModel closures.

Tracks per-request latency (admission wait, end-to-end) so serving SLOs
are measurable across in-flight updates — the paper's headline property:
the engine only *briefly pauses* for new weights, no request is dropped.
Admission is policy-driven (`admission="fifo"|"sjf"` — shortest prompt
first, the serving analogue of the pool router's length affinity), and
prompts longer than the engine's budget fail fast: the request comes
back `rejected=True` (counted in `metrics()["prompts_rejected"]`)
instead of being silently truncated or hung.
`request_weight_update(streamed=True)` exercises the chunked publication
path: the new weights install one chunk per serving step and the policy
version flips only at the final pointer swap.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.events import ActorStage, EventLoop
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.data.math_task import Problem


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: List[int]
    submitted_at: float = 0.0
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    completion_ids: Optional[np.ndarray] = None
    weight_versions: Optional[np.ndarray] = None
    rejected: bool = False      # prompt longer than the engine's budget

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


class _QueueSource:
    """Prompt source draining the server's waiting queue (None when empty);
    records which Request each admitted Problem belongs to. `admission`
    orders the drain: "fifo" (submission order) or "sjf" (shortest prompt
    first — the serving analogue of the pool router's length-affinity
    admission; ties break by submission order, so it stays deterministic
    and starvation shows up as admission wait, not nondeterminism)."""

    def __init__(self, server: "Server", admission: str = "fifo"):
        if admission not in ("fifo", "sjf"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.server = server
        self.admission = admission

    def __call__(self) -> Optional[Problem]:
        waiting = self.server.waiting
        if not waiting:
            return None
        if self.admission == "sjf":
            k = min(range(len(waiting)),
                    key=lambda i: (len(waiting[i].prompt_ids), i))
            req = waiting[k]
            del waiting[k]
        else:
            req = waiting.popleft()
        req.admitted_at = self.server.clock
        self.server.in_flight[req.rid] = req
        prob = Problem(req.prompt_ids, 0)
        prob.rid = req.rid  # type: ignore[attr-defined]
        return prob


class Server:
    """Continuous-batching server with in-flight weight updates."""

    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig,
                 seed: int = 0, admission: str = "fifo"):
        self.cfg, self.ec = cfg, ec
        self.waiting: deque = deque()
        self.in_flight: Dict[int, Request] = {}
        self.done: List[Request] = []
        self.rejected: List[Request] = []
        self._next_rid = 0
        self._trainer: Optional[Callable] = None
        self._source = _QueueSource(self, admission=admission)
        self.engine = GenerationEngine(cfg, params, ec, self._source,
                                       seed=seed)
        self.engine.on_prompt_rejected = self._reject
        self.loop = EventLoop()
        self._dt = 1.0
        self._updates = 0
        self._completed_now: List[Request] = []
        self.actor = ActorStage(
            self.loop, self.engine, task=None, name="server",
            step_cost=lambda h: self._dt,
            prefill_cost=lambda toks, inv: self._dt * inv,
            deliver=self._complete, auto_refill=True, refill_first=True,
            chain=False)

    @property
    def clock(self) -> float:
        return self.loop.now

    # ---- the three endpoints -----------------------------------------
    def submit(self, prompt_ids: List[int]) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append(Request(rid, list(prompt_ids),
                                    submitted_at=self.clock))
        return rid

    def connect_trainer(self, get_weights: Callable[[], tuple]) -> None:
        """Pair with a trainer: `get_weights() -> (params, version)`."""
        self._trainer = get_weights

    def request_weight_update(self, recompute_kv: bool = False,
                              streamed: bool = False,
                              n_chunks: int = 8) -> int:
        """In-flight update. Atomic (default): swap weights at the next
        step boundary; every in-flight request keeps its KV cache.
        streamed=True: layer-chunked publication — one chunk installs per
        serving step (the shadow buffer fills between decode steps) and
        the version flips only at the final pointer swap."""
        assert self._trainer is not None, "connect_trainer first"
        params, version = self._trainer()
        self._updates += 1
        if streamed:
            # all chunks are "arrived"; the per_tick cap meters them out
            # one per step so the transfer overlaps serving
            self.actor.deliver_stream(params, version,
                                      arrivals=[self.clock] * n_chunks,
                                      install_pause=0.0, per_tick=1,
                                      recompute_kv=recompute_kv)
        else:
            self.engine.set_weights(params, version,
                                    recompute_kv=recompute_kv)
        return version

    # ---- serving loop ---------------------------------------------------
    def _reject(self, prob) -> None:
        """Engine declined the prompt (longer than max_len-2): fail the
        owning request immediately instead of leaving it in_flight
        forever — the caller sees `rejected=True`, not a hang."""
        rid = getattr(prob, "rid", None)
        req = self.in_flight.pop(rid, None)
        if req is None:
            return
        req.rejected = True
        req.finished_at = self.clock
        self.rejected.append(req)

    def _complete(self, rollouts, t: float) -> None:
        for r in rollouts:
            prob = self.engine.problems[r.slot]
            rid = getattr(prob, "rid", None)
            if rid is None or rid not in self.in_flight:
                continue
            req = self.in_flight.pop(rid)
            req.finished_at = t
            req.completion_ids = r.tokens[r.prompt_len:]
            req.weight_versions = r.weight_versions[r.prompt_len:]
            self.done.append(req)
            self._completed_now.append(req)
        # advance the clock to the tick completion even when nothing
        # finished (the tick event itself fires at the tick *start* time)
        self.loop.post(t, lambda now: None)

    def step(self, dt: float = 1.0) -> List[Request]:
        """Admit waiting requests, decode one token for every in-flight
        request; returns requests completed this step. One call = one
        tick of the shared event scheduler."""
        self._dt = dt
        self._completed_now = []
        self.loop.post(self.loop.now, self.actor.tick)
        self.loop.run()
        return self._completed_now

    # ---- metrics --------------------------------------------------------
    def metrics(self) -> dict:
        lat = [r.latency for r in self.done if r.latency is not None]
        wait = [r.admitted_at - r.submitted_at for r in self.done
                if r.admitted_at is not None]
        return {
            "served": len(self.done),
            "in_flight": len(self.in_flight),
            "waiting": len(self.waiting),
            "p50_latency": float(np.percentile(lat, 50)) if lat else 0.0,
            "p99_latency": float(np.percentile(lat, 99)) if lat else 0.0,
            "mean_admission_wait": float(np.mean(wait)) if wait else 0.0,
            "tokens_generated": self.engine.tokens_generated,
            # chunked-prefill admission path (DESIGN.md §2)
            "prefill_tokens": self.engine.prefill_tokens,
            "prefill_invocations": self.engine.prefill_invocations,
            # long-prompt admission policy (EngineConfig.long_prompt)
            "prompts_rejected": self.engine.prompts_rejected,
            "prompts_truncated": self.engine.prompts_truncated,
            # weight-publication path (DESIGN.md §7)
            "weight_updates": self._updates,
            "streams_completed": self.actor.streams_completed,
        }
