"""Request-level serving front over the continuous-batching engine.

Mirrors the paper's three integration endpoints (§4 "Architecture and
Implementation Details") in-process:

  - submit()/step()            ~ /v1/chat/completions (batched, continuous)
  - connect_trainer()          ~ /init_process_group (weight-transfer pairing)
  - request_weight_update()    ~ /request_weight_update (in-flight update)

Since DESIGN.md §7 the server is a configuration of the shared
event-driven substrate: one externally-driven `ActorStage`
(`chain=False` — each `step(dt)` posts exactly one admission+decode tick
onto the `EventLoop`) with a step-denominated cost model (`dt` per decode
step, `dt` per chunked-prefill invocation) instead of the RL
orchestrators' flash-unit HardwareModel closures.

Tracks per-request latency (admission wait, end-to-end) so serving SLOs
are measurable across in-flight updates — the paper's headline property:
the engine only *briefly pauses* for new weights, no request is dropped.
Graceful degradation under faults (DESIGN.md §8): waiting requests carry
admission `deadline`s; a miss re-submits with capped exponential backoff
(up to `max_retries`, then a final reject), and `queue_limit` sheds new
submissions at the door when the waiting queue is saturated. Every
submitted request ends in exactly one of {done, in_flight, waiting,
backoff-held, rejected, shed} — `metrics()["requests_lost"]` asserts
that accounting is airtight (always 0).
Admission is policy-driven (`admission="fifo"|"sjf"` — shortest prompt
first, the serving analogue of the pool router's length affinity), and
prompts longer than the engine's budget fail fast: the request comes
back `rejected=True` (counted in `metrics()["prompts_rejected"]`)
instead of being silently truncated or hung.
`request_weight_update(streamed=True)` exercises the chunked publication
path: the new weights install one chunk per serving step and the policy
version flips only at the final pointer swap.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.events import ActorStage, EventLoop
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.data.math_task import Problem


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: List[int]
    submitted_at: float = 0.0    # latest (re-)submission time
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    completion_ids: Optional[np.ndarray] = None
    weight_versions: Optional[np.ndarray] = None
    rejected: bool = False      # prompt over budget OR retries exhausted
    # graceful degradation (DESIGN.md §8)
    first_submitted_at: float = 0.0   # latency anchors here, so retry
    #                                   backoff time counts against SLO
    deadline: Optional[float] = None  # admission deadline (absolute)
    retries: int = 0
    shed: bool = False          # refused at the door (queue_limit)
    quarantined: bool = False   # §10 circuit breaker tripped
    fail_reason: Optional[str] = None

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.first_submitted_at


class _QueueSource:
    """Prompt source draining the server's waiting queue (None when empty);
    records which Request each admitted Problem belongs to. `admission`
    orders the drain: "fifo" (submission order) or "sjf" (shortest prompt
    first — the serving analogue of the pool router's length-affinity
    admission; ties break by submission order, so it stays deterministic
    and starvation shows up as admission wait, not nondeterminism)."""

    def __init__(self, server: "Server", admission: str = "fifo"):
        if admission not in ("fifo", "sjf"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.server = server
        self.admission = admission

    def __call__(self) -> Optional[Problem]:
        waiting = self.server.waiting
        if not waiting:
            return None
        if self.admission == "sjf":
            k = min(range(len(waiting)),
                    key=lambda i: (len(waiting[i].prompt_ids), i))
        else:
            k = 0
        req = waiting[k]
        # page-costed admission (DESIGN.md §9): a paged engine that cannot
        # back the candidate's KV blocks leaves it WAITING — its admission
        # deadline keeps ticking, so sustained page pressure degrades into
        # counted deadline misses/retries, never a hang or a silent drop
        can = getattr(self.server.engine, "can_admit", None)
        if can is not None and not can(len(req.prompt_ids)):
            self.server.admissions_deferred += 1
            return None
        del waiting[k]
        req.admitted_at = self.server.clock
        self.server.in_flight[req.rid] = req
        prob = Problem(req.prompt_ids, 0)
        prob.rid = req.rid  # type: ignore[attr-defined]
        return prob


class Server:
    """Continuous-batching server with in-flight weight updates."""

    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig,
                 seed: int = 0, admission: str = "fifo",
                 deadline: Optional[float] = None, max_retries: int = 0,
                 retry_backoff: float = 4.0, backoff_cap: float = 64.0,
                 queue_limit: Optional[int] = None):
        self.cfg, self.ec = cfg, ec
        self.waiting: deque = deque()
        self.in_flight: Dict[int, Request] = {}
        self.done: List[Request] = []
        self.rejected: List[Request] = []
        self.shed: List[Request] = []
        self.quarantined: List[Request] = []
        # per-request admission deadline + retry/backoff + load shedding
        self.deadline = deadline
        self.max_retries = int(max_retries)
        self.retry_backoff = retry_backoff
        self.backoff_cap = backoff_cap
        self.queue_limit = queue_limit
        self.requests_retried = 0
        self.deadline_misses = 0
        self.admissions_deferred = 0  # paged: candidate left waiting for pages
        self._backoff: List[Tuple[float, int, Request]] = []  # heap
        self._bseq = 0
        self._next_rid = 0
        self._trainer: Optional[Callable] = None
        self._source = _QueueSource(self, admission=admission)
        self.engine = GenerationEngine(cfg, params, ec, self._source,
                                       seed=seed)
        self.engine.on_prompt_rejected = self._reject
        self.loop = EventLoop()
        self._dt = 1.0
        self._updates = 0
        self._completed_now: List[Request] = []
        self.actor = ActorStage(
            self.loop, self.engine, task=None, name="server",
            step_cost=lambda h: self._dt,
            prefill_cost=lambda toks, inv: self._dt * inv,
            deliver=self._complete, auto_refill=True, refill_first=True,
            chain=False)

    @property
    def clock(self) -> float:
        return self.loop.now

    # ---- the three endpoints -----------------------------------------
    def submit(self, prompt_ids: List[int]) -> int:
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock
        req = Request(rid, list(prompt_ids), submitted_at=now,
                      first_submitted_at=now)
        if (self.queue_limit is not None
                and len(self.waiting) >= self.queue_limit):
            # load shedding: refuse at the door rather than letting the
            # waiting queue (and every deadline in it) blow out
            req.shed, req.rejected, req.finished_at = True, True, now
            self.shed.append(req)
            return rid
        if self.deadline is not None:
            req.deadline = now + self.deadline
        self.waiting.append(req)
        return rid

    def connect_trainer(self, get_weights: Callable[[], tuple]) -> None:
        """Pair with a trainer: `get_weights() -> (params, version)`."""
        self._trainer = get_weights

    def request_weight_update(self, recompute_kv: bool = False,
                              streamed: bool = False,
                              n_chunks: int = 8) -> int:
        """In-flight update. Atomic (default): swap weights at the next
        step boundary; every in-flight request keeps its KV cache.
        streamed=True: layer-chunked publication — one chunk installs per
        serving step (the shadow buffer fills between decode steps) and
        the version flips only at the final pointer swap."""
        assert self._trainer is not None, "connect_trainer first"
        params, version = self._trainer()
        self._updates += 1
        if streamed:
            # all chunks are "arrived"; the per_tick cap meters them out
            # one per step so the transfer overlaps serving
            self.actor.deliver_stream(params, version,
                                      arrivals=[self.clock] * n_chunks,
                                      install_pause=0.0, per_tick=1,
                                      recompute_kv=recompute_kv)
        else:
            self.engine.set_weights(params, version,
                                    recompute_kv=recompute_kv)
        return version

    def quarantine(self, rid: int, reason: str = "poison") -> bool:
        """Gray-failure circuit breaker (DESIGN.md §10): pull a request
        out of service into a counted terminal state with a reason — a
        prompt that repeatedly wedges whatever decodes it must stop
        consuming capacity, but it must never be silently dropped (the
        `requests_lost == 0` invariant covers quarantined requests). An
        in-flight request's decode slot is reclaimed via the engine's
        `kill_slot`; waiting/backoff-held requests are simply removed.
        Returns False if `rid` is unknown or already terminal."""
        now = self.clock
        req = self.in_flight.pop(rid, None)
        if req is not None:
            for s, prob in enumerate(self.engine.problems):
                if prob is not None and getattr(prob, "rid", None) == rid:
                    self.engine.kill_slot(s)
                    break
        else:
            for k, cand in enumerate(self.waiting):
                if cand.rid == rid:
                    req = cand
                    del self.waiting[k]
                    break
            else:
                for k, (_, _, cand) in enumerate(self._backoff):
                    if cand.rid == rid:
                        req = cand
                        del self._backoff[k]
                        heapq.heapify(self._backoff)
                        break
        if req is None:
            return False
        req.quarantined, req.rejected = True, True
        req.fail_reason = reason
        req.finished_at = now
        self.quarantined.append(req)
        return True

    # ---- serving loop ---------------------------------------------------
    def _reject(self, prob) -> None:
        """Engine declined the prompt (longer than max_len-2): fail the
        owning request immediately instead of leaving it in_flight
        forever — the caller sees `rejected=True`, not a hang."""
        rid = getattr(prob, "rid", None)
        req = self.in_flight.pop(rid, None)
        if req is None:
            return
        req.rejected = True
        req.finished_at = self.clock
        self.rejected.append(req)

    def _complete(self, rollouts, t: float) -> None:
        for r in rollouts:
            prob = self.engine.problems[r.slot]
            rid = getattr(prob, "rid", None)
            if rid is None or rid not in self.in_flight:
                continue
            req = self.in_flight.pop(rid)
            req.finished_at = t
            req.completion_ids = r.tokens[r.prompt_len:]
            req.weight_versions = r.weight_versions[r.prompt_len:]
            self.done.append(req)
            self._completed_now.append(req)
        # advance the clock to the tick completion even when nothing
        # finished (the tick event itself fires at the tick *start* time)
        self.loop.post(t, lambda now: None)

    def _sweep_deadlines(self, now: float) -> None:
        """Graceful degradation sweep, run before each admission tick:
        (1) requests whose backoff hold expired re-enter the waiting
        queue with a fresh deadline; (2) waiting requests past their
        deadline either retry — exponential backoff hold, capped at
        `backoff_cap` — or, with retries exhausted, reject for good.
        Deadlines only govern *admission*: once a request holds a decode
        slot it runs to completion."""
        while self._backoff and self._backoff[0][0] <= now:
            _, _, req = heapq.heappop(self._backoff)
            req.submitted_at = now
            if self.deadline is not None:
                req.deadline = now + self.deadline
            self.waiting.append(req)
        still: deque = deque()
        for req in self.waiting:
            if req.deadline is None or now <= req.deadline:
                still.append(req)
                continue
            self.deadline_misses += 1
            if req.retries < self.max_retries:
                req.retries += 1
                self.requests_retried += 1
                hold = min(self.retry_backoff * (2.0 ** (req.retries - 1)),
                           self.backoff_cap)
                heapq.heappush(self._backoff, (now + hold, self._bseq, req))
                self._bseq += 1
            else:
                req.rejected, req.finished_at = True, now
                self.rejected.append(req)
        self.waiting = still

    def step(self, dt: float = 1.0) -> List[Request]:
        """Admit waiting requests, decode one token for every in-flight
        request; returns requests completed this step. One call = one
        tick of the shared event scheduler."""
        self._dt = dt
        self._completed_now = []
        if (self.deadline is not None or self._backoff):
            self._sweep_deadlines(self.clock)
        self.loop.post(self.loop.now, self.actor.tick)
        self.loop.run()
        return self._completed_now

    # ---- metrics --------------------------------------------------------
    def metrics(self) -> dict:
        lat = [r.latency for r in self.done if r.latency is not None]
        wait = [r.admitted_at - r.submitted_at for r in self.done
                if r.admitted_at is not None]
        # retried requests' total time — backoff holds included, since
        # latency anchors at first_submitted_at (the SLO the client sees)
        rlat = [r.latency for r in self.done
                if r.retries and r.latency is not None]
        accounted = (len(self.done) + len(self.in_flight)
                     + len(self.waiting) + len(self._backoff)
                     + len(self.rejected) + len(self.shed)
                     + len(self.quarantined))
        return {
            "served": len(self.done),
            "in_flight": len(self.in_flight),
            "waiting": len(self.waiting),
            # graceful-degradation accounting (DESIGN.md §8)
            "requests_rejected": len(self.rejected),
            "requests_retried": self.requests_retried,
            "requests_shed": len(self.shed),
            "requests_quarantined": len(self.quarantined),
            "deadline_misses": self.deadline_misses,
            "admissions_deferred": self.admissions_deferred,
            "free_pages": (self.engine.free_pages
                           if getattr(self.engine, "_paged", False) else None),
            "backoff_held": len(self._backoff),
            "requests_lost": self._next_rid - accounted,   # invariant: 0
            "retry_p50_latency": float(np.percentile(rlat, 50)) if rlat
            else 0.0,
            "retry_p99_latency": float(np.percentile(rlat, 99)) if rlat
            else 0.0,
            "p50_latency": float(np.percentile(lat, 50)) if lat else 0.0,
            "p99_latency": float(np.percentile(lat, 99)) if lat else 0.0,
            "mean_admission_wait": float(np.mean(wait)) if wait else 0.0,
            "tokens_generated": self.engine.tokens_generated,
            # chunked-prefill admission path (DESIGN.md §2)
            "prefill_tokens": self.engine.prefill_tokens,
            "prefill_invocations": self.engine.prefill_invocations,
            # long-prompt admission policy (EngineConfig.long_prompt)
            "prompts_rejected": self.engine.prompts_rejected,
            "prompts_truncated": self.engine.prompts_truncated,
            # weight-publication path (DESIGN.md §7)
            "weight_updates": self._updates,
            "streams_completed": self.actor.streams_completed,
            # per-request weight-lag over the completion's version stamps
            # (DESIGN.md §12): a request served across an in-flight update
            # mixes versions — lag here is each token's distance from the
            # newest version *within its own request* (0 for a request
            # served entirely under one version)
            **self._request_lag(),
        }

    def _request_lag(self) -> dict:
        means, maxes = [], []
        for r in self.done:
            vs = getattr(r, "weight_versions", None)
            if vs is None or len(vs) == 0:
                continue
            l = vs.max() - vs
            means.append(float(l.mean()))
            maxes.append(float(l.max()))
        return {
            "request_lag_mean": float(np.mean(means)) if means else 0.0,
            "request_lag_max": float(np.max(maxes)) if maxes else 0.0,
            "requests_mixed_version": int(sum(1 for m in maxes if m > 0)),
        }
