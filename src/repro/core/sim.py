"""Appendix-A analytical throughput model ("flash" time units).

A *flash* is the theoretically smallest amortized time for one token
forward pass (Eq. 9). U(h) is the accelerator utilization at per-chip batch
h (paper Fig. 8: near-linear up to h~200-256, then saturating ~0.5 of peak
for generation-shaped matmuls). tau is the amortized training flashes per
token (from the paper's case study: r_conv_train = N/tau = 26.02 at N=128
=> tau ~ 4.92).

These closed forms reproduce the paper's Fig. 9 case study (PipelineRL up
to ~1.57x conventional at equal max lag) and provide the simulated clock
for the co-simulated RL experiments.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    u_max: float = 0.5   # generation-kernel utilization ceiling (Fig. 8)
    h_sat: int = 256     # batch where utilization saturates
    tau: float = 4.92    # training flashes per token (Appendix A.4)
    # per-engine chip-speed override (DESIGN.md §7 pool scheduling): a
    # `speed`x faster chip runs the same decode/prefill work in 1/speed
    # the wall-time. Only the generation-side terms scale — the trainer
    # fleet and the broadcast interconnect are separate hardware.
    speed: float = 1.0
    # amortized flashes per *prompt* token admitted via chunked prefill: a
    # batched many-token forward runs compute-bound like training, so it
    # costs ~1 flash/token (the Eq. 9 definition of a flash) instead of a
    # full decode step per token
    prefill_flash: float = 1.0
    # trainer -> generation-engine weight-broadcast interconnect, in bytes
    # moved per flash of wall-time (DESIGN.md §7). An *atomic* publication
    # stalls decode for the whole transfer; a *streamed* one overlaps the
    # transfer with decode and only pauses `bcast_install_flash` per
    # installed chunk (shadow-buffer fill + pointer publish).
    bcast_bytes_per_flash: float = 1e4
    bcast_install_flash: float = 1.0
    # paged-KV admission overhead (DESIGN.md §9): flashes charged per page
    # the refill actually allocated — allocator bookkeeping plus the block
    # table push. 0.0 by default so slot-array runs are cost-identical;
    # prefix-shared GRPO admission shows up as fewer pages charged (the
    # group's prefix pages are allocated once, forks cost nothing).
    page_touch_flash: float = 0.0

    def U(self, h):
        """Utilization at per-chip batch h (0 at h=0)."""
        h = np.asarray(h, np.float64)
        return self.u_max * np.minimum(h, self.h_sat) / self.h_sat

    def scaled(self, speed: float) -> "HardwareModel":
        """Per-engine override for heterogeneous actor pools: the returned
        model's decode/prefill costs are divided by `speed` (composes
        multiplicatively with any existing override)."""
        return dataclasses.replace(self, speed=self.speed * float(speed))

    def step_cost(self, h) -> float:
        """Wall-time (flashes) for one decode step at per-chip batch h:
        h tokens at utilization U(h) -> h/U(h); 0 if no work."""
        h = float(h)
        if h <= 0:
            return 0.0
        return h / float(self.U(max(h, 1e-9))) / self.speed

    def train_time(self, n_tokens: int, n_chips: int) -> float:
        return n_tokens * self.tau / max(n_chips, 1)

    def prefill_time(self, n_tokens: int, n_chips: int) -> float:
        """Wall-time (flashes) to admit `n_tokens` prompt tokens through
        the batched chunked-prefill path. Costed as compute-bound prefill
        FLOPs — NOT as `prompt_len` decode steps of the whole H batch,
        which is what the legacy forcing loop effectively charged."""
        if n_tokens <= 0:
            return 0.0
        return n_tokens * self.prefill_flash / max(n_chips, 1) / self.speed

    def page_touch_time(self, n_pages: int) -> float:
        """Wall-time (flashes) for a refill that allocated `n_pages` KV
        pages (paged engines only; slot-array refills report 0 pages)."""
        if n_pages <= 0:
            return 0.0
        return n_pages * self.page_touch_flash / self.speed

    def broadcast_time(self, n_bytes: float) -> float:
        """Wall-time (flashes) to move `n_bytes` of weights over the
        trainer->engine interconnect (one unicast hop). Atomic updates
        charge this whole window as decode pause; streamed updates overlap
        it with decode and pause only per-chunk installs (DESIGN.md §7)."""
        if n_bytes <= 0:
            return 0.0
        return float(n_bytes) / self.bcast_bytes_per_flash


# ---------------------------------------------------------------------------
# Closed-form throughputs (Appendix A.2 / A.3)
# ---------------------------------------------------------------------------

def conventional_throughput(hw: HardwareModel, N: int, B: int, G: int,
                            L: int) -> Tuple[float, float, float]:
    """Uniform length distribution 1..L (paper A.4). Returns
    (r_conv, r_gen, r_train) in tokens/flash. Eq. 10-15."""
    S = B * G
    K = S * (L + 1) / 2.0  # total tokens
    t_gen = 0.0
    for l in range(1, L + 1):
        h = S * (1.0 - (l - 1) / L) / N  # sequences still in progress / chip
        t_gen += hw.step_cost(h)
    t_train = K * hw.tau / N
    r_gen = K / max(t_gen, 1e-12)
    r_train = N / hw.tau
    return K / (t_gen + t_train), r_gen, r_train


def pipeline_throughput(hw: HardwareModel, N: int, B: int, I: int, H: int,
                        L: int) -> Tuple[float, float, float, int]:
    """Eq. 16-18. I generation chips at per-chip batch H; N-I training.
    Returns (r, r_gen, r_train, g_max)."""
    r_gen = float(hw.U(H)) * I
    r_train = (N - I) / hw.tau
    Lbar = (L + 1) / 2.0
    g_max = math.ceil(H * I * L / (Lbar * B))
    return min(r_gen, r_train), r_gen, r_train, g_max


def best_pipeline_config(hw: HardwareModel, N: int, B: int, L: int,
                         g_max_limit: float = float("inf")):
    """Exhaustive (I, H) search maximizing throughput subject to the max-lag
    constraint (Appendix A.3)."""
    best = None
    for I in range(1, N):
        for H in list(range(1, 64)) + list(range(64, 1025, 4)):
            r, r_gen, r_train, g = pipeline_throughput(hw, N, B, I, H, L)
            if g > g_max_limit:
                continue
            if best is None or r > best[0]:
                best = (r, I, H, g, r_gen, r_train)
    return best


def fig9_curves(hw: HardwareModel, N: int = 128, B: int = 128, L: int = 2048,
                g_grid: Iterable[int] = (2, 4, 8, 16, 32, 64, 96, 128, 133,
                                         160, 192, 256)):
    """Reproduces paper Fig. 9: throughput vs max lag for both systems."""
    rows = []
    for g in g_grid:
        r_conv, _, _ = conventional_throughput(hw, N, B, max(g, 1), L)
        bp = best_pipeline_config(hw, N, B, L, g_max_limit=g)
        r_pipe = bp[0] if bp else 0.0
        rows.append({
            "g_max": g, "r_conv": r_conv, "r_pipe": r_pipe,
            "speedup": r_pipe / max(r_conv, 1e-12),
            "I": bp[1] if bp else 0, "H": bp[2] if bp else 0,
        })
    return rows
