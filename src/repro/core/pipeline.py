"""PipelineRL orchestrator (Algorithm 2): concurrent Actor + Trainer with
in-flight weight updates, co-simulated deterministically.

Both stages execute *real* JAX compute; wall-clock is the Appendix-A
hardware model (flash units), which is what makes the paper's asynchrony
reproducible on CPU: the trainer step runs eagerly as soon as B sequences
exist in the queue, its completion is stamped on the simulated clock, and
the actor applies the weight update at the first decode-step boundary after
that stamp — token-granular in-flight updates, exactly Figure 1(b).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.queues import SampleQueue
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.data.packing import Rollout, pack


@dataclasses.dataclass
class PipelineConfig:
    batch_size: int = 16          # B sequences per optimizer step
    n_opt_steps: int = 50
    n_chips: int = 8              # N
    train_chips: int = 4          # T; generation gets N-T
    pack_rows: int = 8
    pack_seq: int = 128
    queue_maxsize: Optional[int] = None
    recompute_kv: bool = False    # §5.1 ablation
    update_every: int = 1         # optimizer steps between weight pushes
    # GRPO-style group-relative baseline (Shao et al., 2024): subtract the
    # mean reward of same-prompt rollouts instead of (or on top of) the
    # learned value baseline. Use with a prompt source that repeats prompts.
    group_baseline: bool = False


def _batch_to_device(batch: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
    """Per-field host->device copy. Kept for tests/tools; the trainer path
    now stages packed host batches itself (one jitted donated transfer,
    see `Trainer.step`)."""
    return {k: jnp.asarray(v) for k, v in batch.items()
            if k != "packing_stats"}


def _apply_group_baseline(rollouts: List[Rollout]) -> List[Rollout]:
    """GRPO-style: reward <- reward - mean(rewards of same-prompt rollouts).
    Returns shallow copies so queue bookkeeping is untouched."""
    import copy
    groups: Dict[int, List[float]] = {}
    for r in rollouts:
        groups.setdefault(r.prompt_key, []).append(r.reward)
    means = {k: float(np.mean(v)) for k, v in groups.items()}
    out = []
    for r in rollouts:
        r2 = copy.copy(r)
        r2.reward = r.reward - means[r.prompt_key]
        out.append(r2)
    return out


def _lag_stats(rollouts: List[Rollout], trainer_version: int):
    lags = []
    for r in rollouts:
        mask = np.arange(r.length) >= r.prompt_len
        lags.append((trainer_version - r.weight_versions)[mask])
    if not lags:
        return 0.0, 0.0
    cat = np.concatenate(lags)
    if cat.size == 0:
        return 0.0, 0.0
    return float(cat.max()), float(cat.mean())


class PipelineRL:
    """The paper's system: run with `.run()`, read `.log` for R(t)/R(S)."""

    def __init__(self, cfg: ModelConfig, params, task: MathTask,
                 ec: EngineConfig, pc: PipelineConfig,
                 hw: HardwareModel = HardwareModel(),
                 trainer: Optional[Trainer] = None, seed: int = 0,
                 preprocessor=None):
        self.cfg, self.task, self.ec, self.pc, self.hw = cfg, task, ec, pc, hw
        self.trainer = trainer or Trainer(cfg, params)
        self.preprocessor = preprocessor  # paper Fig. 4 middle stage
        self.engine = GenerationEngine(cfg, self.trainer.params, ec,
                                       task.sample, seed=seed)
        self.queue = SampleQueue(pc.queue_maxsize)
        self.actor_time = 0.0
        self.trainer_time = 0.0
        self.pending: List = []  # (available_at, params, version)
        self.log: List[Dict] = []

    @property
    def gen_chips(self) -> int:
        return self.pc.n_chips - self.pc.train_chips

    def run(self, n_opt_steps: Optional[int] = None) -> List[Dict]:
        n = n_opt_steps or self.pc.n_opt_steps
        self._refill()
        while self.trainer.version < n:
            self._actor_tick()
            self._trainer_tick()
        return self.log

    def _refill(self):
        """Admit prompts; chunked prefill is costed as batched prefill
        FLOPs on the generation chips (legacy forcing loops cost decode
        steps inside _actor_tick instead)."""
        admitted = self.engine.refill(self.actor_time)
        if admitted:
            self.actor_time += self.hw.prefill_time(
                self.engine.last_admit_prefill_tokens, max(self.gen_chips, 1))
        return admitted

    # ------------------------------------------------------------------
    def _actor_tick(self):
        # in-flight weight update at a decode-step boundary (Alg. 2 l. 9-11)
        while self.pending and self.pending[0][0] <= self.actor_time:
            _, params, version = self.pending.pop(0)
            self.engine.set_weights(params, version,
                                    recompute_kv=self.pc.recompute_kv)
        h_active = self.engine.n_active
        finished = self.engine.step(self.task, now=self.actor_time)
        self.actor_time += self.hw.step_cost(h_active / max(self.gen_chips, 1))
        for r in finished:
            r.finished_at = self.actor_time
        self.queue.put(finished)
        self._refill()

    def _trainer_tick(self):
        B = self.pc.batch_size
        while len(self.queue) >= B:
            rollouts = self.queue.pop(B)
            t_avail = max(r.finished_at for r in rollouts)
            raw_reward = float(np.mean([r.reward for r in rollouts]))
            if self.preprocessor is not None:
                rollouts = self.preprocessor.process(rollouts)
                t_avail += self.preprocessor.stage_time(
                    sum(r.length for r in rollouts))
            start = max(self.trainer_time, t_avail)
            if self.pc.group_baseline:
                rollouts = _apply_group_baseline(rollouts)
            batch = pack(rollouts, self.pc.pack_rows, self.pc.pack_seq)
            stats = batch.pop("packing_stats")
            # host batch goes straight in: the trainer stages it with one
            # jitted donated transfer; returned metrics are device-resident
            # and sync only when the log entry below reads them
            metrics = self.trainer.step(batch)
            n_tokens = sum(r.length for r in rollouts)
            self.trainer_time = start + self.hw.train_time(
                n_tokens, self.pc.train_chips)
            max_lag, mean_lag = _lag_stats(rollouts, self.trainer.version - 1)
            if (self.trainer.version % self.pc.update_every) == 0:
                self.pending.append((self.trainer_time, self.trainer.params,
                                     self.trainer.version))
            self.log.append({
                "version": self.trainer.version,
                "samples": self.trainer.version * B,
                "time": self.trainer_time,
                "reward": raw_reward,
                "mean_len": float(np.mean([r.length for r in rollouts])),
                "max_lag": max_lag,
                "mean_lag": mean_lag,
                "fill": stats["fill"],
                **metrics,
            })
