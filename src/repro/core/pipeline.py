"""PipelineRL orchestrator (Algorithm 2): concurrent actor pool + Trainer
with in-flight weight updates, co-simulated deterministically.

Built as a *configuration* of the event-driven substrate (`core.events`,
DESIGN.md §7): each of the pool's generation engines is an `ActorStage`
with its own clock and chip share, finished rollouts stream through the
shared `SampleQueue` (and, when configured, an overlapped
`PreprocessStage` on its own chips — paper Fig. 4) into the
`TrainerStage`, and every `update_every`-th optimizer step publishes
weights through the `WeightBroadcaster`. The broadcast is *costed*:
atomic publications stall decode for the whole transfer, streamed ones
fill a shadow param buffer chunk-by-chunk between decode steps and only
pause for the per-chunk install + final pointer swap — the paper's
headline "the engine only briefly pauses for new weights" is now a
measured quantity (`broadcast_stats()`), not an assumption.

All stages execute *real* JAX compute; wall-clock is the Appendix-A
hardware model (flash units), which is what makes the paper's asynchrony
reproducible on CPU: the trainer step runs eagerly as soon as B sequences
exist in the queue, its completion is stamped on the simulated clock, and
each actor applies arrived weight publications at its next decode-step
boundary — token-granular in-flight updates, exactly Figure 1(b).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.events import (
    ActorStage, EventLoop, PoolRouter, PreprocessStage, TrainerStage,
    WeightBroadcaster, apply_group_baseline, lag_stats,
)
from repro.core.queues import SampleQueue
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask

# legacy names — kept where tests/tools import them from
_apply_group_baseline = apply_group_baseline
_lag_stats = lag_stats


@dataclasses.dataclass
class PipelineConfig:
    batch_size: int = 16          # B sequences per optimizer step
    n_opt_steps: int = 50
    n_chips: int = 8              # N
    train_chips: int = 4          # T; generation gets N-T
    pack_rows: int = 8
    pack_seq: int = 128
    queue_maxsize: Optional[int] = None
    recompute_kv: bool = False    # §5.1 ablation
    update_every: int = 1         # optimizer steps between weight pushes
    # GRPO-style group-relative baseline (Shao et al., 2024): subtract the
    # mean reward of same-prompt rollouts instead of (or on top of) the
    # learned value baseline. Use with a prompt source that repeats prompts.
    group_baseline: bool = False
    # --- actor pool + weight broadcast (DESIGN.md §7) -----------------
    n_engines: int = 1            # independent generation engines sharing
    #                               the N-T generation chips
    broadcast: str = "streamed"   # "streamed" | "atomic" | "free"
    broadcast_chunks: int = 8     # layer chunks per streamed publication
    # --- pool scheduling (DESIGN.md §7 "Pool scheduling") -------------
    # per-engine HardwareModel speed overrides (len == n_engines): a
    # heterogeneous pool of slow/fast chips. None = homogeneous (1.0).
    engine_speeds: Optional[Sequence[float]] = None
    router: str = "fifo"          # PoolRouter policy: "fifo" |
    #                               "shortest_queue" | "length_affinity"
    router_lookahead: int = 0     # pending-prompt buffer (0 = pool slots)
    router_slack: Optional[float] = None  # shortest_queue admission slack
    # --- trainer-stall scenario (checkpoint pause every k steps) ------
    ckpt_every: int = 0
    ckpt_pause: float = 0.0       # flashes the trainer stalls per ckpt


def _batch_to_device(batch: Dict[str, np.ndarray]):
    """Per-field host->device copy. Kept for tests/tools; the trainer path
    now stages packed host batches itself (one jitted donated transfer,
    see `Trainer.step`)."""
    import jax.numpy as jnp
    return {k: jnp.asarray(v) for k, v in batch.items()
            if k != "packing_stats"}


class PipelineRL:
    """The paper's system: run with `.run()`, read `.log` for R(t)/R(S)."""

    def __init__(self, cfg: ModelConfig, params, task: MathTask,
                 ec: EngineConfig, pc: PipelineConfig,
                 hw: HardwareModel = HardwareModel(),
                 trainer: Optional[Trainer] = None, seed: int = 0,
                 preprocessor=None,
                 prompt_source: Optional[Callable] = None):
        self.cfg, self.task, self.ec, self.pc, self.hw = cfg, task, ec, pc, hw
        self.trainer = trainer or Trainer(cfg, params)
        self.preprocessor = preprocessor  # paper Fig. 4 middle stage
        self.queue = SampleQueue(pc.queue_maxsize)
        self.log: List[Dict] = []
        self.loop = EventLoop()

        # --- actor pool: n_engines independent engines, each with its own
        # clock and an equal share of the N-T generation chips; identical
        # configs share one set of compiled step functions (jit_donor).
        # The shared prompt source feeds the pool through a PoolRouter
        # (fifo = the pass-through pull, bit-identical to pre-router
        # behavior); per-engine HardwareModel speed overrides make the
        # pool heterogeneous (DESIGN.md §7 "Pool scheduling").
        n_eng = max(int(pc.n_engines), 1)
        chips_per_engine = self.gen_chips / n_eng
        speeds = ([float(s) for s in pc.engine_speeds]
                  if pc.engine_speeds is not None else [1.0] * n_eng)
        if len(speeds) != n_eng:
            raise ValueError(f"engine_speeds has {len(speeds)} entries "
                             f"for n_engines={n_eng}")
        self.engine_speeds = speeds
        self.router = PoolRouter(prompt_source or task.sample,
                                 policy=pc.router,
                                 lookahead=pc.router_lookahead,
                                 slack=pc.router_slack)
        self.engines: List[GenerationEngine] = []
        for i in range(n_eng):
            donor = self.engines[0] if self.engines else None
            self.engines.append(GenerationEngine(
                cfg, self.trainer.params, ec, self.router.source_for(i),
                seed=seed + 1009 * i, jit_donor=donor))
        self.router.attach(self.engines, speeds)

        self.trainer_stage = TrainerStage(
            self.loop, self.trainer,
            queue=None if preprocessor is not None else self.queue,
            batch_size=pc.batch_size,
            train_time=lambda n: hw.train_time(n, pc.train_chips),
            pack_rows=pc.pack_rows, pack_seq=pc.pack_seq, log=self.log,
            update_every=pc.update_every, group_baseline=pc.group_baseline,
            ckpt_every=pc.ckpt_every, ckpt_pause=pc.ckpt_pause,
            samples_per_step=pc.batch_size)
        self.pre_stage = None
        if preprocessor is not None:
            self.pre_stage = PreprocessStage(
                self.loop, preprocessor, self.queue, pc.batch_size,
                self.trainer_stage)
            self.trainer_stage.on_free = self.pre_stage.kick
        consumer = self.pre_stage or self.trainer_stage

        def _deliver(rollouts, t):
            self.queue.put(rollouts)
            if rollouts:
                consumer.kick(t)

        self.actors: List[ActorStage] = [
            ActorStage(
                self.loop, eng, task=task, name=f"actor{i}",
                step_cost=lambda h, c=chips_per_engine,
                    m=hw.scaled(speeds[i]): m.step_cost(h / max(c, 1e-9)),
                prefill_cost=lambda toks, inv, c=chips_per_engine,
                    m=hw.scaled(speeds[i]): m.prefill_time(toks, max(c, 1)),
                deliver=_deliver, recompute_kv=pc.recompute_kv)
            for i, eng in enumerate(self.engines)]
        self.broadcaster = WeightBroadcaster(
            hw, self.actors, mode=pc.broadcast, n_chunks=pc.broadcast_chunks)
        self.trainer_stage.broadcaster = self.broadcaster

    # ----- compatibility surface ---------------------------------------
    @property
    def engine(self) -> GenerationEngine:
        """First pool engine (the whole pool for n_engines=1)."""
        return self.engines[0]

    @property
    def gen_chips(self) -> int:
        return self.pc.n_chips - self.pc.train_chips

    @property
    def actor_time(self) -> float:
        return max(a.time for a in self.actors)

    @property
    def trainer_time(self) -> float:
        return self.trainer_stage.free_at

    def broadcast_stats(self) -> Dict:
        """Per-engine weight-publication accounting: updates applied,
        decode pause charged per update, streams completed/aborted."""
        return self.broadcaster.stats()

    def router_stats(self) -> Dict:
        """Per-engine admission accounting (PoolRouter): prompts assigned,
        prompt tokens routed, pulls declined."""
        st = self.router.stats()
        for eng_stats, actor, speed in zip(st["engines"], self.actors,
                                           self.engine_speeds):
            eng_stats["name"] = actor.name
            eng_stats["speed"] = speed
            eng_stats["preempt_total"] = actor.preempt_total
        return st

    # ----- run ----------------------------------------------------------
    def run(self, n_opt_steps: Optional[int] = None) -> List[Dict]:
        """Run until the trainer reaches `n_opt_steps` optimizer steps
        (absolute). Resumable: pending events survive between calls."""
        n = n_opt_steps or self.pc.n_opt_steps
        for a in self.actors:
            a.start(self.loop.now)
        self.loop.run(until=lambda: self.trainer.version >= n)
        return self.log
