"""PipelineRL orchestrator (Algorithm 2): concurrent actor pool + Trainer
with in-flight weight updates, co-simulated deterministically.

Built as a *configuration* of the event-driven substrate (`core.events`,
DESIGN.md §7): each of the pool's generation engines is an `ActorStage`
with its own clock and chip share, finished rollouts stream through the
shared `SampleQueue` (and, when configured, an overlapped
`PreprocessStage` on its own chips — paper Fig. 4) into the
`TrainerStage`, and every `update_every`-th optimizer step publishes
weights through the `WeightBroadcaster`. The broadcast is *costed*:
atomic publications stall decode for the whole transfer, streamed ones
fill a shadow param buffer chunk-by-chunk between decode steps and only
pause for the per-chunk install + final pointer swap — the paper's
headline "the engine only briefly pauses for new weights" is now a
measured quantity (`broadcast_stats()`), not an assumption.

All stages execute *real* JAX compute; wall-clock is the Appendix-A
hardware model (flash units), which is what makes the paper's asynchrony
reproducible on CPU: the trainer step runs eagerly as soon as B sequences
exist in the queue, its completion is stamped on the simulated clock, and
each actor applies arrived weight publications at its next decode-step
boundary — token-granular in-flight updates, exactly Figure 1(b).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import HealthConfig, ModelConfig
from repro.core.events import (
    ActorStage, EventLoop, HealthMonitor, LagGate, PoolRouter,
    PreprocessStage, TrainerStage, WeightBroadcaster, apply_group_baseline,
    lag_stats,
)
from repro.core.queues import SampleQueue
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask

# legacy names — kept where tests/tools import them from
_apply_group_baseline = apply_group_baseline
_lag_stats = lag_stats


@dataclasses.dataclass
class PipelineConfig:
    batch_size: int = 16          # B sequences per optimizer step
    n_opt_steps: int = 50
    n_chips: int = 8              # N
    train_chips: int = 4          # T; generation gets N-T
    pack_rows: int = 8
    pack_seq: int = 128
    queue_maxsize: Optional[int] = None
    recompute_kv: bool = False    # §5.1 ablation
    update_every: int = 1         # optimizer steps between weight pushes
    # GRPO-style group-relative baseline (Shao et al., 2024): subtract the
    # mean reward of same-prompt rollouts instead of (or on top of) the
    # learned value baseline. Use with a prompt source that repeats prompts.
    group_baseline: bool = False
    # --- actor pool + weight broadcast (DESIGN.md §7) -----------------
    n_engines: int = 1            # independent generation engines sharing
    #                               the N-T generation chips
    broadcast: str = "streamed"   # "streamed" | "atomic" | "free"
    broadcast_chunks: int = 8     # layer chunks per streamed publication
    # --- pool scheduling (DESIGN.md §7 "Pool scheduling") -------------
    # per-engine HardwareModel speed overrides (len == n_engines): a
    # heterogeneous pool of slow/fast chips. None = homogeneous (1.0).
    engine_speeds: Optional[Sequence[float]] = None
    router: str = "fifo"          # PoolRouter policy: "fifo" |
    #                               "shortest_queue" | "length_affinity"
    router_lookahead: int = 0     # pending-prompt buffer (0 = pool slots)
    router_slack: Optional[float] = None  # shortest_queue admission slack
    # --- periodic asynchrony (DESIGN.md §12) --------------------------
    # bounded-staleness barrier: None = free-running pipeline (the
    # paper's operating point); an int bounds every *trained* token's
    # weight lag — actors pause (preemption-window machinery) when a
    # newly sampled token would exceed the bound, and pack() hard-masks
    # any over-bound token out of the loss. max_lag=0 is conventional-RL
    # lockstep. Requires update_every == 1 (versions that never publish
    # would park the pool forever).
    max_lag: Optional[int] = None
    # --- trainer-stall scenario (checkpoint pause every k steps) ------
    ckpt_every: int = 0
    ckpt_pause: float = 0.0       # flashes the trainer stalls per ckpt
    # when set, the stall actually persists the TrainState (atomically)
    # to <ckpt_dir>/trainer_latest.npz and trainer crash-restart restores
    # from it (DESIGN.md §8)
    ckpt_dir: Optional[str] = None
    # --- gray-failure self-healing (DESIGN.md §10) --------------------
    # HealthMonitor watchdog (hang/straggler detection + quarantine) and
    # the trainer's NaN-skip / loss-spike / rollback policy. Enabled by
    # default: with no faults injected the run is bit-identical to a
    # monitor-less one (the watchdog only observes on the healthy path).
    health: HealthConfig = dataclasses.field(default_factory=HealthConfig)


def _batch_to_device(batch: Dict[str, np.ndarray]):
    """Per-field host->device copy. Kept for tests/tools; the trainer path
    now stages packed host batches itself (one jitted donated transfer,
    see `Trainer.step`)."""
    import jax.numpy as jnp
    return {k: jnp.asarray(v) for k, v in batch.items()
            if k != "packing_stats"}


class PipelineRL:
    """The paper's system: run with `.run()`, read `.log` for R(t)/R(S)."""

    def __init__(self, cfg: ModelConfig, params, task: MathTask,
                 ec: EngineConfig, pc: PipelineConfig,
                 hw: HardwareModel = HardwareModel(),
                 trainer: Optional[Trainer] = None, seed: int = 0,
                 preprocessor=None,
                 prompt_source: Optional[Callable] = None,
                 fault_plan=None, mesh=None, rules=None):
        self.cfg, self.task, self.ec, self.pc, self.hw = cfg, task, ec, pc, hw
        # real-mesh runtime (DESIGN.md §11): the trainer keeps the FSDP+TP
        # train layout on `mesh`, each engine owns a disjoint 1D device
        # subset (falling back to the shared mesh when devices don't split
        # evenly), and streamed publications are *executed* per-chunk
        # reshard transfers via MeshBroadcastExecutor. mesh=None keeps the
        # pure simulation bit-identical to before.
        self.mesh, self.rules = mesh, rules
        self._engine_meshes: Optional[List] = None
        if mesh is not None:
            from repro.launch.mesh import engine_submeshes
            n_eng = max(int(pc.n_engines), 1)
            try:
                self._engine_meshes = engine_submeshes(mesh, n_eng)
            except ValueError:
                self._engine_meshes = [mesh] * n_eng
            if trainer is None:
                trainer = Trainer(cfg, params, mesh=mesh, rules=rules)
        self.trainer = trainer or Trainer(cfg, params)
        self.preprocessor = preprocessor  # paper Fig. 4 middle stage
        self.queue = SampleQueue(pc.queue_maxsize)
        self.log: List[Dict] = []
        self.loop = EventLoop()
        self.seed = seed
        self.fault_plan = fault_plan
        self.fault_log: List[Dict] = []

        # --- actor pool: n_engines independent engines, each with its own
        # clock and an equal share of the N-T generation chips; identical
        # configs share one set of compiled step functions (jit_donor).
        # The shared prompt source feeds the pool through a PoolRouter
        # (fifo = the pass-through pull, bit-identical to pre-router
        # behavior); per-engine HardwareModel speed overrides make the
        # pool heterogeneous (DESIGN.md §7 "Pool scheduling").
        n_eng = max(int(pc.n_engines), 1)
        chips_per_engine = self.gen_chips / n_eng
        speeds = ([float(s) for s in pc.engine_speeds]
                  if pc.engine_speeds is not None else [1.0] * n_eng)
        if len(speeds) != n_eng:
            raise ValueError(f"engine_speeds has {len(speeds)} entries "
                             f"for n_engines={n_eng}")
        self.engine_speeds = speeds
        # poison-prompt faults mark the Nth draw from the shared source
        # (§10): the wrapper stamps `_poison` on exactly those ordinals,
        # so whichever engine admits the prompt deterministically wedges
        self._poison_ordinals = (set(fault_plan.poison_ordinals())
                                 if fault_plan is not None else set())
        src = prompt_source or task.sample
        if self._poison_ordinals:
            src = self._wrap_poison(src)
        self.router = PoolRouter(src,
                                 policy=pc.router,
                                 lookahead=pc.router_lookahead,
                                 slack=pc.router_slack,
                                 clock=lambda: self.loop.now)
        # periodic-asynchrony gate (DESIGN.md §12): one pool-shared
        # bounded-staleness barrier, consulted by every actor tick
        self.lag_gate: Optional[LagGate] = None
        if pc.max_lag is not None:
            if pc.max_lag < 0:
                raise ValueError(f"max_lag must be >= 0, got {pc.max_lag}")
            if pc.update_every != 1:
                raise ValueError(
                    "max_lag requires update_every=1: unpublished versions "
                    "would strand gate-parked actors with no delivery to "
                    "wake on")
            self.lag_gate = LagGate(pc.max_lag,
                                    lambda: self.trainer.version)
        self.engines: List[GenerationEngine] = []
        for i in range(n_eng):
            donor = self.engines[0] if self.engines else None
            self.engines.append(GenerationEngine(
                cfg, self.trainer.params, ec, self.router.source_for(i),
                seed=seed + 1009 * i, jit_donor=donor,
                mesh=self._engine_mesh(i), rules=self.rules))
        self.router.attach(self.engines, speeds)

        self.trainer_stage = TrainerStage(
            self.loop, self.trainer,
            queue=None if preprocessor is not None else self.queue,
            batch_size=pc.batch_size,
            train_time=lambda n: hw.train_time(n, pc.train_chips),
            pack_rows=pc.pack_rows, pack_seq=pc.pack_seq, log=self.log,
            update_every=pc.update_every, group_baseline=pc.group_baseline,
            ckpt_every=pc.ckpt_every, ckpt_pause=pc.ckpt_pause,
            ckpt_dir=pc.ckpt_dir, ckpt_keep=pc.health.ckpt_keep,
            bad_step_rollback=pc.health.bad_step_rollback,
            loss_spike_factor=pc.health.loss_spike_factor,
            samples_per_step=pc.batch_size, max_lag=pc.max_lag)
        self.pre_stage = None
        if preprocessor is not None:
            self.pre_stage = PreprocessStage(
                self.loop, preprocessor, self.queue, pc.batch_size,
                self.trainer_stage)
            self.trainer_stage.on_free = self.pre_stage.kick
        consumer = self.pre_stage or self.trainer_stage

        def _deliver(rollouts, t):
            self.queue.put(rollouts)
            if rollouts:
                consumer.kick(t)

        self._deliver = _deliver
        self._chips_per_engine = chips_per_engine
        self.actors: List[ActorStage] = [
            self._make_actor(i, eng, speeds[i])
            for i, eng in enumerate(self.engines)]
        executor = None
        if mesh is not None and pc.broadcast == "streamed":
            from repro.launch.meshrt import MeshBroadcastExecutor
            executor = MeshBroadcastExecutor()
        self.broadcaster = WeightBroadcaster(
            hw, self.actors, mode=pc.broadcast, n_chunks=pc.broadcast_chunks,
            fault_plan=fault_plan, executor=executor)
        self.trainer_stage.broadcaster = self.broadcaster
        # gray-failure watchdog (DESIGN.md §10): hang/straggler detection
        # over the pool, escalating through the §8 fail/salvage/requeue
        # machinery and quarantining repeat-offender prompts
        self.monitor: Optional[HealthMonitor] = None
        self._hang_restart: Dict[int, List[float]] = {}
        hc = pc.health
        if hc.enabled:
            self.monitor = HealthMonitor(
                self.loop, self.actors, router=self.router, speeds=speeds,
                interval=hc.interval, hang_grace=hc.hang_grace,
                hang_factor=hc.hang_factor,
                straggler_factor=hc.straggler_factor,
                straggler_patience=hc.straggler_patience,
                quarantine_after=hc.quarantine_after,
                on_hang=self._on_hang)
        if fault_plan is not None:
            self._schedule_faults(fault_plan)

    def _wrap_poison(self, source: Callable) -> Callable:
        """Count draws from the shared prompt source and stamp `_poison`
        on the ordinals the fault plan names."""
        state = {"n": 0}

        def draw():
            p = source()
            if p is not None:
                if state["n"] in self._poison_ordinals:
                    p._poison = True  # type: ignore[attr-defined]
                state["n"] += 1
            return p

        return draw

    def _engine_mesh(self, i: int):
        """Device subset of pool engine i (None without a mesh). Elastic
        joiners beyond the configured pool reuse the last subset."""
        if self._engine_meshes is None:
            return None
        return self._engine_meshes[min(i, len(self._engine_meshes) - 1)]

    def _make_actor(self, i: int, eng: GenerationEngine,
                    speed: float) -> ActorStage:
        """One pool member. The chip share stays fixed at the *configured*
        pool size (gen_chips / pc.n_engines) — elastic joins add capacity
        rather than re-slicing the incumbents' chips, matching how spare
        capacity is attached in practice."""
        c = self._chips_per_engine
        m = self.hw.scaled(speed)
        a = ActorStage(
            self.loop, eng, task=self.task, name=f"actor{i}",
            step_cost=lambda h: m.step_cost(h / max(c, 1e-9)),
            prefill_cost=lambda toks, inv: m.prefill_time(toks, max(c, 1)),
            page_cost=m.page_touch_time,
            deliver=self._deliver, recompute_kv=self.pc.recompute_kv,
            lag_gate=self.lag_gate)
        # real-mesh pool: the stage advertises the device subset it owns
        a.devices = (tuple(eng.mesh.devices.reshape(-1))
                     if getattr(eng, "mesh", None) is not None else None)
        plan = self.fault_plan
        if plan is not None and plan.has_slowdown_faults():
            # gray degradation (§10): the plan's windows scale this
            # engine's decode cost; outside a window the factor is 1.0
            # (bitwise no-op for finite costs)
            a.cost_scale = lambda t, i=i: plan.slowdown_factor(i, t)
        if self._poison_ordinals:
            a.poison_check = True
        return a

    # ----- compatibility surface ---------------------------------------
    @property
    def engine(self) -> GenerationEngine:
        """First pool engine (the whole pool for n_engines=1)."""
        return self.engines[0]

    @property
    def gen_chips(self) -> int:
        return self.pc.n_chips - self.pc.train_chips

    @property
    def actor_time(self) -> float:
        return max(a.time for a in self.actors)

    @property
    def trainer_time(self) -> float:
        return self.trainer_stage.free_at

    def broadcast_stats(self) -> Dict:
        """Per-engine weight-publication accounting: updates applied,
        decode pause charged per update, streams completed/aborted."""
        return self.broadcaster.stats()

    def router_stats(self) -> Dict:
        """Per-engine admission accounting (PoolRouter): prompts assigned,
        prompt tokens routed, pulls declined."""
        st = self.router.stats()
        for eng_stats, actor, speed in zip(st["engines"], self.actors,
                                           self.engine_speeds):
            eng_stats["name"] = actor.name
            eng_stats["speed"] = speed
            eng_stats["preempt_total"] = actor.preempt_total
        return st

    def lag_stats(self) -> Dict:
        """Staleness accounting for the whole run, from the *typed* lag
        fields the trainer packed (DESIGN.md §12) — supersedes the old
        ad-hoc per-batch recomputation. `histogram` maps lag value ->
        trained-token count; `masked_tokens` counts completions the
        `max_lag` bound dropped from the loss; per-engine entries report
        how far each engine's installed weights trail the learner right
        now, plus the gate pauses it absorbed."""
        ts = self.trainer_stage
        hist = dict(sorted(ts.lag_hist.items()))
        total = sum(hist.values())
        mean = (sum(v * c for v, c in hist.items()) / total
                if total else 0.0)
        st: Dict = {
            "bound": self.pc.max_lag,
            "histogram": hist,
            "trained_tokens": total,
            "max_lag": max(hist) if hist else 0,
            "mean_lag": mean,
            "masked_tokens": ts.lag_masked_tokens,
            "engines": [{
                "name": a.name,
                "version": int(a.engine.version),
                "behind": self.trainer.version - int(a.engine.version),
                "oldest_inflight": a.engine.oldest_inflight_version(),
                "lag_pauses": a.lag_pauses,
                "lag_wait_total": a.lag_wait_total,
            } for a in self.actors],
        }
        if self.lag_gate is not None:
            st["gate"] = self.lag_gate.stats()
        return st

    # ----- fault injection + elastic pool (DESIGN.md §8) ----------------
    def _schedule_faults(self, plan) -> None:
        """Post the plan's faults onto the event loop. Link faults need no
        events — the broadcaster consults the plan per chunk transmission;
        everything else becomes a timed crash (+ optional timed restore)."""
        n_eng = len(self.engines)
        for f in plan.faults:
            if f.kind == "engine_crash":
                i = int(f.engine or 0)
                if not 0 <= i < n_eng:
                    raise ValueError(
                        f"fault targets engine {i} of a {n_eng}-engine pool")
                self.loop.post(f.at, lambda t, i=i: self._fail_engine(i, t))
                if f.restart_after is not None:
                    self.loop.post(f.at + f.restart_after,
                                   lambda t, i=i: self.restore_engine(i, t))
            elif f.kind == "trainer_crash":
                self.loop.post(f.at, self._crash_trainer)
                if f.restart_after is not None:
                    self.loop.post(f.at + f.restart_after,
                                   self._restore_trainer)
            elif f.kind == "preprocess_fail":
                self.loop.post(f.at, self._fail_preprocess)
            elif f.kind == "engine_hang":
                i = int(f.engine or 0)
                if not 0 <= i < n_eng:
                    raise ValueError(
                        f"fault targets engine {i} of a {n_eng}-engine pool")
                self.loop.post(f.at, lambda t, i=i: self._hang_engine(i, t))
                if f.restart_after is not None:
                    # consumed at *detection* (the watchdog finds the hang;
                    # nothing fires at a wall-clock restore time — a hang
                    # has no self-announcing crash event to anchor one)
                    self._hang_restart.setdefault(i, []).append(
                        float(f.restart_after))
            elif f.kind == "engine_slowdown":
                i = int(f.engine or 0)
                if not 0 <= i < n_eng:
                    raise ValueError(
                        f"fault targets engine {i} of a {n_eng}-engine pool")
                # no event: the actor's cost_scale closure consults the
                # plan's windows per tick (installed in _make_actor)
            elif f.kind == "nan_step":
                self.loop.post(
                    f.at, lambda t, n=max(int(f.count), 1):
                    self.trainer_stage.poison_steps(n))
            elif f.kind not in ("link_degrade", "chunk_corrupt",
                                "poison_prompt"):
                # link/corruption faults are consulted per transmission by
                # the broadcaster; poison prompts by the source wrapper
                raise ValueError(f"unknown fault kind {f.kind!r}")

    def _fail_engine(self, i: int, t: float) -> None:
        """Kill engine i mid-decode: its live slots' prompts are salvaged
        and re-offered (front of the router's pending buffer) to the
        surviving engines; partially decoded tokens are lost
        (`rollouts_lost`). Idle survivors are kicked so the salvaged work
        is picked up immediately."""
        a = self.actors[i]
        if a.failed:
            return
        salvaged = a.fail(t)
        self.router.set_alive(i, False)
        n_quar = self._requeue_salvaged(salvaged, t)
        for j, other in enumerate(self.actors):
            if j != i and not other.failed:
                other.start(t)
        self.fault_log.append({
            "kind": "engine_crash", "engine": i, "at": t,
            "prompts_salvaged": len(salvaged),
            "prompts_quarantined": n_quar,
            "rollouts_lost": a.rollouts_lost})

    def _requeue_salvaged(self, salvaged, t: float) -> int:
        """Route salvaged prompts back to the pool through the monitor's
        failure attribution (§10): repeat offenders are quarantined —
        surfaced in `pool_stats()` instead of crash-looping engine after
        engine. Without a monitor everything requeues (§8 behavior).
        Returns the number quarantined."""
        if not salvaged:
            return 0
        if self.monitor is not None:
            requeue, quarantine = self.monitor.attribute_failure(salvaged)
        else:
            requeue, quarantine = list(salvaged), []
        if requeue:
            self.router.requeue(requeue, now=t)
        return len(quarantine)

    def _hang_engine(self, i: int, t: float) -> None:
        """Inject a gray hang: engine i wedges without crashing. Nothing
        is salvaged here — only the HealthMonitor's missed-heartbeat
        deadline can notice and escalate (`_on_hang`)."""
        a = self.actors[i]
        if a.failed or a.hung:
            return
        a.hang(t)
        self.fault_log.append({"kind": "engine_hang", "engine": i, "at": t})

    def _on_hang(self, i: int, t: float) -> None:
        """Watchdog escalation: treat the wedged engine exactly like an
        operator-killed process — fail/salvage, attribute the failure to
        the stranded prompts (quarantining repeat offenders), requeue the
        rest to survivors, and schedule a restart (the fault plan's
        `restart_after` if it named one, else the health policy's
        `hang_restart_after`)."""
        a = self.actors[i]
        if a.failed:
            return
        salvaged = a.fail(t)
        self.router.set_alive(i, False)
        n_quar = self._requeue_salvaged(salvaged, t)
        for j, other in enumerate(self.actors):
            if j != i and not other.failed:
                other.start(t)
        self.fault_log.append({
            "kind": "engine_hang_detected", "engine": i, "at": t,
            "prompts_salvaged": len(salvaged),
            "prompts_quarantined": n_quar})
        pending = self._hang_restart.get(i)
        delay = (pending.pop(0) if pending
                 else self.pc.health.hang_restart_after)
        if delay is not None:
            self.loop.post(t + float(delay),
                           lambda tt, i=i: self.restore_engine(i, tt))

    def restore_engine(self, i: int, t: Optional[float] = None) -> None:
        """Bring a crashed engine back. Before re-admission it gets a
        catch-up *atomic* weight sync to the trainer's newest params, so
        its first post-restart rollouts carry the exact current version
        stamp — a rejoining engine never generates with stale weights."""
        t = self.loop.now if t is None else t
        a = self.actors[i]
        if not a.failed:
            return
        a.restore(t, params=self.trainer.params,
                  version=self.trainer.version)
        self.router.set_alive(i, True)
        self.router.set_health(i, 1.0)   # fresh process, clean slate
        if self.monitor is not None:
            self.monitor.notice_restore(i, t)
        self.fault_log.append({
            "kind": "engine_restore", "engine": i, "at": t,
            "version": self.trainer.version, "downtime": a.downtime})

    def _crash_trainer(self, t: float) -> None:
        self.trainer_stage.crash(t)
        self.fault_log.append({
            "kind": "trainer_crash", "at": t,
            "steps_lost": self.trainer_stage.steps_lost})

    def _restore_trainer(self, t: float) -> None:
        v = self.trainer_stage.restore(t)
        self.fault_log.append({
            "kind": "trainer_restore", "at": t, "version": v})

    def _fail_preprocess(self, t: float) -> None:
        n = self.pre_stage.fail(t) if self.pre_stage is not None else 0
        self.fault_log.append({
            "kind": "preprocess_fail", "at": t, "rollouts_requeued": n})

    def add_engine(self, speed: float = 1.0,
                   at: Optional[float] = None) -> int:
        """Elastic join: attach one new engine to the pool at runtime.
        The joiner shares the incumbents' compiled step functions
        (jit_donor), receives a catch-up atomic weight sync to the current
        params/version *before* admission, and only then starts pulling
        prompts from the router. Returns the new engine's pool index."""
        t = self.loop.now if at is None else at
        idx = len(self.engines)
        eng = GenerationEngine(
            self.cfg, self.trainer.params, self.ec,
            self.router.source_for(idx), seed=self.seed + 1009 * idx,
            jit_donor=self.engines[0] if self.engines else None,
            mesh=self._engine_mesh(idx), rules=self.rules)
        self.engines.append(eng)
        self.engine_speeds.append(float(speed))
        self.router.add_engine(eng, speed)
        a = self._make_actor(idx, eng, speed)
        self.actors.append(a)
        self.broadcaster.actors.append(a)
        if self.monitor is not None:
            self.monitor.actors.append(a)
            self.monitor.watch_engine(speed)
        # catch-up sync before admission: version stamps stay exact
        eng.set_weights(self.trainer.params, self.trainer.version,
                        recompute_kv=self.pc.recompute_kv)
        a.updates_applied += 1
        a.start(t)
        self.fault_log.append({
            "kind": "engine_join", "engine": idx, "at": t,
            "version": self.trainer.version})
        return idx

    def detach_engine(self, i: int, at: Optional[float] = None) -> int:
        """Elastic shrink: administratively remove engine i. Its in-flight
        prompts are salvaged and requeued to the survivors (partial decode
        work is lost, same as a crash — there is no drain protocol); the
        slot stays in the pool lists (marked dead) so indices are stable.
        Returns the number of prompts salvaged."""
        t = self.loop.now if at is None else at
        a = self.actors[i]
        if a.failed:
            return 0
        salvaged = a.fail(t)
        self.router.set_alive(i, False)
        if salvaged:
            self.router.requeue(salvaged, now=t)
        for j, other in enumerate(self.actors):
            if j != i and not other.failed:
                other.start(t)
        self.fault_log.append({
            "kind": "engine_detach", "engine": i, "at": t,
            "prompts_salvaged": len(salvaged)})
        return len(salvaged)

    def pool_stats(self) -> Dict:
        """Recovery/elasticity accounting for the whole pool: per-engine
        failure counters layered onto router + broadcaster stats."""
        st = self.router_stats()
        for eng_stats, actor in zip(st["engines"], self.actors):
            eng_stats.update({
                "failures": actor.failures,
                "recoveries": actor.recoveries,
                "rollouts_lost": actor.rollouts_lost,
                "prompts_salvaged": actor.prompts_salvaged,
                "downtime": actor.downtime,
            })
        st["rollouts_lost"] = sum(a.rollouts_lost for a in self.actors)
        st["prompts_salvaged"] = sum(a.prompts_salvaged for a in self.actors)
        # §10 zero-lost invariant: every salvaged prompt is either back in
        # the pool or in the counted quarantine list, never dropped
        st["prompts_quarantined"] = (self.monitor.prompts_quarantined
                                     if self.monitor is not None else 0)
        st["trainer"] = {
            "crashes": self.trainer_stage.crashes,
            "recoveries": self.trainer_stage.recoveries,
            "steps_lost": self.trainer_stage.steps_lost,
            "ckpts_saved": self.trainer_stage.ckpts_saved,
            "last_ckpt_version": self.trainer_stage.last_ckpt_version,
            # numerical robustness (DESIGN.md §10)
            "bad_steps": self.trainer_stage.bad_steps,
            "divergences": self.trainer_stage.divergences,
            "rollbacks": self.trainer_stage.rollbacks,
            "ckpts_corrupt": self.trainer_stage.ckpts_corrupt,
            "nonfinite_steps": getattr(self.trainer, "nonfinite_steps", 0),
        }
        st["broadcast"] = {
            "chunks_lost": self.broadcaster.chunks_lost,
            "chunks_corrupt": self.broadcaster.chunks_corrupt,
            "retransmit_wait": self.broadcaster.retransmit_wait,
            "deliveries_skipped": self.broadcaster.deliveries_skipped,
            "wchunks_rejected": sum(getattr(e, "wchunks_rejected", 0)
                                    for e in self.engines),
            "wstreams_torn": sum(getattr(e, "wstreams_torn", 0)
                                 for e in self.engines),
        }
        if self.monitor is not None:
            st["health"] = self.monitor.stats()
        st["fault_log"] = list(self.fault_log)
        return st

    # ----- run ----------------------------------------------------------
    def run(self, n_opt_steps: Optional[int] = None) -> List[Dict]:
        """Run until the trainer reaches `n_opt_steps` optimizer steps
        (absolute). Resumable: pending events survive between calls."""
        n = n_opt_steps or self.pc.n_opt_steps
        for a in self.actors:
            a.start(self.loop.now)
        if self.monitor is not None:
            self.monitor.start(self.loop.now)
        self.loop.run(until=lambda: self.trainer.version >= n)
        return self.log
