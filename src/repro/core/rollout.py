"""Continuous-batching generation engine with in-flight weight updates —
the Actor process of PipelineRL (Algorithm 2), TPU/JAX-native.

vLLM's dynamic paged batching becomes a *slot array*: H static slots, each
with its own write index into a preallocated KV cache. Finished sequences
retire and their slot is refilled with a new prompt in the same jitted step
function (no dynamic shapes). The in-flight weight update is a host-side
pointer swap of the behavior weights μ — the KV cache (and SSM state) of
in-progress sequences is retained *stale*, exactly the paper's mechanism
(§5.1 shows this is safe; `recompute_kv=True` reproduces their ablation).

Per-token bookkeeping records the behavior logprob (mixed-policy μ of
Eq. 8) and the weight version each token was sampled under (token lag).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, kv_cache_specs
from repro.data.math_task import MathTask, Problem
from repro.data.packing import Rollout
from repro.models import model as M


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 16            # H, the generation batch size
    max_len: int = 64            # prompt + completion budget per sequence
    temperature: float = 1.0
    eos_id: int = 2
    pad_id: int = 0


def _zero_cache(cfg: ModelConfig, n_slots: int, max_len: int):
    specs = kv_cache_specs(cfg, n_slots, max_len)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in specs.items()}


def _engine_step(params, st: Dict[str, Any], cfg: ModelConfig,
                 ec: EngineConfig):
    """One token for every active slot. st: tokens (H,T), n_cached (H,),
    prompt_len (H,), active (H,) bool, cache, lp (H,T), key."""
    H, T = st["tokens"].shape
    idx = jnp.arange(H)
    cur_tok = st["tokens"][idx, st["n_cached"]][:, None]          # (H,1)
    positions = st["n_cached"][:, None]                           # (H,1)
    out = M.decode_step(params, cur_tok, positions, st["cache"],
                        st["n_cached"], cfg, ring=False)
    logits = out["logits"][:, 0] / jnp.maximum(ec.temperature, 1e-6)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)

    key, sub = jax.random.split(st["key"])
    sampled = jax.random.categorical(sub, logp, axis=-1)          # (H,)

    next_idx = st["n_cached"] + 1
    in_prompt = next_idx < st["prompt_len"]
    forced = st["tokens"][idx, jnp.minimum(next_idx, T - 1)]
    next_tok = jnp.where(in_prompt, forced, sampled).astype(jnp.int32)
    tok_lp = jnp.take_along_axis(logp, next_tok[:, None], axis=-1)[:, 0]
    tok_lp = jnp.where(in_prompt, 0.0, tok_lp)

    active = st["active"]
    write = active & (next_idx < T)
    tokens = st["tokens"].at[idx, jnp.minimum(next_idx, T - 1)].set(
        jnp.where(write, next_tok, st["tokens"][idx, jnp.minimum(next_idx, T - 1)]))
    lp = st["lp"].at[idx, jnp.minimum(next_idx, T - 1)].set(
        jnp.where(write, tok_lp, st["lp"][idx, jnp.minimum(next_idx, T - 1)]))

    finished = active & ~in_prompt & (
        (next_tok == ec.eos_id) | (next_idx >= T - 1))
    n_cached = jnp.where(active, next_idx, st["n_cached"])
    new_active = active & ~finished

    new_st = dict(st, tokens=tokens, lp=lp, key=key,
                  n_cached=n_cached, active=new_active, cache=out["cache"])
    return new_st, finished


class GenerationEngine:
    """H-slot continuous-batching engine (Algorithm 2, Actor)."""

    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig,
                 prompt_source: Callable[[], Problem], seed: int = 0):
        self.cfg, self.ec = cfg, ec
        self.params = params      # behavior weights μ
        self.version = 0          # trainer version of μ
        self.prompt_source = prompt_source
        H, T = ec.n_slots, ec.max_len
        self.state: Dict[str, Any] = {
            "tokens": jnp.zeros((H, T), jnp.int32),
            "lp": jnp.zeros((H, T), jnp.float32),
            "n_cached": jnp.zeros((H,), jnp.int32),
            "prompt_len": jnp.ones((H,), jnp.int32),
            "active": jnp.zeros((H,), bool),
            "cache": _zero_cache(cfg, H, T),
            "key": jax.random.PRNGKey(seed),
        }
        # host-side bookkeeping
        self.problems: List[Optional[Problem]] = [None] * H
        self.ver_buf = np.zeros((H, T), np.int32)
        self.started_at = np.zeros(H, np.float64)
        self.tokens_generated = 0
        self._step = jax.jit(functools.partial(_engine_step, cfg=cfg, ec=ec))
        self._recompute = jax.jit(functools.partial(self._recompute_impl, cfg=cfg))

    # ----- weights -----------------------------------------------------
    def set_weights(self, params, version: int, recompute_kv: bool = False):
        """In-flight weight update: swap μ, keep the (stale) KV cache.
        recompute_kv=True reproduces the paper's §5.1 ablation (recompute
        the cache of in-progress sequences under the new weights)."""
        self.params = params
        self.version = version
        if recompute_kv:
            self.state["cache"] = self._recompute(params, self.state)

    @staticmethod
    def _recompute_impl(params, st, cfg: ModelConfig):
        H, T = st["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(T)[None], (H, T))
        out = M.forward(params, st["tokens"], positions, cfg, return_cache=True)
        # entries at positions >= n_cached are garbage in both old and new
        # caches (masked by cache_index), so a full overwrite is safe.
        new = dict(st["cache"])
        for k in ("k", "v", "c_kv", "k_rope", "conv", "ssd"):
            if k in out["cache"]:
                if k in ("conv", "ssd"):
                    continue  # recurrent state recompute not supported here
                new[k] = out["cache"][k].astype(new[k].dtype)
        return new

    # ----- admission ----------------------------------------------------
    def refill(self, now: float = 0.0) -> int:
        """Fill inactive slots with fresh prompts. The prompt source may
        return None to decline (serving: empty request queue) — those slots
        stay inactive. Returns #admitted."""
        active = np.asarray(self.state["active"])
        free = np.where(~active)[0]
        if free.size == 0:
            return 0
        H, T = self.ec.n_slots, self.ec.max_len
        tokens = np.asarray(self.state["tokens"]).copy()
        n_cached = np.asarray(self.state["n_cached"]).copy()
        prompt_len = np.asarray(self.state["prompt_len"]).copy()
        lp = np.asarray(self.state["lp"]).copy()
        act = active.copy()
        admitted = []
        for s in free:
            prob = self.prompt_source()
            if prob is None:
                continue
            admitted.append(s)
            pl = min(len(prob.prompt_ids), T - 2)
            tokens[s] = self.ec.pad_id
            tokens[s, :pl] = prob.prompt_ids[:pl]
            lp[s] = 0.0
            n_cached[s] = 0
            prompt_len[s] = pl
            act[s] = True
            self.problems[s] = prob
            self.ver_buf[s] = 0
            self.started_at[s] = now
        if not admitted:
            return 0
        st = self.state
        st["tokens"] = jnp.asarray(tokens)
        st["n_cached"] = jnp.asarray(n_cached)
        st["prompt_len"] = jnp.asarray(prompt_len)
        st["lp"] = jnp.asarray(lp)
        st["active"] = jnp.asarray(act)
        # zero recurrent state of refilled slots (attention cache is masked
        # by cache_index, but SSM state carries over unless cleared)
        if "ssd" in st["cache"]:
            mask = jnp.asarray(
                ~np.isin(np.arange(self.ec.n_slots), np.asarray(admitted)),
                st["cache"]["ssd"].dtype)
            st["cache"]["ssd"] = st["cache"]["ssd"] * mask[None, :, None, None, None]
            st["cache"]["conv"] = st["cache"]["conv"] * mask[None, :, None, None].astype(st["cache"]["conv"].dtype)
        return len(admitted)

    @property
    def n_active(self) -> int:
        return int(np.asarray(self.state["active"]).sum())

    # ----- stepping -----------------------------------------------------
    def step(self, task: Optional[MathTask] = None,
             now: float = 0.0) -> List[Rollout]:
        """Generate one token on every active slot; returns rollouts that
        finished this step."""
        prev_active = np.asarray(self.state["active"])
        prev_ncached = np.asarray(self.state["n_cached"])
        self.state, finished = self._step(self.params, self.state)
        finished = np.asarray(finished)
        # record weight version for tokens written this step
        wrote = prev_active & (prev_ncached + 1 < self.ec.max_len)
        self.ver_buf[wrote, prev_ncached[wrote] + 1] = self.version
        self.tokens_generated += int(prev_active.sum())

        done: List[Rollout] = []
        if finished.any():
            tokens = np.asarray(self.state["tokens"])
            lp = np.asarray(self.state["lp"])
            n_cached = np.asarray(self.state["n_cached"])
            for s in np.where(finished)[0]:
                L = int(n_cached[s]) + 1  # includes the just-sampled token
                L = min(L, self.ec.max_len)
                prob = self.problems[s]
                pl = int(np.asarray(self.state["prompt_len"])[s])
                completion = tokens[s, pl:L]
                reward = 0.0
                if task is not None and prob is not None:
                    reward = task.reward(prob, completion,
                                         self.ec.max_len - pl)
                done.append(Rollout(
                    tokens=tokens[s, :L].copy(),
                    prompt_len=pl,
                    behavior_logprobs=lp[s, :L].copy(),
                    reward=reward,
                    weight_versions=self.ver_buf[s, :L].copy(),
                    finished_at=now,
                    prompt_key=(hash(tuple(prob.prompt_ids)) & 0x7FFFFFFF
                                if prob is not None else 0),
                    slot=int(s),
                ))
        return done
