"""Continuous-batching generation engine with in-flight weight updates —
the Actor process of PipelineRL (Algorithm 2), TPU/JAX-native.

vLLM's dynamic paged batching becomes a *slot array*: H static slots, each
with its own write index into a preallocated KV cache. Finished sequences
retire and their slot is refilled with a new prompt in the same jitted step
function (no dynamic shapes). The in-flight weight update is a host-side
pointer swap of the behavior weights μ — the KV cache (and SSM state) of
in-progress sequences is retained *stale*, exactly the paper's mechanism
(§5.1 shows this is safe; `recompute_kv=True` reproduces their ablation).

Per-token bookkeeping records the behavior logprob (mixed-policy μ of
Eq. 8) and the weight version each token was sampled under (token lag).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (CACHE_LOGICAL, ModelConfig,
                                effective_cache_len, kv_cache_specs,
                                paged_cache_specs, paged_layout)
from repro.data.math_task import MathTask, Problem
from repro.data.packing import Rollout
from repro.kernels.paged_cache import BlockTables, OutOfPages, PageAllocator
from repro.models import attention as attn
from repro.models import model as M


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 16            # H, the generation batch size
    max_len: int = 64            # prompt + completion budget per sequence
    temperature: float = 1.0
    eos_id: int = 2
    pad_id: int = 0
    # chunked-prefill admission (DESIGN.md §2): newly admitted prompts run
    # through batched `prefill_chunk`-token forwards that write K/V (and
    # SSM state) straight into the slot cache — ceil((P-1)/chunk) model
    # invocations per prompt instead of P-1 one-token decode steps. 0
    # falls back to the legacy token-at-a-time forcing loop. The effective
    # chunk is reduced to the largest common divisor of max_len and the
    # attention cache length, so chunk windows never cross the cache end
    # and ring-buffer (sliding-window) writes stay contiguous — ring
    # caches take the chunked path like everything else.
    prefill_chunk: int = 16
    # Pallas interpret-mode override threaded into every kernel the engine
    # compiles (None = auto: interpret off-TPU, compiled on TPU)
    interpret: Optional[bool] = None
    # admission policy for prompts longer than max_len-2: "reject" drops
    # the prompt and counts it in `prompts_rejected` (the task reward is
    # computed against the FULL problem, so silently truncating the
    # prompt scores the policy on a question it never saw); "truncate"
    # keeps the legacy clip-and-admit behavior, counted in
    # `prompts_truncated`.
    long_prompt: str = "reject"
    # --- paged KV cache (DESIGN.md §9) ---------------------------------
    # "slots": one contiguous max_len stripe per slot (the differential
    # oracle). "paged": attention leaves become page pools addressed
    # through a ref-counted block table — short requests stop reserving
    # max_len of cache, a GRPO group's prompt is prefilled once and
    # forked copy-on-write, and admission is costed in pages.
    cache: str = "slots"
    # logical tokens per page (reduced until it divides the cache length)
    page_size: int = 16
    # physical pages in the pool, including the reserved trash page 0.
    # 0 = auto: n_slots * blocks_per_slot + 1, i.e. exactly the slot-array
    # footprint (no eviction pressure); smaller values trade capacity for
    # memory and rely on page-exhaustion preemption.
    n_pages: int = 0
    # prefill a GRPO group's identical prompt once and fork the rest over
    # shared pages (paged mode with chunked prefill only)
    prefix_sharing: bool = True
    # paged decode read path: "gather" runs the unchanged attention on the
    # gathered per-slot view (bit-identical to the slot engine); "kernel"
    # opts into the scalar-prefetch paged flash-decode kernel (no gather;
    # page-sized softmax blocks, so fp32-close rather than bitwise unless
    # page_size == decode_block_k)
    paged_attention: str = "gather"


# backstop for refill's reject-retry loop: after this many rejections in
# one refill call the engine stops pulling for the tick (turns a source
# that yields only overlong prompts from a hang into slow, counted
# progress — real sources either fit or drain)
_MAX_REJECTS_PER_REFILL = 1024


def _zero_cache(cfg: ModelConfig, n_slots: int, max_len: int):
    specs = kv_cache_specs(cfg, n_slots, max_len)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in specs.items()}


def _zero_paged_cache(cfg: ModelConfig, n_slots: int, max_len: int,
                      n_pages: int, page_size: int):
    specs = paged_cache_specs(cfg, n_slots, max_len, n_pages, page_size)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in specs.items()}


def _paged_ring_view(cache, block_tables):
    """Gather pool leaves (L,NP,PS,...) into slot-layout (L,H,CL,...)
    views through the block table; SSM leaves (already per-slot) pass
    through untouched."""
    out = dict(cache)
    for k in ("k", "v", "c_kv", "k_rope"):
        if k in out:
            v = jnp.take(out[k], block_tables, axis=1)    # (L,H,NB,PS,...)
            out[k] = v.reshape(
                (v.shape[0], v.shape[1], v.shape[2] * v.shape[3])
                + v.shape[4:])
    return out


def _admit_impl(st: Dict[str, Any], new_tokens, new_plen, new_ncached,
                admit_mask, cfg: ModelConfig):
    """Device-side admission: scatter fresh prompt rows into engine state.

    Replaces the old host round trip (five full-state np copies per
    admission) — the only host->device traffic is the (H,T) prompt buffer
    and three (H,) vectors; everything else is donated and updated in
    place. admit_mask: (H,) bool, True where a new prompt enters.
    """
    m = admit_mask
    tokens = jnp.where(m[:, None], new_tokens, st["tokens"])
    lp = jnp.where(m[:, None], 0.0, st["lp"])
    n_cached = jnp.where(m, new_ncached, st["n_cached"])
    prompt_len = jnp.where(m, new_plen, st["prompt_len"])
    active = st["active"] | m
    cache = dict(st["cache"])
    # zero recurrent state of refilled slots (attention cache is masked by
    # cache_index, but SSM state carries over unless cleared)
    if "ssd" in cache:
        keep = (~m).astype(cache["ssd"].dtype)[None, :, None, None, None]
        cache["ssd"] = cache["ssd"] * keep
        keep_c = (~m).astype(cache["conv"].dtype)[None, :, None, None]
        cache["conv"] = cache["conv"] * keep_c
    return dict(st, tokens=tokens, lp=lp, n_cached=n_cached,
                prompt_len=prompt_len, active=active, cache=cache)


def _prefill_impl(params, st: Dict[str, Any], offset, admit_mask,
                  block_tables, cfg: ModelConfig, chunk: int,
                  offset_hint: Optional[int] = None):
    """One chunked-prefill step over the slot state (cache update only).

    offset_hint (static): host-side bound on the valid cache-slot count,
    bucketed to the prefill kernel's block size; shrinks the kernel's
    cache-block grid (grid-level early exit, like decode's kv_len_hint).
    block_tables: (H,NB) int32 in paged mode, None for the slot array."""
    cache = M.prefill_chunk(params, st["tokens"], st["prompt_len"], offset,
                            admit_mask, st["cache"], cfg, chunk=chunk,
                            offset_hint=offset_hint,
                            block_tables=block_tables)
    return dict(st, cache=cache)


def _engine_step(params, st: Dict[str, Any], block_tables,
                 cfg: ModelConfig, ec: EngineConfig,
                 kv_len_hint: Optional[int] = None):
    """One token for every active slot. st: tokens (H,T), n_cached (H,),
    prompt_len (H,), active (H,) bool, cache, lp (H,T), key.

    kv_len_hint (static): host-mirrored bound on the valid cache length,
    bucketed to the flash-decode block size so jit sees few values; shrinks
    the decode kernel's KV grid (grid-level early exit).

    block_tables: (H,NB) int32 in paged mode (None for the slot array).
    The host guarantees, before every step, that each active slot's write
    block is backed by an exclusively-owned page (lazy alloc + COW), and
    that inactive slots' rows are all trash-page zeros so their
    static-shape stale writes land harmlessly."""
    H, T = st["tokens"].shape
    idx = jnp.arange(H)
    cur_tok = st["tokens"][idx, st["n_cached"]][:, None]          # (H,1)
    positions = st["n_cached"][:, None]                           # (H,1)
    out = M.decode_step(params, cur_tok, positions, st["cache"],
                        st["n_cached"], cfg, ring=False,
                        kv_len_hint=kv_len_hint,
                        block_tables=block_tables,
                        paged_kernel=ec.paged_attention == "kernel")
    logits = out["logits"][:, 0] / jnp.maximum(ec.temperature, 1e-6)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)

    key, sub = jax.random.split(st["key"])
    sampled = jax.random.categorical(sub, logp, axis=-1)          # (H,)

    next_idx = st["n_cached"] + 1
    in_prompt = next_idx < st["prompt_len"]
    forced = st["tokens"][idx, jnp.minimum(next_idx, T - 1)]
    next_tok = jnp.where(in_prompt, forced, sampled).astype(jnp.int32)
    tok_lp = jnp.take_along_axis(logp, next_tok[:, None], axis=-1)[:, 0]
    tok_lp = jnp.where(in_prompt, 0.0, tok_lp)

    active = st["active"]
    write = active & (next_idx < T)
    tokens = st["tokens"].at[idx, jnp.minimum(next_idx, T - 1)].set(
        jnp.where(write, next_tok, st["tokens"][idx, jnp.minimum(next_idx, T - 1)]))
    lp = st["lp"].at[idx, jnp.minimum(next_idx, T - 1)].set(
        jnp.where(write, tok_lp, st["lp"][idx, jnp.minimum(next_idx, T - 1)]))

    finished = active & ~in_prompt & (
        (next_tok == ec.eos_id) | (next_idx >= T - 1))
    n_cached = jnp.where(active, next_idx, st["n_cached"])
    new_active = active & ~finished

    new_st = dict(st, tokens=tokens, lp=lp, key=key,
                  n_cached=n_cached, active=new_active, cache=out["cache"])
    return new_st, finished


class GenerationEngine:
    """H-slot continuous-batching engine (Algorithm 2, Actor).

    `jit_donor`: another engine whose compiled step/admit/prefill
    callables are reused when cfg+ec match — an actor pool of identical
    engines (core.events.ActorStage) compiles the hot functions once
    instead of once per engine."""

    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig,
                 prompt_source: Callable[[], Problem], seed: int = 0,
                 jit_donor: Optional["GenerationEngine"] = None,
                 mesh=None, rules=None):
        if ec.interpret is not None:
            cfg = dataclasses.replace(cfg, pallas_interpret=ec.interpret)
        self.cfg, self.ec = cfg, ec
        # --- real-mesh placement (DESIGN.md §11): when `mesh` is given the
        # engine owns a device set — params live in the generation layout
        # from `tree_shardings`, the KV cache follows CACHE_LOGICAL, and
        # every jitted call runs under `sharding_context` so the model's
        # `constrain` annotations become real sharding constraints.
        self.mesh, self.rules = mesh, rules
        self._param_shardings = None
        self._pshard_leaves: Optional[List[Any]] = None
        # executed-transfer log, one entry per measured device placement:
        # {"kind": "atomic"|"chunk", "version", "k", "nbytes", "seconds"}
        self.wexec_log: List[Dict[str, Any]] = []
        if mesh is not None:
            from repro.sharding import tree_shardings
            ann = M.init_params(cfg, abstract=True)
            self._param_shardings = tree_shardings(ann, mesh, rules)
            self._pshard_leaves = jax.tree_util.tree_leaves(
                self._param_shardings)
            params = jax.device_put(params, self._param_shardings)
        self.params = params      # behavior weights μ
        self.version = 0          # trainer version of μ
        self.prompt_source = prompt_source
        H, T = ec.n_slots, ec.max_len
        # --- paged KV cache (DESIGN.md §9): page pool + block tables ----
        # attention-free archs have nothing to page; they run the slot
        # state machine under either setting (admission costs 0 pages)
        self._paged = ec.cache == "paged" and cfg.has_attention
        if ec.cache not in ("slots", "paged"):
            raise ValueError(f"EngineConfig.cache: {ec.cache!r}")
        self.allocator: Optional[PageAllocator] = None
        self.tables: Optional[BlockTables] = None
        self._bt_jax = None                 # device copy of the block table
        self._bt_dirty = False
        self._deferred: "collections.deque[Problem]" = collections.deque()
        if self._paged:
            ps, nb = paged_layout(cfg, T, ec.page_size)
            n_pages = ec.n_pages or H * nb + 1
            if n_pages - 1 < nb:
                # a lone sequence must be able to fill its table even after
                # preempting everyone else, or eviction cannot terminate
                raise ValueError(
                    f"n_pages={n_pages} cannot back one full sequence "
                    f"({nb} blocks + trash page)")
            self.allocator = PageAllocator(n_pages, ps)
            self.tables = BlockTables(H, nb, self.allocator)
            self._bt_jax = jnp.zeros((H, nb), jnp.int32)
            cache = _zero_paged_cache(cfg, H, T, n_pages, ps)
        else:
            cache = _zero_cache(cfg, H, T)
        self.state: Dict[str, Any] = {
            "tokens": jnp.zeros((H, T), jnp.int32),
            "lp": jnp.zeros((H, T), jnp.float32),
            "n_cached": jnp.zeros((H,), jnp.int32),
            "prompt_len": jnp.ones((H,), jnp.int32),
            "active": jnp.zeros((H,), bool),
            "cache": cache,
            "key": jax.random.PRNGKey(seed),
        }
        if mesh is not None:
            self.state = jax.device_put(self.state, self._state_shardings())
        # host-side bookkeeping
        self.problems: List[Optional[Problem]] = [None] * H
        self.ver_buf = np.zeros((H, T), np.int32)
        self.started_at = np.zeros(H, np.float64)
        self.tokens_generated = 0
        # host mirrors of the scheduling scalars — the step/refill hot loop
        # never reads engine state back from device except `finished`
        self._host_active = np.zeros(H, bool)
        self._host_ncached = np.zeros(H, np.int64)
        self._host_prompt_len = np.ones(H, np.int64)
        # attention cache length (None for attention-free archs); a ring
        # buffer when < T (sliding-window long-context decode). In paged
        # mode the leaves are (L,NP,PS,...) pools, so the logical length
        # comes from the layout, not the leaf shape.
        self._cache_len: Optional[int] = None
        if cfg.has_attention:
            if self._paged:
                self._cache_len = (self.tables.n_blocks
                                   * self.allocator.page_size)
                assert self._cache_len == effective_cache_len(cfg, T)
            else:
                self._cache_len = (
                    self.state["cache"]["k"].shape[2]
                    if "k" in self.state["cache"]
                    else self.state["cache"]["c_kv"].shape[2])
        # the decode-length hint only matters when gqa_decode actually
        # takes the flash-decode kernel path; computing it otherwise would
        # re-trace the jitted step once per hint bucket for no benefit
        self._use_decode_hint = (self._cache_len is not None
                                 and attn.uses_flash_decode(
                                     cfg, self._cache_len))
        # chunked prefill: the effective chunk must divide T (chunk windows
        # never cross the token buffer end) and the cache length (modular
        # ring writes stay contiguous — DESIGN.md §2 chunk geometry); in
        # paged mode it must also divide the page size, so every chunk
        # write lands inside exactly one logical block
        chunk = max(int(ec.prefill_chunk), 0)
        if chunk:
            cl = self._cache_len or T
            ps = self.allocator.page_size if self._paged else cl
            chunk = min(chunk, T, cl, ps)
            while T % chunk or cl % chunk or ps % chunk:
                chunk -= 1
        self.prefill_chunk_size = chunk
        self.prefill_invocations = 0       # chunked-prefill model calls
        self.prefill_tokens = 0            # prompt tokens admitted via prefill
        self.last_admit_prefill_tokens = 0
        # paged-mode accounting (all stay 0 for the slot array)
        self.prompt_prefills = 0           # rows actually prefilled (leaders)
        self.prefix_forks = 0              # rows admitted by COW fork
        self.last_admit_pages = 0          # pages allocated by last refill
        self.slots_preempted = 0           # page-exhaustion evictions
        self.pages_copied = 0              # COW page copies materialized
        # long-prompt admission accounting (EngineConfig.long_prompt)
        self.prompts_rejected = 0
        self.prompts_truncated = 0
        # notified with the dropped Problem on every rejection (the Server
        # uses it to fail the owning request instead of losing it)
        self.on_prompt_rejected: Optional[Callable[[Problem], None]] = None
        # streamed in-flight weight broadcast (DESIGN.md §7): shadow param
        # buffer filled chunk-by-chunk between decode steps
        self._wstream: Optional[Dict[str, Any]] = None
        # §10 integrity gate accounting: damaged transmissions rejected
        # by the per-chunk checksum, and assembled streams rejected by
        # the pre-swap digest verify (both must stay 0 on healthy links)
        self.wchunks_rejected = 0
        self.wstreams_torn = 0
        self.last_stream_installed = True
        if (jit_donor is not None and jit_donor.cfg == cfg
                and jit_donor.ec == ec
                and getattr(jit_donor, "mesh", None) == mesh
                and getattr(jit_donor, "rules", None) == rules):
            self._step = jit_donor._step
            self._recompute = jit_donor._recompute
            self._admit = jit_donor._admit
            if chunk:
                self._prefill = jit_donor._prefill
                self._use_prefill_hint = jit_donor._use_prefill_hint
            return
        self._step = jax.jit(functools.partial(_engine_step, cfg=cfg, ec=ec),
                             static_argnames=("kv_len_hint",))
        rc = (self._recompute_impl_paged if self._paged
              else self._recompute_impl)
        self._recompute = jax.jit(functools.partial(rc, cfg=cfg))
        self._admit = jax.jit(functools.partial(_admit_impl, cfg=cfg),
                              donate_argnums=(0,))
        if chunk:
            self._prefill = jax.jit(
                functools.partial(_prefill_impl, cfg=cfg, chunk=chunk),
                donate_argnums=(1,), static_argnames=("offset_hint",))
            # hint buckets only matter when the Pallas prefill kernel runs
            # (each bucket is one extra compile of the chunk forward)
            self._use_prefill_hint = (self._cache_len is not None
                                      and attn._use_prefill_kernel(
                                          cfg, chunk, self._cache_len))

    # ----- device placement (DESIGN.md §11 real-mesh runtime) ----------
    def _state_shardings(self):
        """Engine-state placement: slot-cache leaves follow CACHE_LOGICAL
        through the rules engine (cache_seq / kv_heads sharding); paged
        pool leaves and the scheduling vectors stay replicated — GSPMD
        keeps the jitted step semantics-identical either way."""
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.sharding import logical_to_spec
        rep = NamedSharding(self.mesh, PartitionSpec())
        sh: Dict[str, Any] = {k: rep for k in self.state if k != "cache"}
        cache = {}
        for k, v in self.state["cache"].items():
            if self._paged or k not in CACHE_LOGICAL:
                cache[k] = rep
            else:
                cache[k] = NamedSharding(self.mesh, logical_to_spec(
                    CACHE_LOGICAL[k], v.shape, self.mesh, self.rules))
        sh["cache"] = cache
        return sh

    def _ctx(self):
        """Ambient sharding context for every jitted call — a no-op for
        mesh-less engines, so the simulated pool is untouched."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.shardctx import sharding_context
        return sharding_context(self.mesh, self.rules)

    # ----- weights -----------------------------------------------------
    def set_weights(self, params, version: int, recompute_kv: bool = False,
                    _placed: bool = False):
        """In-flight weight update: swap μ, keep the (stale) KV cache.
        recompute_kv=True reproduces the paper's §5.1 ablation (recompute
        the cache of in-progress sequences under the new weights). An
        atomic swap supersedes any in-progress weight stream.

        On a mesh engine the swap is an *executed* transfer: the incoming
        tree is resharded onto this engine's placement and the measured
        wall time lands in `wexec_log` (`_placed=True` skips the copy when
        the caller already delivered device-resident buffers, e.g. the
        final swap of an executed chunk stream)."""
        self._wstream = None
        if self.mesh is not None and not _placed:
            from repro.core.events import tree_bytes
            t0 = time.perf_counter()
            params = jax.device_put(params, self._param_shardings)
            jax.block_until_ready(params)
            self.wexec_log.append({
                "kind": "atomic", "version": int(version), "k": -1,
                "nbytes": tree_bytes(params),
                "seconds": time.perf_counter() - t0})
        self.params = params
        self.version = version
        if recompute_kv:
            if self._paged:
                # unshare every shared block first: the recompute scatter
                # overwrites all positions of every referenced page, which
                # must not clobber a page other forks still read — and a
                # page referenced twice in one scatter would be written
                # nondeterministically
                self._unshare_all()
                self._sync_tables()
                with self._ctx():
                    self.state["cache"] = self._recompute(
                        params, self.state, self._bt_jax)
            else:
                with self._ctx():
                    self.state["cache"] = self._recompute(params, self.state)

    def begin_weight_stream(self, params, version: int, n_chunks: int = 8,
                            recompute_kv: bool = False,
                            expect_digest: Optional[int] = None,
                            chunk_leaves: Optional[List[List[Any]]] = None
                            ) -> List[int]:
        """Streamed in-flight broadcast (DESIGN.md §7): stage the new
        param tree into a shadow buffer chunk-by-chunk between decode
        steps via `stream_weight_chunk`; μ (and `self.version`) stay on
        the old weights until the final chunk lands, then pointer-swap —
        so per-token `weight_versions` stamps stay exact across the whole
        transfer. A second `begin` abandons the unfinished shadow buffer.
        `expect_digest` arms the §10 integrity gate: the assembled stream
        must reproduce it before the swap is allowed. `chunk_leaves[k]`,
        when given, holds the k-th span's leaves already resharded onto
        this engine's devices (a WeightBroadcaster execution backend ran
        the transfer) — installs consume those buffers instead of the
        sender's. Returns the per-chunk byte sizes (for interconnect
        costing)."""
        from repro.core.events import chunk_spans, span_bytes
        leaves, treedef = jax.tree_util.tree_flatten(params)
        spans = chunk_spans(leaves, n_chunks)
        sizes = span_bytes(leaves, spans)
        self._wstream = {
            "treedef": treedef, "leaves": leaves, "spans": spans,
            "sizes": sizes, "shadow": [None] * len(leaves), "next": 0,
            "version": version, "recompute": recompute_kv,
            "expect": expect_digest, "tokens": [],
            "chunk_leaves": chunk_leaves,
        }
        return sizes

    def stream_weight_chunk(self, token: Optional[int] = None) -> bool:
        """Install the next chunk into the shadow buffer; on the last
        chunk, assemble the tree and pointer-swap it in (returns True).
        No-op (False) when no stream is active.

        Integrity gate (DESIGN.md §10): when the transmission carries a
        checksum `token`, it must match the token this engine computes
        from its own span table — a damaged chunk is rejected before it
        touches the shadow buffer (`wchunks_rejected`) and the sender's
        backoff machinery retransmits it. Before the pointer swap the
        whole shadow buffer is verified (every span filled + accumulated
        digest matches the publication digest), so a torn stream can
        never install (`wstreams_torn`); `last_stream_installed` tells
        the stage whether the final chunk actually swapped weights."""
        from repro.core.events import chunk_token, stream_digest
        ws = self._wstream
        if ws is None:
            return False
        k = ws["next"]
        if token is not None:
            if token != chunk_token(ws["version"], k, ws["sizes"][k]):
                self.wchunks_rejected += 1
                return False
        lo, hi = ws["spans"][k]
        if ws.get("chunk_leaves") is not None:
            # executor-resharded span: the buffers already live on this
            # engine's devices (k-indexed, so a retransmit after a
            # rejected chunk naturally reuses the right span)
            ws["shadow"][lo:hi] = list(ws["chunk_leaves"][k])
        elif self.mesh is not None:
            # in-engine executed transfer: reshard the span onto this
            # engine's placement, measured (DESIGN.md §11)
            t0 = time.perf_counter()
            placed = jax.device_put(ws["leaves"][lo:hi],
                                    self._pshard_leaves[lo:hi])
            jax.block_until_ready(placed)
            self.wexec_log.append({
                "kind": "chunk", "version": int(ws["version"]), "k": k,
                "nbytes": ws["sizes"][k],
                "seconds": time.perf_counter() - t0})
            ws["shadow"][lo:hi] = placed
        else:
            ws["shadow"][lo:hi] = ws["leaves"][lo:hi]
        ws["tokens"].append(chunk_token(ws["version"], k, ws["sizes"][k]))
        ws["next"] += 1
        if ws["next"] < len(ws["spans"]):
            return False
        torn = any(x is None for x in ws["shadow"]) or (
            ws["expect"] is not None
            and stream_digest(ws["tokens"]) != ws["expect"])
        if torn:
            self.wstreams_torn += 1
            self.last_stream_installed = False
            self._wstream = None
            return True
        params = jax.tree_util.tree_unflatten(ws["treedef"], ws["shadow"])
        version, recompute = ws["version"], ws["recompute"]
        self.last_stream_installed = True
        self.set_weights(params, version, recompute_kv=recompute,
                         _placed=True)
        return True

    @property
    def stream_active(self) -> bool:
        return self._wstream is not None

    # ----- crash semantics (DESIGN.md §8 failure model) -----------------
    def reset_slots(self) -> int:
        """Kill every in-flight sequence — engine-process crash semantics.
        All slots go inactive and their token/KV contents are abandoned
        (safe: admission overwrites tokens and prefill rewrites every
        cache position a later decode step may read, exactly as on normal
        slot reuse); any half-filled weight-stream shadow buffer is
        dropped (the restart's catch-up sync supersedes it). In paged
        mode every page reference — including shared prefix pages, whose
        refcounts drop once per holding slot — returns to the pool;
        prompts deferred by page pressure are dropped with the slots (a
        salvage path that wants them calls `drain_deferred()` first).
        Returns the number of live slots killed, i.e. the rollouts
        lost."""
        n = int(self._host_active.sum())
        H = self.ec.n_slots
        self._host_active[:] = False
        self._host_ncached[:] = 0
        self._host_prompt_len[:] = 1
        self.problems = [None] * H
        self._wstream = None
        self._deferred.clear()
        if self._paged:
            for s in range(H):
                self.tables.release_row(s)
            assert self.allocator.live_pages == 0, "pages leaked on reset"
            self._bt_dirty = True
            self._sync_tables()
        self.state = dict(
            self.state,
            n_cached=jnp.zeros((H,), jnp.int32),
            prompt_len=jnp.ones((H,), jnp.int32),
            active=jnp.zeros((H,), bool))
        return n

    def drain_deferred(self) -> List[Problem]:
        """Hand back prompts parked by page-exhaustion deferral/preemption
        (salvage path: they re-enter the pool through the router like the
        live slots' prompts)."""
        out = list(self._deferred)
        self._deferred.clear()
        return out

    def kill_slot(self, s: int) -> Optional[Problem]:
        """Kill ONE live slot without crashing the engine (DESIGN.md §10
        quarantine path): the slot's rollout-in-progress is abandoned
        exactly as in `reset_slots` — tokens/KV left for reuse, pages
        (shared refs included) returned — and its prompt is handed back
        so the caller can quarantine or requeue it. Returns None for an
        inactive slot."""
        s = int(s)
        if not self._host_active[s]:
            return None
        prob = self.problems[s]
        self._host_active[s] = False
        self._host_ncached[s] = 0
        self._host_prompt_len[s] = 1
        self.problems[s] = None
        if self._paged:
            self.tables.release_row(s)
            self._bt_dirty = True
            self._sync_tables()
        self.state = dict(
            self.state,
            n_cached=self.state["n_cached"].at[s].set(0),
            prompt_len=self.state["prompt_len"].at[s].set(1),
            active=self.state["active"].at[s].set(False))
        return prob

    # ----- paged-cache machinery (DESIGN.md §9) -------------------------
    @property
    def free_pages(self) -> int:
        """Free pages in the pool (a large sentinel for the slot array /
        attention-free engines, whose admission is slot-bounded only)."""
        if not self._paged:
            return 1 << 30
        return self.allocator.free_pages

    def pages_needed(self, prompt_len: int) -> int:
        """Pages a prompt of `prompt_len` needs through admission and its
        first decode write (its logical footprint is capped by the ring
        length)."""
        if not self._paged:
            return 0
        cl = self._cache_len
        return self.tables.blocks_for(min(max(int(prompt_len), 1), cl))

    def can_admit(self, prompt_len: int) -> bool:
        """Page-costed admission check (serving/router gate): True when a
        free slot exists AND the pool can back the prompt without evicting
        in-flight work. Slot-array engines only check slots."""
        if not (~self._host_active).any():
            return False
        return self.free_pages >= self.pages_needed(prompt_len)

    def _sync_tables(self) -> None:
        if self._paged and self._bt_dirty:
            self._bt_jax = jnp.asarray(self.tables.table)
            self._bt_dirty = False

    def _unshare_all(self) -> None:
        """Break every COW share: after this, each live page is referenced
        by exactly one table entry (recompute_kv's full-scatter needs
        exclusive pages; no device copy — the scatter overwrites every
        position of every referenced page)."""
        tb, alloc = self.tables, self.allocator
        for s in range(self.ec.n_slots):
            for j in range(tb.n_blocks):
                p = int(tb.table[s, j])
                if p and alloc.refcount[p] > 1:
                    q = alloc.alloc()
                    alloc.refcount[p] -= 1
                    tb.table[s, j] = q
                    self._bt_dirty = True

    def _evict_one(self, requester: int) -> bool:
        """Preempt the least-progressed active slot (ties: higher index)
        to free its pages; its prompt re-enters through `_deferred` at the
        front. Returns False when no victim exists."""
        victims = [s for s in np.where(self._host_active)[0]
                   if s != requester]
        if not victims:
            return False
        progress = {s: int(self._host_ncached[s] - self._host_prompt_len[s])
                    for s in victims}
        victim = max(victims, key=lambda s: (-progress[s], s))
        self.tables.release_row(victim)
        self._bt_dirty = True
        self._host_active[victim] = False
        prob = self.problems[victim]
        self.problems[victim] = None
        if prob is not None:
            self._deferred.appendleft(prob)
        self.slots_preempted += 1
        # the jitted step reads `active` from device state — push the kill
        self.state = dict(self.state,
                          active=jnp.asarray(self._host_active))
        return True

    def _ensure_block(self, s: int, j: int,
                      copies: List[Tuple[int, int]]) -> None:
        """Host side of the lazy alloc/COW discipline for one (slot,
        block): allocate or copy-on-write, evicting under page pressure.
        Termination: n_pages-1 >= n_blocks (checked at init) and the
        requester holds < n_blocks pages when an alloc is needed, so
        after evicting every other slot a free page must exist."""
        while True:
            before = int(self.tables.table[s, j])
            try:
                pair = self.tables.ensure_writable(s, j)
            except OutOfPages:
                if not self._evict_one(s):
                    raise
                continue
            if pair is not None:
                copies.append(pair)
                self.pages_copied += 1
            if int(self.tables.table[s, j]) != before:
                self._bt_dirty = True
            return

    def _prepare_pages_for_step(self) -> None:
        """Before every decode step: make each active slot's write block
        (ring position n_cached mod CL) exclusively owned — lazy alloc at
        block entry, COW at a fork's divergence block — and materialize
        the COW copies on device. Establishes the invariant the jitted
        step relies on: no write ever lands on a page with refcount > 1."""
        if not self._paged:
            return
        ps = self.allocator.page_size
        cl = self._cache_len
        copies: List[Tuple[int, int]] = []
        for s in np.where(self._host_active)[0]:
            if not self._host_active[s]:
                continue  # evicted mid-loop by an earlier slot's alloc
            j = (int(self._host_ncached[s]) % cl) // ps
            self._ensure_block(int(s), j, copies)
        if copies:
            src = np.array([c[0] for c in copies])
            dst = np.array([c[1] for c in copies])
            cache = dict(self.state["cache"])
            for k in ("k", "v", "c_kv", "k_rope"):
                if k in cache:
                    cache[k] = cache[k].at[:, dst].set(cache[k][:, src])
            self.state = dict(self.state, cache=cache)
        self._sync_tables()

    def _release_slot_pages(self, s: int) -> None:
        """Rollout finished (or slot abandoned): drop the slot's page
        references — shared prefix pages survive until the last fork
        finishes — and zero its table row so the static-shape stale
        writes of the now-inactive row land on the trash page."""
        if self._paged:
            self.tables.release_row(int(s))
            self._bt_dirty = True

    @staticmethod
    def _recompute_impl_paged(params, st, block_tables, cfg: ModelConfig):
        """Paged twin of `_recompute_impl`: recompute through the slot
        twin's ring-gather, then scatter each row's ring view into its own
        pages. The caller has unshared every block (refcount 1), so no
        page is written twice except the trash page (unallocated entries
        of inactive/short rows — never read)."""
        view = GenerationEngine._recompute_impl(
            params, dict(st, cache=_paged_ring_view(st["cache"],
                                                    block_tables)), cfg)
        new = dict(st["cache"])
        NB = block_tables.shape[1]
        for k in ("k", "v", "c_kv", "k_rope"):
            if k not in new:
                continue
            pool = new[k]                         # (L,NP,PS,...)
            L, NP, PS = pool.shape[:3]
            v = view[k]                           # (L,H,CL,...)
            vr = v.reshape((L, v.shape[1], NB, PS) + v.shape[3:])
            new[k] = pool.at[:, block_tables].set(vr.astype(pool.dtype))
        return new

    @staticmethod
    def _recompute_impl(params, st, cfg: ModelConfig):
        H, T = st["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(T)[None], (H, T))
        out = M.forward(params, st["tokens"], positions, cfg, return_cache=True)
        # entries at positions >= n_cached are garbage in both old and new
        # caches (masked by cache_index), so a full overwrite is safe.
        new = dict(st["cache"])
        for k in ("k", "v", "c_kv", "k_rope", "conv", "ssd"):
            if k not in out["cache"]:
                continue
            if k in ("conv", "ssd"):
                continue  # recurrent state recompute not supported here
            full = out["cache"][k]            # (L,H,T,...) full-length
            if full.shape == new[k].shape:
                new[k] = full.astype(new[k].dtype)
                continue
            # ring cache (CL < T): gather the last CL positions of the
            # full-length recompute into ring order — slot j must hold the
            # most recent position p <= n_cached-1 with p ≡ j (mod CL),
            # exactly what the sequential decode loop would have written
            # (the §3 ablation then works on sliding-window engines too).
            # Rows with n_cached <= CL reduce to p_j = j for live slots;
            # slots beyond a row's frontier clamp to dead positions that
            # count-based decode masking never reads.
            CL = new[k].shape[2]
            nc = st["n_cached"][None, :, None]              # (1,H,1)
            j = jnp.arange(CL)[None, None]                  # (1,1,CL)
            p = (nc - 1) - jnp.mod(nc - 1 - j, CL)          # (1,H,CL)
            p = jnp.clip(p, 0, T - 1)
            idx = p.reshape(p.shape + (1,) * (full.ndim - 3))
            new[k] = jnp.take_along_axis(
                full, jnp.broadcast_to(
                    idx, full.shape[:2] + (CL,) + full.shape[3:]),
                axis=2).astype(new[k].dtype)
        return new

    # ----- admission ----------------------------------------------------
    def refill(self, now: float = 0.0) -> int:
        """Fill inactive slots with fresh prompts. The prompt source may
        return None to decline (serving: empty request queue) — those slots
        stay inactive. Returns #admitted.

        Admission is device-side: a jitted, donated `admit` scatters the
        new prompt rows into tokens/n_cached/prompt_len/lp/active (no full
        engine-state round trip through host numpy), then chunked prefill
        writes the prompts' K/V into the slot cache in ceil((P-1)/chunk)
        batched forwards (prefill_chunk=0: legacy token-at-a-time loop).

        Paged mode (DESIGN.md §9): admission is page-costed — a prompt
        only enters when the pool can back its blocks (otherwise it parks
        in `_deferred`, consumed first next refill, and the engine stops
        pulling for the tick); identical prompts admitted in the same
        refill form a GRPO prefix-sharing group: the leader alone runs
        prefill (and alone counts prefill tokens/pages), the rest fork
        its pages copy-on-write and merely copy its recurrent SSM state.
        """
        self.last_admit_prefill_tokens = 0
        self.last_admit_pages = 0
        free = np.where(~self._host_active)[0]
        if free.size == 0:
            return 0
        H, T = self.ec.n_slots, self.ec.max_len
        new_tokens = np.full((H, T), self.ec.pad_id, np.int32)
        new_plen = np.zeros(H, np.int32)
        mask = np.zeros(H, bool)
        admitted = []
        chunk = self.prefill_chunk_size
        allocs0 = self.allocator.total_allocs if self._paged else 0
        # prefix sharing needs the chunked path: forks resume at n_cached
        # = P-1, which the legacy token-forcing loop never reaches
        share = self._paged and chunk > 0 and self.ec.prefix_sharing
        leaders: Dict[Tuple[int, ...], int] = {}
        prefill_mask = np.zeros(H, bool)   # rows that run prefill
        forks: List[Tuple[int, int]] = []  # (fork slot, leader slot)
        # a rejected prompt re-offers its slot immediately (otherwise one
        # overlong request idles a slot for a whole tick while admissible
        # prompts wait); the budget bounds the spin against a pathological
        # source that yields nothing but overlong prompts
        rejects_left = _MAX_REJECTS_PER_REFILL
        out_of_pages = False
        for s in free:
            while True:
                prob = (self._deferred.popleft() if self._deferred
                        else self.prompt_source())
                if prob is None:
                    break
                pl = len(prob.prompt_ids)
                if pl <= T - 2:
                    break
                # no room for even one sampled token + EOS: either clip
                # (legacy, opt-in) or reject-and-count — never silently
                # truncate, the reward scores the full problem
                if self.ec.long_prompt == "truncate":
                    pl = T - 2
                    self.prompts_truncated += 1
                    break
                self.prompts_rejected += 1
                if self.on_prompt_rejected is not None:
                    self.on_prompt_rejected(prob)
                rejects_left -= 1
                if rejects_left <= 0:
                    prob = None
                    break
            if prob is None:
                if rejects_left <= 0:
                    break
                continue
            key = tuple(prob.prompt_ids[:pl]) if share else None
            if share and key in leaders:
                # COW fork: share the leader's pages, prefill nothing
                forks.append((int(s), leaders[key]))
            elif self._paged:
                if self.allocator.free_pages < self.pages_needed(pl):
                    # page-costed admission: park the prompt (front of the
                    # deferral queue) and stop pulling — pages free up as
                    # in-flight rollouts finish
                    self._deferred.appendleft(prob)
                    out_of_pages = True
                    break
                need = (self.tables.blocks_for(
                    min(max(pl - 1, 0), self._cache_len)) if chunk else 0)
                if need:
                    self.tables.alloc_prefix(int(s), need)
                    self._bt_dirty = True
                if share:
                    leaders[key] = int(s)
                prefill_mask[s] = True
            else:
                prefill_mask[s] = True
            admitted.append(s)
            new_tokens[s, :pl] = prob.prompt_ids[:pl]
            new_plen[s] = pl
            mask[s] = True
            self.problems[s] = prob
            self.ver_buf[s] = 0
            self.started_at[s] = now
        del out_of_pages  # loop already stopped; counted via _deferred
        if not admitted:
            return 0
        # chunked path: the cache is prefilled below, so decode resumes at
        # the LAST prompt token (n_cached = P-1); legacy path starts at 0
        # and forces the prompt token by token
        target_nc = (np.maximum(new_plen - 1, 0) if chunk
                     else np.zeros(H, np.int32))
        with self._ctx():
            self.state = self._admit(self.state, jnp.asarray(new_tokens),
                                     jnp.asarray(new_plen),
                                     jnp.asarray(target_nc.astype(np.int32)),
                                     jnp.asarray(mask))
        self._host_active[mask] = True
        self._host_prompt_len[mask] = new_plen[mask]
        self._host_ncached[mask] = target_nc[mask]
        self._sync_tables()
        if chunk:
            # forks never prefill: their cache IS the leader's prefix
            n_pre = (int(new_plen[prefill_mask].max()) - 1
                     if prefill_mask.any() else 0)
            for off in range(0, max(n_pre, 0), chunk):
                # grid-level early exit for the prefill kernel: bound the
                # valid cache-slot count from the host-known chunk offset,
                # rounded up to the kernel block so jit sees at most
                # CL/block distinct static values (DESIGN.md §5)
                hint = None
                if self._use_prefill_hint:
                    cl = self._cache_len
                    blk = attn.prefill_block_k(cl)
                    hint = int(min(cl, -(-min(off, cl) // blk) * blk))
                with self._ctx():
                    self.state = self._prefill(self.params, self.state, off,
                                               jnp.asarray(prefill_mask),
                                               self._bt_jax,
                                               offset_hint=hint)
                self.prefill_invocations += 1
            self.last_admit_prefill_tokens = int(
                np.maximum(new_plen[prefill_mask] - 1, 0).sum())
            self.prefill_tokens += self.last_admit_prefill_tokens
            self.prompt_prefills += int(prefill_mask.sum())
        if forks:
            for f, ldr in forks:
                self.tables.fork_row(f, ldr)
            self._bt_dirty = True
            self._sync_tables()
            self.prefix_forks += len(forks)
            # recurrent SSM state is per-slot (not paged): forks copy the
            # leader's post-prefill conv/ssd rows
            farr = np.array([f for f, _ in forks])
            larr = np.array([ldr for _, ldr in forks])
            cache = dict(self.state["cache"])
            for k in ("conv", "ssd"):
                if k in cache:
                    cache[k] = cache[k].at[:, farr].set(cache[k][:, larr])
            self.state = dict(self.state, cache=cache)
        if self._paged:
            self.last_admit_pages = self.allocator.total_allocs - allocs0
        return len(admitted)

    @property
    def n_active(self) -> int:
        return int(self._host_active.sum())

    # ----- stepping -----------------------------------------------------
    def step(self, task: Optional[MathTask] = None,
             now: float = 0.0) -> List[Rollout]:
        """Generate one token on every active slot; returns rollouts that
        finished this step."""
        if self._paged:
            # host-side COW hook: every active slot's next write lands on
            # an exclusively-owned page (may preempt a slot on OutOfPages,
            # which deactivates it before the mirrors are snapshotted)
            self._prepare_pages_for_step()
        prev_active = self._host_active.copy()
        prev_ncached = self._host_ncached.copy()
        # grid-level early exit for flash-decode: bound the valid cache
        # length from the host mirrors, rounded up to the kernel's block
        # size so jit sees at most CL/block distinct static values. Only
        # active slots count — an idle slot's stale high count would pin
        # the hint at capacity; inactive rows' (possibly truncated)
        # attention outputs are discarded by the `active` gating anyway.
        hint = None
        if self._use_decode_hint:
            cl = self._cache_len
            blk = attn.decode_block_k(cl)
            cur = (int(self._host_ncached[self._host_active].max()) + 1
                   if self._host_active.any() else 1)
            hint = int(min(cl, -(-cur // blk) * blk))
        with self._ctx():
            self.state, finished = self._step(self.params, self.state,
                                              self._bt_jax,
                                              kv_len_hint=hint)
        finished = np.asarray(finished)
        # record weight version for tokens written this step — only tokens
        # actually *sampled* under μ; prompt-forced tokens keep version 0
        # so token-lag stats can't be diluted by the prompt mask convention
        nxt = prev_ncached + 1
        wrote = (prev_active & (nxt < self.ec.max_len)
                 & (nxt >= self._host_prompt_len))
        self.ver_buf[wrote, nxt[wrote]] = self.version
        self.tokens_generated += int(prev_active.sum())
        # advance host mirrors (device does n_cached+1 on active slots)
        self._host_ncached[prev_active] += 1
        self._host_active[finished] = False

        done: List[Rollout] = []
        if finished.any():
            tokens = np.asarray(self.state["tokens"])
            lp = np.asarray(self.state["lp"])
            for s in np.where(finished)[0]:
                if self._paged:
                    # finished slots return their pages (shared-prefix
                    # pages only truly free once every fork finishes)
                    self._release_slot_pages(int(s))
                L = int(self._host_ncached[s]) + 1  # incl. just-sampled token
                L = min(L, self.ec.max_len)
                prob = self.problems[s]
                pl = int(self._host_prompt_len[s])
                completion = tokens[s, pl:L]
                reward = 0.0
                if task is not None and prob is not None:
                    reward = task.reward(prob, completion,
                                         self.ec.max_len - pl)
                done.append(Rollout(
                    tokens=tokens[s, :L].copy(),
                    prompt_len=pl,
                    behavior_logprobs=lp[s, :L].copy(),
                    reward=reward,
                    weight_versions=self.ver_buf[s, :L].copy(),
                    finished_at=now,
                    prompt_key=(hash(tuple(prob.prompt_ids)) & 0x7FFFFFFF
                                if prob is not None else 0),
                    slot=int(s),
                    truncated=bool(tokens[s, L - 1] != self.ec.eos_id),
                ))
        return done

    def oldest_inflight_version(self) -> Optional[int]:
        """Smallest weight-version stamp among sampled tokens of in-flight
        (active, past-prompt) slots — the staleness frontier the periodic-
        asynchrony gate reports. None when nothing sampled is in flight."""
        oldest: Optional[int] = None
        for s in np.where(self._host_active)[0]:
            pl = int(self._host_prompt_len[s])
            nc = int(self._host_ncached[s])
            if nc + 1 <= pl:       # still in prompt: nothing sampled yet
                continue
            v = int(self.ver_buf[s, pl:min(nc + 1, self.ec.max_len)].min())
            oldest = v if oldest is None else min(oldest, v)
        return oldest
