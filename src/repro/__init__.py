"""PipelineRL in JAX: asynchronous RL for LLMs with in-flight weight
updates (Piché et al., 2025), as a multi-pod TPU framework.

Public API (the paper's contribution as a composable module):

    from repro import PipelineRL, PipelineConfig      # Alg. 2 orchestrator
    from repro import GenerationEngine, EngineConfig  # Actor (in-flight updates)
    from repro import Trainer, RLConfig               # IS-REINFORCE trainer
    from repro import ConventionalRL                  # Alg. 1 baseline
    from repro.configs import get_config, SHAPES      # 10 assigned archs
"""
from repro.core.algo import RLConfig
from repro.core.conventional import ConventionalConfig, ConventionalRL
from repro.core.events import (
    ActorStage, EventLoop, Fault, FaultPlan, PoolRouter, PreprocessStage,
    TrainerStage, WeightBroadcaster,
)
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.preprocess import PreprocessConfig, Preprocessor
from repro.core.rollout import EngineConfig, GenerationEngine
from repro.core.serving import Server
from repro.core.sim import HardwareModel
from repro.core.trainer import Trainer

__all__ = [
    "ActorStage", "ConventionalConfig", "ConventionalRL", "EngineConfig",
    "EventLoop", "Fault", "FaultPlan", "GenerationEngine", "HardwareModel",
    "PipelineConfig", "PipelineRL", "PoolRouter", "PreprocessConfig",
    "Preprocessor", "PreprocessStage", "RLConfig", "Server", "Trainer",
    "TrainerStage", "WeightBroadcaster",
]
