"""Production mesh builders.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices this host actually has, as a 1D data mesh (used by
    smoke tests / the CPU RL driver)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def engine_submeshes(mesh: Mesh, n_engines: int,
                     axis_name: str = "model") -> list:
    """Split a mesh's devices into `n_engines` disjoint 1D submeshes —
    the per-engine device sets of the real-mesh actor pool (DESIGN.md
    §11). Each engine places its params/cache on its own submesh; the
    trainer→engine weight transfer is then a cross-mesh reshard, executed
    by `launch.meshrt.MeshBroadcastExecutor`. Devices must split evenly."""
    import numpy as np

    devices = mesh.devices.reshape(-1)
    n = int(n_engines)
    if n <= 0 or len(devices) % n:
        raise ValueError(
            f"cannot split {len(devices)} devices into {n} engine meshes")
    per = len(devices) // n
    return [Mesh(np.asarray(devices[i * per:(i + 1) * per]), (axis_name,))
            for i in range(n)]


def make_disaggregated_meshes(mesh: Mesh, n_train_model: int = 8):
    """PipelineRL resource split: T trainer chips vs N-T generator chips.

    Splits the trailing "model" axis of the production mesh into a trainer
    submesh and a generator submesh (the paper's T-vs-(N-T) knob mapped to a
    mesh partition). Used by the launcher to place train_step and decode_step
    on disjoint device sets; the in-flight weight update is the reshard
    between the two.
    """
    devices = mesh.devices
    model_ax = mesh.axis_names.index("model")
    n_model = devices.shape[model_ax]
    if not (0 < n_train_model < n_model):
        raise ValueError(f"n_train_model must be in (0, {n_model})")
    take = [slice(None)] * devices.ndim
    take[model_ax] = slice(0, n_train_model)
    train_dev = devices[tuple(take)]
    take[model_ax] = slice(n_train_model, None)
    gen_dev = devices[tuple(take)]
    return Mesh(train_dev, mesh.axis_names), Mesh(gen_dev, mesh.axis_names)
