"""Real-mesh runtime (DESIGN.md §11): executed broadcast + co-sim twin.

Everything distributed used to be simulated or dry-run-lowered; this
module is the execution side. `MeshBroadcastExecutor` plugs into
`WeightBroadcaster(executor=...)` and turns every streamed publication
into *actual* per-chunk reshard transfers onto the target engine's
devices (the runtime twin of `launch.steps.lower_weight_update`), with
wall time measured per chunk. `record_cosim_trace` / `replay_trace`
close the loop: a real decode run on a mesh engine is recorded (per-tick
decode + per-chunk transfer seconds) and replayed through the EventLoop
`ActorStage`, so the simulator's pause/lag accounting can be checked
against measurement — the sim stays a calibrated twin, not a guess.

CI exercises all of it on forced host devices
(`XLA_FLAGS=--xla_force_host_platform_device_count=8`): true multi-device
SPMD on CPU, no accelerator required.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

import jax


class MeshBroadcastExecutor:
    """Executes the trainer→engine streamed weight transfer on real device
    buffers. For each chunk span (the same byte-balanced `chunk_spans`
    table the sim and the integrity gate use) the leaves are resharded
    onto the target engine's placement:

      * publisher and engine share a mesh → a cached jitted
        identity-with-out-shardings program (the executed form of
        `lower_weight_update(n_chunks=)`'s per-chunk reshard);
      * the engine owns its own device subset → a cross-mesh
        `device_put` of the span.

    Either way the per-chunk wall time is measured (`block_until_ready`)
    and returned, so `WeightBroadcaster.exec_records` holds real transfer
    costs next to the sim's modeled ones."""

    def __init__(self):
        self._programs: Dict[Any, Any] = {}

    def _program(self, engine, n_chunks: int, k: int, gshard, lo: int,
                 hi: int):
        key = (id(engine), n_chunks, k)
        fn = self._programs.get(key)
        if fn is None:
            fn = jax.jit(lambda xs: xs, out_shardings=tuple(gshard[lo:hi]))
            self._programs[key] = fn
        return fn

    def run(self, engine, params, version: int, n_chunks: int
            ) -> Dict[str, Any]:
        from repro.core.events import chunk_spans, span_bytes
        leaves = jax.tree_util.tree_leaves(params)
        spans = chunk_spans(leaves, n_chunks)
        sizes = span_bytes(leaves, spans)
        gshard = engine._pshard_leaves
        in_mesh = getattr(getattr(leaves[0], "sharding", None), "mesh", None)
        use_jit = in_mesh is not None and in_mesh == engine.mesh
        chunks: List[List[Any]] = []
        per_chunk: List[float] = []
        for k, (lo, hi) in enumerate(spans):
            t0 = time.perf_counter()
            if use_jit:
                out = self._program(engine, n_chunks, k, gshard, lo, hi)(
                    tuple(leaves[lo:hi]))
            else:
                out = jax.device_put(leaves[lo:hi], gshard[lo:hi])
            jax.block_until_ready(out)
            per_chunk.append(time.perf_counter() - t0)
            chunks.append(list(out))
        return {"chunks": chunks, "per_chunk": per_chunk,
                "seconds": sum(per_chunk), "sizes": sizes,
                "nbytes": int(sum(sizes)), "version": int(version),
                "jit": use_jit}


# ---------------------------------------------------------------------------
# co-sim calibration: record a real-mesh trace, replay it in the EventLoop
# ---------------------------------------------------------------------------

def record_cosim_trace(engine, params, *, n_ticks: int = 24,
                       publish_every: int = 8, n_chunks: int = 4,
                       task=None) -> Dict[str, Any]:
    """Run a real decode loop on a mesh engine and record its timeline.

    Every `publish_every` ticks a streamed publication of `params` begins;
    exactly one chunk installs per tick (the ActorStage `per_tick=1`
    discipline), resharded onto the engine's devices through the §11
    executed-install path and measured. Each tick records the decode wall
    seconds, the chunk transfer seconds (None on chunk-free ticks), the
    engine's weight version after installs, and the newest version
    published so far — everything `replay_trace` needs."""
    engine.refill(0.0)
    ticks: List[Dict[str, Any]] = []
    version = engine.version
    published = version
    pending = 0
    for i in range(n_ticks):
        chunk_s = None
        if pending == 0 and i and i % publish_every == 0:
            published += 1
            sizes = engine.begin_weight_stream(params, published,
                                               n_chunks=n_chunks)
            pending = len(sizes)
        if pending:
            t0 = time.perf_counter()
            engine.stream_weight_chunk()
            chunk_s = time.perf_counter() - t0
            pending -= 1
        t0 = time.perf_counter()
        engine.step(task)
        jax.block_until_ready(engine.state["tokens"])
        decode_s = time.perf_counter() - t0
        if engine.n_active == 0:
            engine.refill(float(i))
        ticks.append({"decode_s": decode_s, "chunk_s": chunk_s,
                      "version": int(engine.version),
                      "published": int(published)})
    return {"ticks": ticks, "n_chunks": int(n_chunks),
            "publish_every": int(publish_every)}


class _ReplayEngine:
    """Minimal engine for trace replay: one always-active slot so the
    tick chain keeps firing, and streamed installs with GenerationEngine's
    return contract (False until the last chunk, version swap on it)."""

    def __init__(self):
        self.version = 0
        self.n_active = 1
        self.last_stream_installed = True
        self.problems: List[Any] = []
        self._left = 0
        self._v = 0

    def refill(self, now):
        return 0

    def step(self, task=None, now=0.0):
        return []

    def set_weights(self, params, version, recompute_kv=False):
        self.version = int(version)

    def begin_weight_stream(self, params, version, n_chunks=8,
                            recompute_kv=False, expect_digest=None):
        self._left, self._v = int(n_chunks), int(version)
        return [1] * int(n_chunks)

    def stream_weight_chunk(self, token=None):
        self._left -= 1
        if self._left > 0:
            return False
        self.version = self._v
        return True


def replay_trace(trace: Dict[str, Any]) -> Dict[str, Any]:
    """Replay a recorded real-mesh trace through the EventLoop twin.

    The sim `ActorStage` is driven with the measured per-tick decode
    seconds as its step cost; each recorded publication is delivered as a
    stream whose chunks all arrive when the real run began installing,
    throttled to `per_tick=1` with `install_pause` set to that
    publication's *mean* measured chunk seconds. Returns the sim's
    predicted totals next to the measured ones — the co-sim tolerance
    check compares them (per-tick decode is shared by construction, so
    any disagreement is the sim's pause/lag *accounting*, which is
    exactly what the twin must keep faithful)."""
    from repro.core.events import ActorStage, EventLoop

    ticks = trace["ticks"]
    n_chunks = trace["n_chunks"]
    decode = [t["decode_s"] for t in ticks]
    # group consecutive chunk installs into publications
    pubs: List[Dict[str, Any]] = []
    cur: Optional[Dict[str, Any]] = None
    for i, t in enumerate(ticks):
        if t["chunk_s"] is None:
            continue
        if cur is None:
            cur = {"start": i, "chunk_s": [], "version": t["published"]}
            pubs.append(cur)
        cur["chunk_s"].append(t["chunk_s"])
        if len(cur["chunk_s"]) == n_chunks:
            cur = None
    # closed-form sim tick-start times: t_{i+1} = t_i + decode_i + pause_i
    pause_of = {}
    for p in pubs:
        mean = sum(p["chunk_s"]) / len(p["chunk_s"])
        p["mean"] = mean
        for o in range(len(p["chunk_s"])):
            pause_of[p["start"] + o] = mean
    starts = [0.0]
    for i in range(len(ticks)):
        starts.append(starts[-1] + decode[i] + pause_of.get(i, 0.0))

    loop = EventLoop()
    eng = _ReplayEngine()
    versions_sim: List[int] = []

    def step_cost(h, _i=[0]):
        versions_sim.append(eng.version)
        i, _i[0] = _i[0], _i[0] + 1
        return decode[min(i, len(decode) - 1)]

    stage = ActorStage(loop, eng, name="replay", step_cost=step_cost,
                       auto_refill=False, chain=True)
    for p in pubs:
        # safely inside (t_{start-1}, t_start]: the first install lands on
        # exactly the tick the real run installed on
        arrive = 0.5 * (starts[p["start"] - 1] + starts[p["start"]])
        loop.post(arrive, lambda now, p=p: stage.deliver_stream(
            None, p["version"], [now] * len(p["chunk_s"]),
            install_pause=p["mean"], per_tick=1))
    stage.start(0.0)
    loop.run(until=lambda: stage.ticks_completed >= len(ticks))

    measured_total = sum(decode) + sum(s for p in pubs for s in p["chunk_s"])
    measured_pause = (sum(sum(p["chunk_s"]) for p in pubs) / len(pubs)
                      if pubs else 0.0)
    lag_meas = sum(t["published"] - t["version"]
                   for t in ticks) / len(ticks)
    lag_sim = sum(t["published"] - v
                  for t, v in zip(ticks, versions_sim)) / len(ticks)
    return {
        "sim_total_s": stage.time,
        "measured_total_s": measured_total,
        "sim_pause_per_update": (stage.pause_total / stage.updates_applied
                                 if stage.updates_applied else 0.0),
        "measured_pause_per_update": measured_pause,
        "updates_sim": stage.updates_applied,
        "updates_measured": len(pubs),
        "mean_lag_sim": lag_sim,
        "mean_lag_measured": lag_meas,
        "versions_sim": versions_sim,
    }
