"""Step-program builders for the launcher and the multi-pod dry-run.

For every (architecture × input shape × mesh) this module produces the jit
callable + in_shardings needed to `.lower().compile()` the program:

  train   -> RL train_step (forward + IS-REINFORCE + Adam)
  prefill -> prompt forward building the KV cache
  decode  -> serve_step: ONE new token against a seq_len cache
  (plus)  -> weight_update: the in-flight weight transfer, expressed as a
             reshard from the trainer layout (FSDP+TP) to the generation
             layout (TP only, FSDP gathered) — its collectives ARE the
             paper's in-flight update cost, visible in the HLO.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (
    ModelConfig, ShapeSpec, for_shape, input_logical, input_specs,
)
from repro.core.algo import RLConfig
from repro.core.trainer import TrainState, train_step
from repro.models import model as M
from repro.optim.adam import AdamConfig, AdamState
from repro.sharding import Annotated, logical_to_spec, tree_shardings, tree_values

# generation engines keep tensor parallelism but gather the FSDP dim: the
# trainer->generator weight transfer is exactly this reshard. The embedding
# table's vocab dim is replicated too: a gather from a vocab-sharded operand
# makes GSPMD fully rematerialize the table every step (§Perf iteration 3)
GEN_RULES = {"p_embed": None, "p_embed_vocab": None}


def abstract_params(cfg: ModelConfig):
    return M.init_params(cfg, abstract=True)


def abstract_train_state(cfg: ModelConfig) -> Tuple[Any, Any]:
    """(TrainState of ShapeDtypeStructs, TrainState of NamedShardings) —
    shardings filled in by state_shardings()."""
    ann = abstract_params(cfg)
    params = tree_values(ann)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = AdamState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    m=jax.tree.map(f32, params),
                    v=jax.tree.map(f32, params))
    state = TrainState(params=params, opt=opt,
                       version=jax.ShapeDtypeStruct((), jnp.int32))
    return ann, state


def state_shardings(ann, mesh: Mesh, rules=None):
    ps = tree_shardings(ann, mesh, rules)
    rep = NamedSharding(mesh, P())
    return TrainState(params=ps,
                      opt=AdamState(step=rep, m=ps, v=ps),
                      version=rep)


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    rules=None):
    specs = input_specs(cfg, shape)
    logical = input_logical(cfg, shape)

    def shard(spec_tree, log_tree):
        return jax.tree.map(
            lambda s, l: NamedSharding(
                mesh, logical_to_spec(l, s.shape, mesh, rules)),
            spec_tree, log_tree,
            is_leaf=lambda x: isinstance(x, tuple) or x is None)

    out = {}
    for k, v in specs.items():
        if isinstance(v, dict):  # cache tree
            out[k] = {kk: NamedSharding(
                mesh, logical_to_spec(logical[k][kk], vv.shape, mesh, rules))
                for kk, vv in v.items()}
        else:
            out[k] = NamedSharding(
                mesh, logical_to_spec(logical[k], v.shape, mesh, rules))
    return specs, out


# ---------------------------------------------------------------------------
# step functions (pure; closed over cfg)
# ---------------------------------------------------------------------------

def make_train_fn(cfg: ModelConfig, rl: RLConfig = RLConfig(),
                  adam: AdamConfig = AdamConfig(), microbatch: int = 1):
    def fn(state, batch):
        new_state, metrics = train_step(state, batch, cfg, rl, adam,
                                        microbatch=microbatch)
        return new_state, metrics
    return fn


def make_prefill_fn(cfg: ModelConfig):
    def fn(params, batch):
        out = M.forward(params, batch["tokens"], batch["positions"], cfg,
                        prefix_embeds=batch.get("prefix_embeds"),
                        return_cache=True)
        next_tok = jnp.argmax(out["logits"][:, -1:], axis=-1)
        return next_tok, out["cache"]
    return fn


def make_serve_fn(cfg: ModelConfig, ring: bool):
    def fn(params, batch):
        out = M.decode_step(params, batch["tokens"], batch["positions"],
                            batch["cache"], batch["cache_index"], cfg,
                            ring=ring)
        next_tok = jnp.argmax(out["logits"], axis=-1)
        return next_tok, out["cache"]
    return fn


def weight_update_fn(params):
    """Identity on the weights; in/out shardings differ (train vs gen
    layout), so XLA lowers this to the in-flight weight-transfer
    collectives."""
    return params


# ---------------------------------------------------------------------------
# lowering helper
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweredProgram:
    name: str
    lowered: Any
    compiled: Any = None

    def compile(self):
        self.compiled = self.lowered.compile()
        return self.compiled


def lower_program(arch_cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                  rules=None, microbatch: int = 1,
                  donate_cache: bool = False) -> LoweredProgram:
    """Lower the step program for one (arch, shape) on `mesh`.

    donate_cache=True donates the decode batch (KV cache) so XLA aliases the
    in/out cache buffers and the ring-buffer write is in-place — without it
    every serve_step copies the whole cache (§Perf iteration 2)."""
    from repro.shardctx import sharding_context

    cfg = for_shape(arch_cfg, shape)
    specs, bshard = batch_shardings(cfg, shape, mesh, rules)

    if shape.kind == "train":
        ann, state = abstract_train_state(cfg)
        sshard = state_shardings(ann, mesh, rules)
        fn = make_train_fn(cfg, microbatch=microbatch)
        with sharding_context(mesh, rules):
            lowered = jax.jit(fn, in_shardings=(sshard, bshard)).lower(
                state, specs)
    else:
        ann = abstract_params(cfg)
        params = tree_values(ann)
        pshard = tree_shardings(ann, mesh, rules)
        if shape.kind == "prefill":
            fn = make_prefill_fn(cfg)
        else:
            ring = cfg.attention_variant == "sliding_window"
            fn = make_serve_fn(cfg, ring)
        donate = (1,) if (donate_cache and shape.kind == "decode") else ()
        with sharding_context(mesh, rules):
            lowered = jax.jit(fn, in_shardings=(pshard, bshard),
                              donate_argnums=donate).lower(params, specs)
    return LoweredProgram(f"{cfg.name}:{shape.name}", lowered)


def lower_weight_update(arch_cfg: ModelConfig, mesh: Mesh, n_chunks: int = 1):
    """Lower the trainer->generator weight transfer. n_chunks=1 (default)
    returns the single whole-tree program; n_chunks>1 returns a *list* of
    per-chunk programs over contiguous byte-balanced leaf spans — the
    launcher-side twin of the engine's streamed in-flight broadcast
    (DESIGN.md §7): each chunk's reshard collectives can be issued
    between decode steps instead of one blocking all-at-once transfer."""
    from repro.core.events import chunk_spans

    ann = abstract_params(arch_cfg)
    params = tree_values(ann)
    train_shard = tree_shardings(ann, mesh)
    # giants (>40B) keep the trainer layout at the generator too (gathering
    # 671B of expert weights over the data axis is 171 GB/dev — see §Perf-3)
    gen_rules = GEN_RULES if arch_cfg.param_count() < 40e9 else None
    gen_shard = tree_shardings(ann, mesh, gen_rules)
    if n_chunks <= 1:
        lowered = jax.jit(weight_update_fn, in_shardings=(train_shard,),
                          out_shardings=gen_shard).lower(params)
        return LoweredProgram(f"{arch_cfg.name}:weight_update", lowered)
    leaves, _ = jax.tree_util.tree_flatten(params)
    tshard_leaves = jax.tree_util.tree_leaves(train_shard)
    gshard_leaves = jax.tree_util.tree_leaves(gen_shard)
    spans = chunk_spans(leaves, n_chunks)
    programs = []
    for i, (lo, hi) in enumerate(spans):
        lowered = jax.jit(
            weight_update_fn,
            in_shardings=(tuple(tshard_leaves[lo:hi]),),
            out_shardings=tuple(gshard_leaves[lo:hi]),
        ).lower(tuple(leaves[lo:hi]))
        programs.append(LoweredProgram(
            f"{arch_cfg.name}:weight_update_chunk{i}", lowered))
    return programs


def execute_weight_update(arch_cfg: ModelConfig, mesh: Mesh,
                          n_chunks: int = 1,
                          max_bytes: int = 1 << 30) -> list:
    """EXECUTE the per-chunk weight-update reshard programs on zero-filled
    sharded buffers and measure each chunk's wall time (DESIGN.md §11) —
    the runtime companion of `lower_weight_update`, whose `t_collective_s`
    is a compiled-cost *estimate*. The model must actually fit on the
    mesh's devices (`max_bytes` guards against accidentally materializing
    a 671B dry-run config on a CPU host). Returns one record per chunk:
    {"chunk", "nbytes", "t_exec_s"}."""
    import time as _time

    from repro.core.events import chunk_spans, span_bytes

    ann = abstract_params(arch_cfg)
    shapes = tree_values(ann)
    leaves_sds, _ = jax.tree_util.tree_flatten(shapes)
    total = sum(s.size * s.dtype.itemsize for s in leaves_sds)
    if total > max_bytes:
        raise ValueError(
            f"{arch_cfg.name}: {total} param bytes exceed the "
            f"execute budget ({max_bytes}); pass a smoke config")
    train_shard = tree_shardings(ann, mesh)
    gen_rules = GEN_RULES if arch_cfg.param_count() < 40e9 else None
    gen_shard = tree_shardings(ann, mesh, gen_rules)
    t_leaves = jax.tree_util.tree_leaves(train_shard)
    g_leaves = jax.tree_util.tree_leaves(gen_shard)
    spans = chunk_spans(leaves_sds, n_chunks)
    sizes = span_bytes(leaves_sds, spans)
    out = []
    for i, (lo, hi) in enumerate(spans):
        bufs = tuple(jax.device_put(jnp.zeros(s.shape, s.dtype), sh)
                     for s, sh in zip(leaves_sds[lo:hi], t_leaves[lo:hi]))
        fn = jax.jit(weight_update_fn,
                     out_shardings=tuple(g_leaves[lo:hi]))
        jax.block_until_ready(fn(bufs))        # compile + warm
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(bufs))
        out.append({"chunk": i, "nbytes": int(sizes[i]),
                    "t_exec_s": _time.perf_counter() - t0})
    return out
