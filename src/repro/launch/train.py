"""End-to-end RL training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --mode pipeline --steps 60 --batch 16 --lr 3e-3 \
        --ckpt-dir /tmp/pipelinerl

Runs PipelineRL (or the Conventional RL baseline) on the synthetic math
reasoning task with the tiny testbed model (CPU-scale twin of the paper's
Qwen-2.5-7B runs), logging reward/ESS/lag per optimizer step and writing
periodic checkpoints.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np

from repro.checkpoint import checkpoint
from repro.configs.tiny import config as tiny_config
from repro.core.algo import RLConfig
from repro.core.conventional import ConventionalConfig, ConventionalRL
from repro.core.evaluator import Evaluator
from repro.core.pipeline import PipelineConfig, PipelineRL
from repro.core.preprocess import PreprocessConfig, Preprocessor
from repro.core.rollout import EngineConfig
from repro.core.trainer import Trainer
from repro.data.math_task import MathTask
from repro.models import model as M
from repro.optim.adam import AdamConfig
from repro.optim.schedule import warmup_constant
from repro.sharding import tree_values


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("pipeline", "conventional"),
                    default="pipeline")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--g", type=int, default=4, help="G for conventional")
    ap.add_argument("--slots", type=int, default=16, help="H generation batch")
    ap.add_argument("--max-len", type=int, default=16)
    ap.add_argument("--chips", type=int, default=8)
    ap.add_argument("--train-chips", type=int, default=4)
    ap.add_argument("--engines", type=int, default=1,
                    help="actor-pool size: independent generation engines "
                         "sharing the N-T generation chips (DESIGN.md §7)")
    ap.add_argument("--engine-speeds", default=None,
                    help="comma-separated per-engine HardwareModel speed "
                         "overrides (heterogeneous pool), e.g. '2.0,1.0' "
                         "— len must equal --engines")
    ap.add_argument("--router",
                    choices=("fifo", "shortest_queue", "length_affinity"),
                    default="fifo",
                    help="PoolRouter admission policy between the shared "
                         "prompt source and the pool (DESIGN.md §7 pool "
                         "scheduling)")
    ap.add_argument("--broadcast", choices=("streamed", "atomic", "free"),
                    default="streamed",
                    help="weight-publication mode: streamed chunks overlap "
                         "decode (brief per-chunk pause), atomic stalls "
                         "decode for the whole transfer, free is the "
                         "legacy zero-cost swap")
    ap.add_argument("--bcast-chunks", type=int, default=8,
                    help="layer chunks per streamed publication")
    ap.add_argument("--lag-mode", choices=("off", "token_is", "truncated"),
                    default="off",
                    help="staleness-corrected objective (DESIGN.md §12): "
                         "token_is = per-token lag-conditional IS clamp, "
                         "truncated = Truncated-PPO staleness horizon; off "
                         "is bit-identical to the uncorrected loss")
    ap.add_argument("--max-lag", type=int, default=None,
                    help="periodic asynchrony (pipeline mode): bound every "
                         "trained token's weight lag — actors pause at the "
                         "bound, pack() masks over-bound tokens. 0 = "
                         "conventional-RL lockstep, unset = free-running")
    ap.add_argument("--ckpt-pause", type=float, default=0.0,
                    help="simulated trainer stall (flashes) every "
                         "--ckpt-every steps (queue back-pressure study)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--d-model", type=int, default=96)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--max-operand", type=int, default=3)
    ap.add_argument("--fused-loss", action="store_true",
                    help="fused lm-head cross-entropy trainer path "
                         "(DESIGN.md §6: no logits materialization)")
    ap.add_argument("--recompute-kv", action="store_true",
                    help="§5.1 ablation: recompute cache at weight updates")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-plan", default=None,
                    help="chaos testing (DESIGN.md §8/§10): comma-separated "
                         "fault specs, e.g. 'engine:0@300r200' (crash engine "
                         "0 at t=300, restart 200 flashes later), "
                         "'trainer@500r100', 'pre@400', "
                         "'link:1@600d300p0.5' (lossy broadcast link); gray "
                         "faults: 'slow:0@300d200x4' (4x cost window), "
                         "'hang:1@300[r60]' (engine wedges; watchdog "
                         "detects, optional restart 60 flashes after "
                         "detection), 'corrupt@300d200p0.5' (damaged weight "
                         "chunks, checksum-gated), 'nan@500x3' (3 non-finite "
                         "trainer steps), 'poison@7' (7th prompt wedges its "
                         "engine); or 'chaos:<seed>[:<horizon>]' for a "
                         "seeded random plan; pipeline mode only")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--eval-every", type=int, default=0,
                    help="greedy held-out eval every N optimizer steps")
    ap.add_argument("--kl-coef", type=float, default=0.0,
                    help="reference-KL reward shaping (preprocessor stage)")
    ap.add_argument("--warmup", type=int, default=0,
                    help="LR warmup steps (0 = constant)")
    ap.add_argument("--log-out", default=None)
    args = ap.parse_args()

    if args.mode == "conventional" and args.max_lag is not None:
        ap.error("--max-lag is a pipeline-mode knob (conventional RL is "
                 "already the max_lag=0 lag structure by construction)")

    task = MathTask(max_operand=args.max_operand, ops="+")
    cfg = tiny_config(vocab_size=task.tok.vocab_size, d_model=args.d_model,
                      n_layers=args.layers)
    if args.fused_loss:
        cfg = dataclasses.replace(cfg, fused_loss=True)
    params = tree_values(M.init_params(cfg, jax.random.PRNGKey(args.seed)))
    schedule = warmup_constant(args.lr, args.warmup) if args.warmup else None
    trainer = Trainer(cfg, params,
                      rl=RLConfig(entropy_coef=0.003,
                                  lag_mode=args.lag_mode),
                      adam=AdamConfig(lr=args.lr), lr_schedule=schedule)
    ec = EngineConfig(n_slots=args.slots, max_len=args.max_len)
    pack_rows = max(2, args.batch * args.max_len // 320)
    preprocessor = None
    if args.kl_coef > 0:
        # freeze the init policy as pi_ref (paper Fig. 4 middle stage)
        preprocessor = Preprocessor(
            cfg, params, PreprocessConfig(kl_coef=args.kl_coef,
                                          max_len=args.max_len))
    evaluator = Evaluator(cfg, task, max_len=args.max_len) \
        if args.eval_every else None

    engine_speeds = None
    if args.engine_speeds:
        engine_speeds = [float(x) for x in args.engine_speeds.split(",")]

    fault_plan = None
    if args.fault_plan:
        from repro.core.events import FaultPlan
        fault_plan = FaultPlan.parse(args.fault_plan,
                                     n_engines=args.engines)

    if args.mode == "pipeline":
        runner = PipelineRL(
            cfg, params, task, ec,
            PipelineConfig(batch_size=args.batch, n_opt_steps=args.steps,
                           n_chips=args.chips, train_chips=args.train_chips,
                           pack_rows=pack_rows, pack_seq=80,
                           recompute_kv=args.recompute_kv,
                           n_engines=args.engines, broadcast=args.broadcast,
                           broadcast_chunks=args.bcast_chunks,
                           engine_speeds=engine_speeds, router=args.router,
                           ckpt_every=(args.ckpt_every if args.ckpt_pause
                                       or args.ckpt_dir else 0),
                           ckpt_pause=args.ckpt_pause,
                           ckpt_dir=args.ckpt_dir,
                           max_lag=args.max_lag),
            trainer=trainer, seed=args.seed, preprocessor=preprocessor,
            fault_plan=fault_plan)
    else:
        runner = ConventionalRL(
            cfg, params, task, ec,
            ConventionalConfig(batch_size=args.batch, g_steps=args.g,
                               n_opt_steps=args.steps, n_chips=args.chips,
                               pack_rows=pack_rows, pack_seq=80),
            trainer=trainer, seed=args.seed)

    ckpt_paths = []
    last_v = 0
    while trainer.version < args.steps:
        target = min(trainer.version + args.ckpt_every, args.steps)
        runner.run(target)
        for r in runner.log[last_v:]:
            print(f"step {r['version']:4d}  t={r['time']:9.0f}f  "
                  f"reward={r['reward']:+.3f}  ess={r.get('ess', 0):.3f}  "
                  f"max_lag={r['max_lag']:.0f}  loss={r.get('loss', 0):+.4f}",
                  flush=True)
        last_v = len(runner.log)
        if args.ckpt_dir:
            path = os.path.join(args.ckpt_dir, f"step{trainer.version}.npz")
            checkpoint.save(path, trainer.state.params)
            ckpt_paths.append(path)
            print(f"checkpoint -> {path}", flush=True)
        if evaluator and args.eval_every and \
                trainer.version % args.eval_every == 0:
            ev = evaluator.evaluate(trainer.state.params)
            print(f"eval @ step {trainer.version}: "
                  f"success_rate={ev['success_rate']:.3f} "
                  f"mean_len={ev['mean_len']:.1f}", flush=True)

    if args.mode == "pipeline":
        bs = runner.broadcast_stats()
        eng = bs["engines"]
        print(f"broadcast[{bs['mode']}]: {bs['published']} publications, "
              f"mean decode pause/update = "
              f"{np.mean([e['pause_per_update'] for e in eng]):.2f}f "
              f"across {len(eng)} engine(s)", flush=True)
        if args.max_lag is not None or args.lag_mode != "off":
            ls = runner.lag_stats()
            bound = "inf" if ls["bound"] is None else str(ls["bound"])
            print(f"lag[bound={bound},mode={args.lag_mode}]: "
                  f"max={ls['max_lag']} mean={ls['mean_lag']:.2f} over "
                  f"{ls['trained_tokens']} trained tokens, "
                  f"masked={ls['masked_tokens']}, hist={ls['histogram']}",
                  flush=True)
        if args.router != "fifo" or engine_speeds:
            rs = runner.router_stats()
            print(f"router[{rs['policy']}]: " + ", ".join(
                f"{e['name']}(x{e['speed']:g})={e['assigned']}p/"
                f"{e['prompt_tokens']}tok/{e['declined']}decl"
                for e in rs["engines"]), flush=True)
        if fault_plan is not None:
            ps = runner.pool_stats()
            tr = ps["trainer"]
            print(f"faults: {len(runner.fault_log)} events, "
                  f"rollouts_lost={ps['rollouts_lost']}, "
                  f"prompts_salvaged={ps['prompts_salvaged']}, "
                  f"requeued={ps['prompts_requeued']}, "
                  f"quarantined={ps['prompts_quarantined']}, "
                  f"trainer crashes={tr['crashes']} "
                  f"(steps_lost={tr['steps_lost']}, "
                  f"restored from v{tr['last_ckpt_version']})", flush=True)
            if runner.monitor is not None:
                h = ps["health"]
                print(f"health: {h['sweeps']} sweeps, "
                      f"hangs_detected={h['hangs_detected']}, "
                      f"stragglers_demoted={h['stragglers_demoted']}/"
                      f"restored={h['stragglers_restored']}", flush=True)
            bc = ps["broadcast"]
            if bc["chunks_corrupt"] or bc["wchunks_rejected"]:
                print(f"integrity: chunks_corrupt={bc['chunks_corrupt']}, "
                      f"rejected={bc['wchunks_rejected']}, "
                      f"torn={bc['wstreams_torn']}", flush=True)

    if args.log_out:
        os.makedirs(os.path.dirname(args.log_out) or ".", exist_ok=True)
        with open(args.log_out, "w") as f:
            json.dump(runner.log, f, indent=1)
        print(f"log -> {args.log_out}")


if __name__ == "__main__":
    main()
