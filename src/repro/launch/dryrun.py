import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and record memory/cost/collective analysis.

  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, OOM-at-compile, or unsupported collective
fails here. Results feed EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config          # noqa: E402
from repro.configs.base import for_shape                        # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.steps import lower_program, lower_weight_update  # noqa: E402
from repro.roofline.analysis import analyze, model_flops_estimate  # noqa: E402


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            include_weight_update: bool = False, calibrated: bool = False,
            optimized: bool = False, wu_chunks: int = 0,
            wu_execute: bool = False) -> dict:
    """optimized=True applies the §Perf winners: remat + microbatch=16 for
    train shapes, GEN_RULES + cache donation for inference shapes.
    calibrated=True replaces the scan-blind cost_analysis terms with the
    unroll-calibrated extrapolation (see repro.roofline.calibrate)."""
    import dataclasses

    from repro.launch.steps import GEN_RULES
    from repro.roofline.calibrate import calibrated_roofline

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(map(str, mesh.devices.shape)), "ok": False,
           "optimized": optimized, "calibrated": calibrated}
    rules = None
    lower_kw = {}
    microbatch = 1
    if optimized:
        if shape.kind == "train":
            cfg = dataclasses.replace(cfg, remat=True)
            microbatch = 16
            lower_kw["microbatch"] = 16
        else:
            # GEN_RULES gathers the FSDP dim: a win up to ~40B params, but
            # replicating 671B-MoE weights over the data axis costs 84GB/dev
            # — keep weight sharding for the giants (§Perf-2 discussion)
            if cfg.param_count() < 40e9:
                rules = GEN_RULES
                lower_kw["rules"] = GEN_RULES
            lower_kw["donate_cache"] = True
    t0 = time.time()
    try:
        prog = lower_program(cfg, shape, mesh, **lower_kw)
        t_lower = time.time() - t0
        compiled = prog.compile()
        t_compile = time.time() - t0 - t_lower
        if calibrated:
            ma0 = compiled.memory_analysis()
            roof = calibrated_roofline(
                cfg, shape, mesh, microbatch=microbatch, rules=rules,
                mem_bytes_per_device=float(ma0.argument_size_in_bytes
                                           + ma0.temp_size_in_bytes))
            if rules is not None:
                roof.name += ":gen_rules"
        else:
            roof = analyze(prog.name, compiled, n_dev,
                           model_flops_estimate(for_shape(cfg, shape), shape))
        rec.update(ok=True, t_lower_s=round(t_lower, 1),
                   t_compile_s=round(t_compile, 1), **roof.row())
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                "argument_gb": ma.argument_size_in_bytes / 1e9,
                "output_gb": ma.output_size_in_bytes / 1e9,
                "temp_gb": ma.temp_size_in_bytes / 1e9,
                "peak_gb": (ma.argument_size_in_bytes
                            + ma.temp_size_in_bytes) / 1e9,
            }
        except Exception:
            pass
        if include_weight_update or wu_chunks > 1:
            wu = lower_weight_update(cfg, mesh)
            wu_compiled = wu.compile()
            wroof = analyze(wu.name, wu_compiled, n_dev)
            rec["weight_update"] = wroof.row()
        if wu_chunks > 1:
            # the streamed in-flight broadcast's launcher-side twin
            # (DESIGN.md §7): per-chunk reshard programs over contiguous
            # byte-balanced leaf spans. Each chunk's collective cost is
            # the decode pause one installed chunk charges on a real
            # mesh — recorded next to the whole-tree program so the
            # whole-vs-max-chunk ratio (the streamed-broadcast win) is a
            # dry-run number, not a co-sim assumption.
            chunk_rows = []
            for prog in lower_weight_update(cfg, mesh, n_chunks=wu_chunks):
                croof = analyze(prog.name, prog.compile(), n_dev)
                chunk_rows.append(croof.row())
            rec["weight_update_chunks"] = {
                "n_chunks_requested": wu_chunks,
                "n_chunks": len(chunk_rows),
                "chunks": chunk_rows,
                "sum_coll_gbytes_per_dev": sum(
                    c["coll_gbytes_per_dev"] for c in chunk_rows),
                "sum_t_collective_s": sum(
                    c["t_collective_s"] for c in chunk_rows),
                "max_chunk_t_collective_s": max(
                    (c["t_collective_s"] for c in chunk_rows), default=0.0),
            }
            if wu_execute:
                # run the same per-chunk reshard programs on zero-filled
                # sharded buffers (DESIGN.md §11): measured t_exec_s sits
                # next to the compiled t_collective_s estimate above, so
                # estimate-vs-execution drift is a dry-run column. Only
                # meaningful for configs that fit on the host devices —
                # execute_weight_update's byte guard turns a 70B config
                # into an error record, not an OOM.
                from repro.launch.steps import execute_weight_update
                try:
                    execd = execute_weight_update(
                        cfg, mesh, n_chunks=wu_chunks)
                    rec["weight_update_chunks"]["executed"] = execd
                    rec["weight_update_chunks"]["sum_t_exec_s"] = sum(
                        c["t_exec_s"] for c in execd)
                except ValueError as e:
                    rec["weight_update_chunks"]["executed_error"] = str(e)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["t_total_s"] = round(time.time() - t0, 1)
    return rec


def run_disaggregated(arch: str, n_train_model: int = 8) -> dict:
    """The paper's T-vs-(N-T) resource split as meshes: lower train_step on
    the trainer submesh, serve_step on the generator submesh, and the
    in-flight weight transfer between them — proving the disaggregated
    placement is coherent (PipelineRL's actual deployment topology)."""
    from repro.launch.mesh import make_disaggregated_meshes
    from repro.launch.steps import GEN_RULES

    full = make_production_mesh()
    train_mesh, gen_mesh = make_disaggregated_meshes(
        full, n_train_model=n_train_model)
    cfg = get_config(arch)
    rec = {"arch": arch, "train_mesh": str(train_mesh.devices.shape),
           "gen_mesh": str(gen_mesh.devices.shape), "ok": False}
    t0 = time.time()
    try:
        tp = lower_program(cfg, SHAPES["train_4k"], train_mesh)
        tc = tp.compile()
        rec["train"] = analyze(tp.name, tc, train_mesh.devices.size).row()
        gp = lower_program(cfg, SHAPES["decode_32k"], gen_mesh,
                           rules=GEN_RULES, donate_cache=True)
        gc = gp.compile()
        rec["serve"] = analyze(gp.name, gc, gen_mesh.devices.size).row()
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["t_total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--weight-update", action="store_true")
    ap.add_argument("--wu-chunks", type=int, default=0,
                    help="also lower the weight update as N>=2 per-chunk "
                         "reshard programs (the streamed broadcast's "
                         "launcher twin) and record per-chunk collective "
                         "cost next to the whole-tree program (implies "
                         "the whole-tree --weight-update record)")
    ap.add_argument("--wu-execute", action="store_true",
                    help="with --wu-chunks: EXECUTE the per-chunk reshard "
                         "programs on zero-filled sharded buffers and "
                         "record measured t_exec_s next to the compiled "
                         "t_collective_s estimate (needs the params to "
                         "fit on the host devices)")
    ap.add_argument("--calibrated", action="store_true",
                    help="unroll-calibrated roofline terms (3 extra compiles)")
    ap.add_argument("--optimized", action="store_true",
                    help="apply §Perf winners (remat+microbatch / GEN_RULES)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.wu_chunks == 1 or args.wu_chunks < 0:
        ap.error("--wu-chunks must be >= 2 (1 chunk IS the whole-tree "
                 "program; use --weight-update for that)")

    combos = []
    if args.all:
        combos = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    results = []
    for arch, shape in combos:
        rec = run_one(arch, shape, multi_pod=args.multi_pod,
                      include_weight_update=args.weight_update,
                      calibrated=args.calibrated, optimized=args.optimized,
                      wu_chunks=args.wu_chunks, wu_execute=args.wu_execute)
        status = "OK " if rec["ok"] else "FAIL"
        print(f"[{status}] {arch:24s} {shape:12s} mesh={rec['mesh']} "
              f"t={rec['t_total_s']}s "
              + (f"bottleneck={rec.get('bottleneck')}" if rec["ok"]
                 else rec.get("error", "")), flush=True)
        results.append(rec)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=str)

    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} combinations lowered + compiled")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
